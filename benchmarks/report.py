"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json.

Usage: PYTHONPATH=src python -m benchmarks.report [--out EXPERIMENTS_gen.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "dryrun"

ARCH_ORDER = [
    "qwen2-moe-a2.7b", "kimi-k2-1t-a32b", "musicgen-large", "gemma3-4b",
    "gemma-2b", "deepseek-67b", "codeqwen1.5-7b", "rwkv6-7b",
    "recurrentgemma-9b", "qwen2-vl-2b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def fmt_e(x):
    return f"{x:.3g}"


def load():
    cells = {}
    for f in RESULTS.glob("*.json"):
        d = json.loads(f.read_text())
        cells[(d["arch"], d["shape"])] = d
    return cells


def dryrun_table(cells) -> str:
    rows = ["| arch | shape | mesh | compile | peak GiB/dev | fits 16GiB "
            "| #coll | coll GB/chip |",
            "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None:
                continue
            if "skipped" in d:
                rows.append(f"| {a} | {s} | — | SKIP (sub-quadratic gate) "
                            f"| — | — | — | — |")
                continue
            if "error" in d:
                rows.append(f"| {a} | {s} | — | ERROR | — | — | — | — |")
                continue
            for mesh in ("pod", "multipod"):
                m = d.get("mesh", {}).get(mesh)
                if not m:
                    continue
                rows.append(
                    f"| {a} | {s} | {mesh} | {m['compile_seconds']}s "
                    f"| {fmt_bytes(m.get('peak_bytes_per_device', 0))} "
                    f"| {'yes' if m.get('fits_hbm') else 'NO'} "
                    f"| {m.get('collective_count', 0)} "
                    f"| {m.get('collective_bytes_per_chip', 0)/1e9:.1f} |")
    return "\n".join(rows)


def roofline_table(cells) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS/HLO | roofline frac | step s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None or "roofline" not in d:
                continue
            r = d["roofline"]
            rows.append(
                f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
                f"| {r['collective_s']:.4f} | **{r['dominant']}** "
                f"| {r['useful_flops_ratio']:.2f} "
                f"| {r['roofline_fraction']:.3f} | {r['step_s']:.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=Path, default=None)
    args = ap.parse_args()
    cells = load()
    out = ["## §Dry-run (generated)", "", dryrun_table(cells), "",
           "## §Roofline (generated, single-pod 256 chips)", "",
           roofline_table(cells), ""]
    text = "\n".join(out)
    if args.out:
        args.out.write_text(text)
    print(text)


if __name__ == "__main__":
    main()
