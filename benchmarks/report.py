"""Generate the EXPERIMENTS.md §Dry-run, §Roofline, and §Serving tables
from results/dryrun/*.json and results/BENCH_serve.json.

Usage: PYTHONPATH=src python -m benchmarks.report [--out EXPERIMENTS_gen.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "dryrun"
SERVE_JSON = REPO / "results" / "BENCH_serve.json"

ARCH_ORDER = [
    "qwen2-moe-a2.7b", "kimi-k2-1t-a32b", "musicgen-large", "gemma3-4b",
    "gemma-2b", "deepseek-67b", "codeqwen1.5-7b", "rwkv6-7b",
    "recurrentgemma-9b", "qwen2-vl-2b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def fmt_e(x):
    return f"{x:.3g}"


def load():
    cells = {}
    for f in RESULTS.glob("*.json"):
        d = json.loads(f.read_text())
        cells[(d["arch"], d["shape"])] = d
    return cells


def dryrun_table(cells) -> str:
    rows = ["| arch | shape | mesh | compile | peak GiB/dev | fits 16GiB "
            "| #coll | coll GB/chip |",
            "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None:
                continue
            if "skipped" in d:
                rows.append(f"| {a} | {s} | — | SKIP (sub-quadratic gate) "
                            f"| — | — | — | — |")
                continue
            if "error" in d:
                rows.append(f"| {a} | {s} | — | ERROR | — | — | — | — |")
                continue
            for mesh in ("pod", "multipod"):
                m = d.get("mesh", {}).get(mesh)
                if not m:
                    continue
                rows.append(
                    f"| {a} | {s} | {mesh} | {m['compile_seconds']}s "
                    f"| {fmt_bytes(m.get('peak_bytes_per_device', 0))} "
                    f"| {'yes' if m.get('fits_hbm') else 'NO'} "
                    f"| {m.get('collective_count', 0)} "
                    f"| {m.get('collective_bytes_per_chip', 0)/1e9:.1f} |")
    return "\n".join(rows)


def roofline_table(cells) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS/HLO | roofline frac | step s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = cells.get((a, s))
            if d is None or "roofline" not in d:
                continue
            r = d["roofline"]
            rows.append(
                f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
                f"| {r['collective_s']:.4f} | **{r['dominant']}** "
                f"| {r['useful_flops_ratio']:.2f} "
                f"| {r['roofline_fraction']:.3f} | {r['step_s']:.3f} |")
    return "\n".join(rows)


# -------------------------------------------------------------- serving
# BENCH_serve.json accumulates one row per (arch, cache, schedule) leg;
# the schedule string names the row family.  Each family carries its own
# metric columns, so the section renders one table per family instead of
# a sparse union-of-all-keys grid.
def _cell(r, key, fmt="{}"):
    v = r.get(key)
    if v is None:
        return "—"
    if isinstance(v, bool):
        return "yes" if v else "NO"
    if isinstance(v, float):
        return fmt.format(v)
    return str(v)


SERVE_FAMILIES = [
    # (title, predicate on schedule, [(header, key, float fmt)])
    ("throughput (phased / static / continuous)",
     lambda s: s in ("phased", "static", "continuous"),
     [("decode tok/s", "decode_tok_s", "{:.0f}"),
      ("total tok/s", "total_tok_s", "{:.0f}"),
      ("prefill tok/s", "prefill_tok_s", "{:.0f}"),
      ("ttft p50 s", "ttft_p50_s", "{:.4f}"),
      ("vs static", "speedup_vs_static", "{:.2f}x"),
      ("rejected", "rejected", "{}")]),
    ("prefix sharing (continuous-share* / continuous-int8-*)",
     lambda s: s.startswith(("continuous-share", "continuous-int8")),
     [("kv dtype", "kv_dtype", "{}"),
      ("decode tok/s", "decode_tok_s", "{:.0f}"),
      ("eff. prefill tok/s", "prefill_tok_s_effective", "{:.0f}"),
      ("prefix hits", "prefix_hits", "{}"),
      ("CoW copies", "cow_copies", "{}"),
      ("peak KV MiB", "max_resident_kv_bytes", "{:.2f}")]),
    ("tensor parallel (continuous-tp*)",
     lambda s: s.startswith("continuous-tp"),
     [("tp", "tp", "{}"),
      ("devices", "devices", "{}"),
      ("decode tok/s", "decode_tok_s", "{:.0f}"),
      ("total tok/s", "total_tok_s", "{:.0f}"),
      ("matches tp=1", "tokens_match_oracle", "{}"),
      ("KV sharded", "kv_sharded", "{}")]),
    ("speculative decoding (continuous-spec*)",
     lambda s: s.startswith("continuous-spec"),
     [("drafter", "drafter", "{}"),
      ("draft toks", "draft_tokens", "{}"),
      ("decode tok/s", "decode_tok_s", "{:.0f}"),
      ("baseline tok/s", "baseline_decode_tok_s", "{:.0f}"),
      ("vs baseline", "speedup_vs_baseline", "{:.2f}x"),
      ("accept rate", "acceptance_rate", "{:.2f}"),
      ("toks/step", "accepted_per_step", "{:.2f}"),
      ("matches baseline", "tokens_match_baseline", "{}")]),
]


def load_serve():
    if not SERVE_JSON.exists():
        return []
    return json.loads(SERVE_JSON.read_text()).get("rows", [])


def serve_tables(rows) -> str:
    out = []
    for title, match, cols in SERVE_FAMILIES:
        fam = [r for r in rows if match(r.get("schedule", ""))]
        if not fam:
            continue
        out.append(f"### {title}")
        out.append("")
        out.append("| arch | cache | schedule | "
                   + " | ".join(h for h, _, _ in cols) + " |")
        out.append("|---" * (3 + len(cols)) + "|")
        for r in sorted(fam, key=lambda r: (r.get("arch", ""),
                                            r.get("schedule", ""))):
            if "max_resident_kv_bytes" in r:   # render bytes as MiB
                r = dict(r, max_resident_kv_bytes=(
                    r["max_resident_kv_bytes"] / 2**20))
            cells = " | ".join(_cell(r, k, f) for _, k, f in cols)
            out.append(f"| {r.get('arch', '—')} | {r.get('cache', '—')} "
                       f"| {r.get('schedule', '—')} | {cells} |")
        out.append("")
    leftover = [r for r in rows
                if not any(m(r.get("schedule", ""))
                           for _, m, _ in SERVE_FAMILIES)]
    for r in leftover:    # unknown family: never drop a row silently
        out.append(f"- unrendered row: {r.get('arch')}/{r.get('schedule')}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=Path, default=None)
    args = ap.parse_args()
    cells = load()
    out = ["## §Dry-run (generated)", "", dryrun_table(cells), "",
           "## §Roofline (generated, single-pod 256 chips)", "",
           roofline_table(cells), ""]
    serve = load_serve()
    if serve:
        out += ["## §Serving (generated, smoke-scale CPU rows)", "",
                serve_tables(serve)]
    text = "\n".join(out)
    if args.out:
        args.out.write_text(text)
    print(text)


if __name__ == "__main__":
    main()
