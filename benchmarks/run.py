"""Benchmark harness — one section per paper table/figure.

The paper's evaluation (Fig. 7) is a staged-transformation progression for
three kernels (stencil, matmul, N-body).  This harness reproduces that
structure on the TPU-adapted kernels:

* ``us_per_call`` — measured wall time of each stage's lowering on THIS
  host (single-core XLA-CPU; Pallas stages in interpret mode time their
  pure-jnp lowering instead, since interpret mode measures the Python
  emulator, not the kernel).  Measured numbers order the stages; absolute
  values are CPU numbers.
* ``derived`` — the §1.2 pipeline model + roofline terms evaluated for
  TPU v5e (DESIGN.md §7): derived_us = max(compute, memory) time for one
  call at that stage's parallelism.  This is the column comparable to the
  paper's FPGA numbers.

Output: ``name,us_per_call,derived`` CSV rows (assignment contract).

``--tune`` mode instead sweeps the repro.tune design space for all five
Pallas kernels (two problem shapes each by default), persists the winners
in the JSON plan cache (``results/tuned_plans.json``, or ``--tune-cache``),
and emits ``kernel,shape,dtype,backend,heuristic_us,tuned_us,speedup,plan``
CSV rows plus a full report at ``--tune-out`` (default
``results/BENCH_tune.json``).  Because the heuristic plan is always
candidate 0 of each sweep, tuned_us <= heuristic_us within a sweep's own
measurements — the tuned column never regresses beyond timer noise.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import TPU_V5E, PipelineModel
from repro.core.plan import Level
from repro.kernels.attention import flash_attention
from repro.kernels.histogram import histogram
from repro.kernels.matmul import matmul
from repro.kernels.nbody import nbody_accel
from repro.kernels.stencil import jacobi4

HW = TPU_V5E
ROWS = []


def _time(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def emit(name: str, us: float, derived: float):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived:.3f}", flush=True)


# ------------------------------------------------------------------ derived
def derived_matmul_us(n, k, m, level: Level) -> float:
    flops = 2.0 * n * k * m
    bytes_ = 2.0 * (n * k + k * m + n * m)
    if level == Level.T0_NAIVE:
        # loop-carried dependency: I = L_acc cycles per MAC on one unit
        l_acc = 6
        return PipelineModel(64, l_acc, flops / 2).seconds(HW.clock_hz) * 1e6
    if level == Level.T1_PIPELINED:
        macs_per_cycle = 1.0          # I=1, one MAC pipeline
    elif level == Level.T2_VECTORIZED:
        macs_per_cycle = 8 * 128      # full VPU (§3.1)
    else:
        macs_per_cycle = HW.peak_flops / 2 / HW.clock_hz  # MXUs (§3.2)
    compute = PipelineModel(
        128, 1, flops / 2 / macs_per_cycle).seconds(HW.clock_hz)
    memory = bytes_ / HW.hbm_bw
    return max(compute, memory) * 1e6


def derived_stencil_us(rows, cols, level: Level) -> float:
    cells = float(rows) * cols
    flops = 4.0 * cells
    if level == Level.T0_NAIVE:
        bytes_ = 6 * 4.0 * cells      # no reuse: 5 reads + 1 write (§6.1)
        compute = PipelineModel(32, 4, cells).seconds(HW.clock_hz)
    elif level in (Level.T1_PIPELINED, Level.T2_VECTORIZED):
        bytes_ = 2 * 4.0 * cells      # delay buffer (§2.2): 1R + 1W
        compute = flops / (2 * 8 * 128 * HW.clock_hz)
    else:
        # T3: P=32 timesteps fused through VMEM (§3.3 systolic replication)
        bytes_ = 2 * 4.0 * cells / 32
        compute = flops / (2 * 8 * 128 * HW.clock_hz)
    memory = bytes_ / HW.hbm_bw
    return max(compute, memory) * 1e6


def derived_nbody_us(n, level: Level) -> float:
    pairs = float(n) * n
    flops_per_pair = 20.0
    if level == Level.T0_NAIVE:
        # serial FLOPs per pair + L_acc-cycle accumulate dependency
        t = PipelineModel(64, flops_per_pair / 2 + 6,
                          pairs).seconds(HW.clock_hz)
        return max(t, pairs * 16 / HW.hbm_bw) * 1e6   # (N,N) spills
    if level == Level.T1_PIPELINED:
        lanes = 1.0
    elif level == Level.T2_VECTORIZED:
        lanes = 8 * 128 / 4.0          # rsqrt limits vector issue
    else:
        lanes = 8 * 128                # resident targets (§3.2) full VPU
    compute = PipelineModel(
        128, 1, pairs * flops_per_pair / (2 * lanes)).seconds(HW.clock_hz)
    memory = 16.0 * n / HW.hbm_bw      # positions+masses stream once
    return max(compute, memory) * 1e6


# --------------------------------------------------------------- benchmarks
def bench_matmul():
    n = k = m = 256
    a = jax.random.normal(jax.random.key(0), (n, k), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (k, m), jnp.float32)
    for level in (Level.T0_NAIVE, Level.T1_PIPELINED, Level.T2_VECTORIZED,
                  Level.T3_REPLICATED):
        if level in (Level.T2_VECTORIZED, Level.T3_REPLICATED):
            us = _time(lambda: matmul(a, b, level=Level.T1_PIPELINED))
        else:
            us = _time(lambda: matmul(a, b, level=level), reps=3)
        emit(f"matmul_{level.name}", us,
             derived_matmul_us(8192, 8192, 8192, level))


def bench_stencil():
    x = jax.random.normal(jax.random.key(0), (256, 512), jnp.float32)
    for level in (Level.T0_NAIVE, Level.T1_PIPELINED, Level.T3_REPLICATED):
        us = _time(lambda: jacobi4(
            x, steps=1,
            level=Level.T1_PIPELINED if level != Level.T0_NAIVE
            else Level.T0_NAIVE))
        emit(f"stencil_{level.name}", us,
             derived_stencil_us(8192, 8192, level))


def bench_nbody():
    n = 512
    pos = jax.random.normal(jax.random.key(0), (3, n), jnp.float32)
    mass = jax.random.uniform(jax.random.key(1), (n,)) + 0.1
    for level in (Level.T0_NAIVE, Level.T1_PIPELINED, Level.T3_REPLICATED):
        us = _time(lambda: nbody_accel(pos, mass,
                                       level=Level.T1_PIPELINED), reps=3)
        emit(f"nbody_{level.name}", us, derived_nbody_us(16128, level))


def bench_histogram():
    vals = jax.random.randint(jax.random.key(0), (1 << 16,), 0, 256,
                              jnp.int32)
    us = _time(lambda: histogram(vals, 256, level=Level.T1_PIPELINED))
    n = float(1 << 20)
    derived = max(n * 4 / HW.hbm_bw,
                  n * 256 * 2 / HW.peak_flops) * 1e6     # one-hot MXU
    emit("histogram_onehot_mxu", us, derived)


def bench_flash_attention():
    b, h, s, hd = 1, 4, 256, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, hd), jnp.bfloat16)
               for kk in ks)
    us = _time(lambda: flash_attention(q, k, v, level=Level.T1_PIPELINED))
    S, HD, H = 4096, 128, 32
    flops = 2 * 2 * H * (S * S / 2) * HD
    derived = max(flops / HW.peak_flops,
                  (3 * S * H * HD * 2) / HW.hbm_bw) * 1e6
    emit("flash_attention_causal_4k", us, derived)


def bench_lm_train_step():
    from repro.configs import get_arch
    from repro.models.transformer import ExecOptions, Model
    from repro.optim.adamw import AdamWConfig
    from repro.train.steps import (TrainStepConfig, init_train_state,
                                   make_train_step)
    for arch in ("gemma-2b", "qwen2-moe-a2.7b", "rwkv6-7b"):
        cfg = get_arch(arch).smoke()
        model = Model(cfg, opts=ExecOptions(mode="run", block_q=32,
                                            block_kv=32))
        ts = TrainStepConfig(opt=AdamWConfig())
        params, opt = init_train_state(model, ts, jax.random.key(0))
        step = jax.jit(make_train_step(model, ts))
        batch = {"labels": jax.random.randint(jax.random.key(2), (2, 64), 0,
                                              cfg.vocab_size)}
        if cfg.input_mode == "embeddings":
            batch["embeddings"] = jax.random.normal(
                jax.random.key(1), (2, 64, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = jax.random.randint(
                jax.random.key(1), (2, 64), 0, cfg.vocab_size)
        if cfg.mrope_sections:
            batch["positions"] = jnp.zeros(
                (2, 64, len(cfg.mrope_sections)), jnp.int32)

        def run(p, o):
            p2, o2, m = step(p, o, batch)
            return m["loss"]

        us = _time(run, params, opt, reps=3)
        emit(f"lm_train_step_{arch}-smoke", us, float("nan"))


def run_tune(args) -> None:
    """--tune: sweep the transformation design space, persist best plans."""
    from repro.tune import DEFAULT_SHAPES, Harness, PlanCache, tune

    cache = PlanCache(args.tune_cache).load()
    harness = Harness(reps=args.tune_reps, warmup=1)
    results = []
    print("kernel,shape,dtype,backend,heuristic_us,tuned_us,speedup,plan")
    for kernel, shapes in DEFAULT_SHAPES.items():
        for shape in shapes:
            res = tune(kernel, shape, cache=cache, harness=harness)
            results.append(res.to_dict())
            shape_s = "x".join(map(str, shape))
            plan_s = ";".join(f"{k}={v}" for k, v in sorted(
                res.best.items()))
            print(f"{kernel},{shape_s},{res.dtype},{res.backend},"
                  f"{res.heuristic_us:.1f},{res.best_us:.1f},"
                  f"{res.speedup:.2f},{plan_s}", flush=True)
    path = cache.save()
    out = Path(args.tune_out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(
        {"cache": str(path), "results": results}, indent=2) + "\n")
    print(f"# plan cache: {path} ({len(cache)} entries)")
    print(f"# report: {out}")


def run_train_grad(args) -> None:
    """--train-grad: attention-backward timing rows, fused vs reference.

    Times ``flash_attention_bwd`` on fixed (q, k, v, o, lse, do) cells for
    both schedules: the dense reference VJP (level T1 — the stash
    schedule) and the fused recompute Pallas kernels (level T3).  On this
    CPU host the fused column times the interpret-mode emulator, so the
    rows order the *lowerings*; re-run on TPU for real trajectories.
    """
    from repro.core.plan import Level
    from repro.kernels.attention import flash_attention, flash_attention_bwd

    rows = []
    print("shape,dtype,reference_us,fused_us,ratio")
    for shape in ((1, 2, 128, 64), (1, 4, 256, 64)):
        for dtype in (jnp.float32, jnp.bfloat16):
            ks = jax.random.split(jax.random.key(0), 4)
            q, k, v = (jax.random.normal(kk, shape, dtype) for kk in ks[:3])
            do = jax.random.normal(ks[3], shape, jnp.float32)
            o, lse = flash_attention(q, k, v, level=Level.T1_PIPELINED,
                                     plan=None, return_residuals=True)
            ref_us = _time(lambda: flash_attention_bwd(
                q, k, v, o, lse, do, plan={"level": 1}), reps=3)
            s = shape[2]
            fused_us = _time(lambda: flash_attention_bwd(
                q, k, v, o, lse, do,
                plan={"level": 3, "block_q": min(128, s),
                      "block_kv": min(128, s)}), reps=3)
            shape_s = "x".join(map(str, shape))
            dname = jnp.dtype(dtype).name
            print(f"{shape_s},{dname},{ref_us:.1f},{fused_us:.1f},"
                  f"{ref_us / max(fused_us, 1e-9):.3f}", flush=True)
            rows.append({"shape": list(shape), "dtype": dname,
                         "reference_us": round(ref_us, 1),
                         "fused_us": round(fused_us, 1),
                         "backend": jax.default_backend()})
    out = Path(args.train_grad_out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"rows": rows}, indent=2) + "\n")
    print(f"# report: {out}")


def run_prefill(args) -> None:
    """--prefill: ragged multi-token prefill attention timing rows.

    Times ``prefill_attention`` on fixed paged-KV cells for both
    lowerings: the gather-and-mask reference (level T1) and the Pallas
    ragged kernel (level T3, heuristic KV-tile geometry).  On this CPU
    host the kernel column times the interpret-mode emulator, so the rows
    order the *lowerings*; re-run on TPU for real trajectories.
    """
    from repro.kernels import registry
    from repro.kernels.attention import prefill_attention

    spec = registry.get("prefill_attention")
    rows = []
    print("shape,dtype,reference_us,kernel_us,ratio")
    for shape in spec.tune.default_shapes:
        for dtype in (jnp.float32, jnp.bfloat16):
            args_ = spec.tune.make_inputs(tuple(shape), dtype)
            ref_us = _time(lambda: prefill_attention(
                *args_, plan={"level": 1}), reps=3)
            kern_us = _time(lambda: prefill_attention(
                *args_, plan={"level": 3}), reps=3)
            shape_s = "x".join(map(str, shape))
            dname = jnp.dtype(dtype).name
            print(f"{shape_s},{dname},{ref_us:.1f},{kern_us:.1f},"
                  f"{ref_us / max(kern_us, 1e-9):.3f}", flush=True)
            rows.append({"shape": list(shape), "dtype": dname,
                         "reference_us": round(ref_us, 1),
                         "kernel_us": round(kern_us, 1),
                         "backend": jax.default_backend()})
    out = Path(args.prefill_out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"rows": rows}, indent=2) + "\n")
    print(f"# report: {out}")


def _merge_serve_rows(path, new_rows) -> None:
    """Merge rows into the serve report keyed by (arch, cache, schedule),
    so --serve and --serve-continuous co-own one file: a re-run replaces
    its own keys and leaves the other mode's rows alone.  Legacy rows
    without a schedule field are the phased (--serve) rows."""
    def key(r):
        return (r.get("arch"), r.get("cache"), r.get("schedule", "phased"))
    out = Path(path)
    rows = []
    if out.exists():
        rows = json.loads(out.read_text()).get("rows", [])
    fresh = {key(r) for r in new_rows}
    rows = [r for r in rows if key(r) not in fresh] + new_rows
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"rows": rows}, indent=2) + "\n")
    print(f"# report: {out}")


def run_serve(args) -> None:
    """--serve: decode-throughput rows for the serving runtime.

    Times a small smoke-config workload on this host for both cache
    layouts: ``paged`` separates the prefill phase (chunked, one page per
    forward) from the decode phase (batched ragged steps through
    ``dispatch.decode_attention``); ``dense`` teacher-forces prompts
    through the decode step, so its tok/s column absorbs the prompt
    replay — the comparison the paged refactor exists to win.  Absolute
    numbers are CPU-interpret numbers; the row structure is what carries
    to TPU.
    """
    import numpy as np

    from repro.configs import get_arch
    from repro.core.memory import DtypePolicy
    from repro.kernels import dispatch
    from repro.launch.serve import PagedScheduler, Request, Server
    from repro.models.transformer import ExecOptions, Model
    from repro.tune.cache import preload as preload_tuned

    preload_tuned()
    cfg = get_arch(args.serve_arch).smoke()
    cfg = dataclasses.replace(cfg, dispatch=args.serve_dispatch)
    model = Model(cfg, dt=DtypePolicy(param=jnp.bfloat16),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    slots, prompt_len, max_new, max_len = 2, 12, 8, 64

    def requests():
        rng = np.random.default_rng(0)
        return [Request(i, rng.integers(0, cfg.vocab_size, prompt_len),
                        max_new) for i in range(slots)]

    def warmup_request():
        rng = np.random.default_rng(99)
        return Request(-1, rng.integers(0, cfg.vocab_size, 4), 2)

    rows = []
    print("arch,cache,dispatch,slots,page_size,"
          "prefill_tok_s,decode_tok_s,decode_route")
    for kind in ("paged", "dense"):
        dispatch.reset_stats()
        if kind == "paged":
            sched = PagedScheduler(model, params, slots=slots,
                                   max_len=max_len,
                                   page_size=args.serve_page_size,
                                   log=None)
            # warmup: compile prefill_step_paged + decode_step on this
            # scheduler instance outside the timed regions
            sched.run([warmup_request()])
            sched.prefill_tokens = sched.decode_tokens = 0
            sched.decode_steps = 0
            reqs = requests()
            t0 = time.perf_counter()
            for i, r in enumerate(reqs):
                if not sched.try_admit(r, i):
                    raise RuntimeError(f"admission failed for request {i}")
            t_prefill = time.perf_counter() - t0
            t0 = time.perf_counter()
            done = sched.run([])
            t_decode = time.perf_counter() - t0
            page = sched.page
            prefill_tok_s = sched.prefill_tokens / max(t_prefill, 1e-9)
            decode_tok_s = sched.decode_tokens / max(t_decode, 1e-9)
        else:
            server = Server(model, params, slots=slots, max_len=max_len,
                            log=None)
            server.run([warmup_request()])     # compile decode_step
            reqs = requests()
            t0 = time.perf_counter()
            done = server.run(reqs)
            t_total = time.perf_counter() - t0
            page = 0
            prefill_tok_s = None           # prompts replay through decode
            decode_tok_s = sum(len(r.out) for r in done) \
                / max(t_total, 1e-9)
        if len(done) != slots:
            raise RuntimeError(
                f"{kind} serve finished {len(done)}/{slots} requests")
        routes = dispatch.stats()
        # dense never calls dispatch.decode_attention at all — report n/a
        # rather than conflating "not exercised" with "reference taken"
        if kind == "dense":
            decode_route = "n/a"
        else:
            decode_route = ("kernel" if routes.get(("decode_attention",
                                                    "kernel"), 0) else
                            "reference")
        row = {"arch": cfg.name, "cache": kind, "schedule": "phased",
               "dispatch": args.serve_dispatch, "slots": slots,
               "page_size": page,
               "prefill_tok_s": None if prefill_tok_s is None
               else round(prefill_tok_s, 2),
               "decode_tok_s": round(decode_tok_s, 2),
               "decode_route": decode_route,
               "backend": jax.default_backend()}
        rows.append(row)
        pf = "" if prefill_tok_s is None else f"{prefill_tok_s:.2f}"
        print(f"{cfg.name},{kind},{args.serve_dispatch},{slots},{page},"
              f"{pf},{decode_tok_s:.2f},{decode_route}", flush=True)
    _merge_serve_rows(args.serve_out, rows)


def run_serve_continuous(args) -> None:
    """--serve-continuous: continuous-batching engine rows vs the static
    run-to-completion schedule.

    Drives the layered engine (loadgen -> policy -> executor -> metrics)
    on a seeded request stream and reports the serving-latency trio the
    engine exists to improve: TTFT p50/p99, per-token latency p50/p99
    (both on the wall virtual clock, in seconds), and decode throughput.
    A second leg replays the SAME stream through ``PagedScheduler.run``
    (schedule=static) so the rows carry a like-for-like total-throughput
    comparison; the continuous row's ``max_prefill_batch`` +
    ``prefill_route`` prove a multi-slot (B > 1) batched
    ``prefill_attention`` kernel forward actually fired.  Absolute
    numbers are CPU-interpret numbers; the row structure carries to TPU.
    """
    import numpy as np

    from repro.configs import get_arch
    from repro.core.memory import DtypePolicy
    from repro.kernels import dispatch
    from repro.launch.engine import ContinuousEngine
    from repro.launch.loadgen import Request, poisson_stream
    from repro.launch.serve import PagedScheduler
    from repro.models.transformer import ExecOptions, Model
    from repro.tune.cache import preload as preload_tuned

    preload_tuned()
    cfg = get_arch(args.serve_arch).smoke()
    cfg = dataclasses.replace(cfg, dispatch=args.serve_dispatch)
    model = Model(cfg, dt=DtypePolicy(param=jnp.bfloat16),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    slots, prompt_len, max_new, max_len = 2, 12, 8, 64
    n_req, rate = args.serve_requests, args.serve_rate

    def stream():
        return poisson_stream(n_req, rate=rate, vocab_size=cfg.vocab_size,
                              prompt_len=prompt_len, max_new=max_new,
                              seed=0)

    def r6(v):
        return None if v is None else round(v, 6)

    def route(routes, op):
        return "kernel" if routes.get((op, "kernel"), 0) else "reference"

    # -------------------------------------------------- continuous leg
    sched = PagedScheduler(model, params, slots=slots, max_len=max_len,
                           page_size=args.serve_page_size, log=None)
    engine = ContinuousEngine(sched, token_budget=args.serve_token_budget,
                              clock="wall", log=None)
    dispatch.reset_stats()       # trace-time counters: count from warmup
    engine.warmup()
    t0 = time.perf_counter()
    done = engine.run(stream())
    dt = time.perf_counter() - t0
    if len(done) != n_req:
        raise RuntimeError(
            f"continuous serve finished {len(done)}/{n_req} requests")
    s = engine.metrics.summary()
    ex = engine.executor
    routes = dispatch.stats()
    total_new = sum(len(r.out) for r in done)
    cont_tok_s = total_new / max(dt, 1e-9)
    cont_row = {
        "arch": cfg.name, "cache": "paged", "schedule": "continuous",
        "dispatch": args.serve_dispatch, "slots": slots,
        "page_size": sched.page, "requests": n_req, "rate": rate,
        "token_budget": engine.policy.token_budget,
        "decode_tok_s": round(
            sched.decode_tokens / max(ex.t_decode, 1e-9), 2),
        "total_tok_s": round(cont_tok_s, 2),
        "ttft_p50_s": r6(s["ttft_p50"]),
        "ttft_p99_s": r6(s["ttft_p99"]),
        "tok_latency_p50_s": r6(s["tok_latency_p50"]),
        "tok_latency_p99_s": r6(s["tok_latency_p99"]),
        "max_prefill_batch": ex.max_prefill_batch,
        "prefill_route": route(routes, "prefill_attention"),
        "decode_route": route(routes, "decode_attention"),
        "rejected": sched.rejected,
        "backend": jax.default_backend(),
    }

    # ------------------------------------------------------ static leg
    sched2 = PagedScheduler(model, params, slots=slots, max_len=max_len,
                            page_size=args.serve_page_size, log=None)
    rng = np.random.default_rng(99)
    sched2.run([Request(-1, rng.integers(0, cfg.vocab_size, 4), 2)])
    sched2.prefill_tokens = sched2.decode_tokens = sched2.decode_steps = 0
    t0 = time.perf_counter()
    done2 = sched2.run(stream())      # arrivals ignored: admit-at-once
    dt2 = time.perf_counter() - t0
    if len(done2) != n_req:
        raise RuntimeError(
            f"static serve finished {len(done2)}/{n_req} requests")
    static_tok_s = sum(len(r.out) for r in done2) / max(dt2, 1e-9)
    static_row = {
        "arch": cfg.name, "cache": "paged", "schedule": "static",
        "dispatch": args.serve_dispatch, "slots": slots,
        "page_size": sched2.page, "requests": n_req,
        "total_tok_s": round(static_tok_s, 2),
        "backend": jax.default_backend(),
    }
    cont_row["speedup_vs_static"] = round(cont_tok_s / static_tok_s, 3)

    print("arch,schedule,dispatch,total_tok_s,decode_tok_s,"
          "ttft_p99_s,tok_latency_p99_s,max_prefill_batch,prefill_route")
    print(f"{cfg.name},continuous,{args.serve_dispatch},"
          f"{cont_row['total_tok_s']},{cont_row['decode_tok_s']},"
          f"{cont_row['ttft_p99_s']},{cont_row['tok_latency_p99_s']},"
          f"{cont_row['max_prefill_batch']},{cont_row['prefill_route']}",
          flush=True)
    print(f"{cfg.name},static,{args.serve_dispatch},"
          f"{static_row['total_tok_s']},,,,,", flush=True)
    print(f"# continuous/static total throughput: "
          f"{cont_row['speedup_vs_static']:.3f}x")

    # ------------------------------------- shared-prefix scenarios
    # Cross-request KV reuse is the capacity lever prefix sharing exists
    # for, so it gets its own designed workload: a 4-slot engine over an
    # OVERSUBSCRIBED pool (11 pages vs 4 requests x 4 pages resident)
    # where each request is 24 prompt tokens, the sharing ones opening
    # with a common 16-token (2-page) prefix.  Swept at 0/50/95% sharing:
    # the 0% row is the capacity/throughput floor, and check_bench
    # requires the 95% row to beat it on BOTH requests-resident
    # (max_resident) and effective prefill throughput
    # (prompt tokens served / prefill wall time — skipped chunks are
    # served work that cost no compute).
    def share_run(model_, params_, frac, tag, kv_dtype):
        sh = PagedScheduler(model_, params_, slots=4, max_len=64,
                            page_size=8, total_pages=11,
                            prefix_cache=True, log=None)
        eng = ContinuousEngine(sh, clock="wall", log=None)
        eng.warmup()
        reqs = poisson_stream(12, rate=0.0, vocab_size=cfg.vocab_size,
                              prompt_len=24, max_new=8, seed=0,
                              shared_prefix_len=16, shared_frac=frac)
        prompt_tokens = sum(len(r.prompt) for r in reqs)
        t0 = time.perf_counter()
        sdone = eng.run(reqs)
        sdt = time.perf_counter() - t0
        if len(sdone) != 12:
            raise RuntimeError(
                f"{tag} finished {len(sdone)}/12 requests")
        sh.check_page_accounting()
        sm = eng.metrics.summary()
        eff = prompt_tokens / max(eng.executor.t_prefill, 1e-9)
        row = {
            "arch": cfg.name, "cache": "paged", "schedule": tag,
            "dispatch": args.serve_dispatch, "slots": 4, "page_size": 8,
            "total_pages": 11, "requests": 12, "shared_frac": frac,
            "shared_prefix_len": 16, "kv_dtype": kv_dtype,
            "decode_tok_s": round(
                sh.decode_tokens / max(eng.executor.t_decode, 1e-9), 2),
            "total_tok_s": round(
                sum(len(r.out) for r in sdone) / max(sdt, 1e-9), 2),
            "prefill_tok_s_effective": round(eff, 2),
            "max_resident": eng.max_resident,
            "max_resident_kv_bytes": eng.max_resident_kv_bytes,
            "shared_tokens": sh.shared_tokens_total,
            "cow_copies": sh.cow_copies,
            "prefix_hits": sh.prefix.hits,
            "ttft_p50_s": r6(sm["ttft_p50"]),
            "ttft_p99_s": r6(sm["ttft_p99"]),
            "tok_latency_p50_s": r6(sm["tok_latency_p50"]),
            "tok_latency_p99_s": r6(sm["tok_latency_p99"]),
            "rejected": sh.rejected, "truncated": sh.truncated,
            "backend": jax.default_backend(),
        }
        print(f"{cfg.name},{tag},{frac},{row['max_resident']},"
              f"{row['max_resident_kv_bytes']},"
              f"{row['prefill_tok_s_effective']},{row['shared_tokens']},"
              f"{row['cow_copies']},{row['total_tok_s']}", flush=True)
        return row

    share_rows = []
    print("\narch,schedule,shared_frac,max_resident,max_resident_kv_bytes,"
          "prefill_tok_s_effective,shared_tokens,cow_copies,total_tok_s")
    for frac in (0.0, 0.5, 0.95):
        share_rows.append(share_run(
            model, params, frac, f"continuous-share{int(frac * 100)}",
            cfg.kv_dtype or "compute"))
    hi = share_rows[-1]
    lo = share_rows[0]
    print(f"# share95/share0: resident {lo['max_resident']} -> "
          f"{hi['max_resident']}, effective prefill "
          f"{hi['prefill_tok_s_effective'] / max(lo['prefill_tok_s_effective'], 1e-9):.2f}x")

    # ------------------------------------- quantized-KV scenarios
    # Same oversubscribed shared-prefix workload with the pool stored as
    # int8 (per-(page, kv-head) f32 scales, in-kernel dequant): the
    # capacity lever is BYTES, so the rows carry max_resident_kv_bytes
    # and check_bench gates int8-share0 strictly below share0 on bytes
    # while holding decode throughput within tolerance.  Params are the
    # same tree — kv_dtype only changes cache storage, which is exactly
    # why the rows are comparable.
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    model8 = Model(cfg8, dt=DtypePolicy(param=jnp.bfloat16),
                   opts=ExecOptions(mode="run"))
    int8_rows = [share_run(model8, params, frac,
                           f"continuous-int8-share{int(frac * 100)}", "int8")
                 for frac in (0.0, 0.95)]
    b0, b8 = share_rows[0], int8_rows[0]
    print(f"# int8-share0/share0: kv bytes {b0['max_resident_kv_bytes']} "
          f"-> {b8['max_resident_kv_bytes']} "
          f"({b8['max_resident_kv_bytes'] / max(b0['max_resident_kv_bytes'], 1): .2f}x), "
          f"decode {b0['decode_tok_s']} -> {b8['decode_tok_s']} tok/s")
    _merge_serve_rows(args.serve_out,
                      [cont_row, static_row] + share_rows + int8_rows)


def run_serve_speculative(args) -> None:
    """--serve-speculative: speculative-decoding rows (continuous-spec*).

    Differential-first, like the TP rows: the headline field is
    ``tokens_match_baseline`` — greedy streams from the speculative
    engine compared token-for-token against a plain continuous engine on
    the identically regenerated seeded stream.  One baseline leg, then
    one leg per drafter (model-free n-gram; small-model early-exit
    sibling sharing the target's leading layers), each on a FRESH
    scheduler so no KV state leaks between legs.  Rows carry decode
    throughput vs baseline, the acceptance rate, and emitted tokens per
    verify step; ``scripts/check_bench.py compare_spec`` gates on them
    without a stored-baseline file.  Absolute numbers are CPU-interpret
    numbers — on real accelerators the verify step's extra width is
    nearly free next to its weight traffic (the paper's §2.1.4
    cross-input pipelining argument), which is the speedup lever.
    """
    from repro.configs import get_arch
    from repro.core.memory import DtypePolicy
    from repro.launch.engine import ContinuousEngine
    from repro.launch.loadgen import poisson_stream
    from repro.launch.serve import PagedScheduler
    from repro.launch.speculative import make_drafter
    from repro.models.transformer import ExecOptions, Model
    from repro.tune.cache import preload as preload_tuned

    preload_tuned()
    cfg = get_arch(args.serve_arch).smoke()
    cfg = dataclasses.replace(cfg, dispatch=args.serve_dispatch)
    model = Model(cfg, dt=DtypePolicy(param=jnp.bfloat16),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    slots, prompt_len, max_new, max_len = 2, 12, 8, 64
    n_req, draft = args.serve_requests, args.serve_draft_tokens

    def leg(drafter):
        sched = PagedScheduler(model, params, slots=slots, max_len=max_len,
                               page_size=args.serve_page_size, log=None)
        eng = ContinuousEngine(sched, clock="wall", drafter=drafter,
                               log=None)
        eng.warmup()
        reqs = poisson_stream(n_req, rate=args.serve_rate,
                              vocab_size=cfg.vocab_size,
                              prompt_len=prompt_len, max_new=max_new,
                              seed=0)
        done = eng.run(reqs)
        if len(done) != n_req:
            raise RuntimeError(
                f"speculative serve finished {len(done)}/{n_req} requests")
        streams = {r.rid: list(r.out) for r in done}
        emitted = (sched.spec_emitted if drafter is not None
                   else sched.decode_tokens)
        return streams, round(emitted / max(eng.executor.t_decode, 1e-9),
                              2), sched

    base_streams, base_tok_s, _ = leg(None)
    rows = []
    print("arch,schedule,drafter,decode_tok_s,baseline_decode_tok_s,"
          "accept_rate,toks_per_step,tokens_match_baseline")
    for kind in ("ngram", "model"):
        drafter = make_drafter(
            kind, cfg, max_draft=draft,
            dt=DtypePolicy(param=jnp.bfloat16), rng_key=jax.random.key(0),
            pad_to=max_len + draft, batch_pad=slots)
        streams, tok_s, sched = leg(drafter)
        match = streams == base_streams
        rows.append({
            "arch": cfg.name, "cache": "paged",
            "schedule": f"continuous-spec{kind}",
            "dispatch": args.serve_dispatch, "slots": slots,
            "page_size": sched.page, "requests": n_req,
            "drafter": kind, "draft_tokens": draft,
            "decode_tok_s": tok_s,
            "baseline_decode_tok_s": base_tok_s,
            "speedup_vs_baseline": round(tok_s / max(base_tok_s, 1e-9), 3),
            "acceptance_rate": round(
                sched.spec_accepted / max(sched.spec_drafted, 1), 4),
            "accepted_per_step": round(
                sched.spec_emitted / max(sched.verify_steps, 1), 3),
            "verify_steps": sched.verify_steps,
            "tokens_match_baseline": match,
            "backend": jax.default_backend(),
        })
        r = rows[-1]
        print(f"{cfg.name},{r['schedule']},{kind},{tok_s},{base_tok_s},"
              f"{r['acceptance_rate']},{r['accepted_per_step']},{match}",
              flush=True)
        if not match:
            raise RuntimeError(
                f"{kind} speculative streams diverged from baseline")
    print(f"# spec vs baseline decode: "
          f"ngram {rows[0]['speedup_vs_baseline']:.3f}x "
          f"(accept {rows[0]['acceptance_rate']:.2f}), "
          f"model {rows[1]['speedup_vs_baseline']:.3f}x "
          f"(accept {rows[1]['acceptance_rate']:.2f})")
    _merge_serve_rows(args.serve_out, rows)


def run_serve_sharded(args) -> None:
    """--serve-sharded: tensor-parallel serving rows (continuous-tp{1,2}).

    Differential-first: every row's headline field is
    ``tokens_match_oracle`` — the sharded continuous engine's greedy
    streams compared token-for-token against the unsharded single-device
    oracle on the same seeded request stream.  tp=1 runs on a degenerate
    1-device mesh (must be BIT-identical); tp=2 runs when >= 2 devices are
    visible (``XLA_FLAGS=--xla_force_host_platform_device_count=2`` on
    CPU) and additionally carries ``kernels_match_reference`` (the same
    sharded mesh with ``--dispatch reference`` produces the same tokens —
    the collectives are dispatch-route-invariant) and ``tp_ops_in_region``
    (distinct ops the tp route counters saw inside the shard_map body).
    ``scripts/check_bench.py compare_tp`` gates these fields baseline-free.
    Throughput columns are CPU-interpret numbers; the verdicts carry.
    """
    from repro.configs import get_arch
    from repro.core.memory import DtypePolicy
    from repro.kernels import dispatch, registry
    from repro.launch.engine import ContinuousEngine
    from repro.launch.loadgen import poisson_stream
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import PagedScheduler
    from repro.models.transformer import ExecOptions, Model
    from repro.runtime import tp as tp_mod
    from repro.tune.cache import preload as preload_tuned

    preload_tuned()
    base_cfg = get_arch(args.serve_arch).smoke()
    slots, prompt_len, max_new, max_len = 2, 12, 8, 64
    n_req = args.serve_requests

    def build(dispatch_policy):
        cfg = dataclasses.replace(base_cfg, dispatch=dispatch_policy)
        model = Model(cfg, dt=DtypePolicy(param=jnp.bfloat16),
                      opts=ExecOptions(mode="run"))
        return cfg, model, model.init(jax.random.key(0))

    def stream(vocab):
        return poisson_stream(n_req, rate=0.0, vocab_size=vocab,
                              prompt_len=prompt_len, max_new=max_new,
                              seed=0)

    def drive(model, params, mesh):
        """Run the continuous engine once; return (streams, row core)."""
        sched = PagedScheduler(model, params, slots=slots, max_len=max_len,
                               page_size=args.serve_page_size, mesh=mesh,
                               log=None)
        engine = ContinuousEngine(sched, clock="wall", log=None)
        dispatch.reset_stats()
        engine.warmup()
        t0 = time.perf_counter()
        done = engine.run(stream(model.cfg.vocab_size))
        dt = time.perf_counter() - t0
        if len(done) != n_req:
            raise RuntimeError(
                f"sharded serve finished {len(done)}/{n_req} requests")
        streams = [list(r.out)
                   for r in sorted(done, key=lambda r: r.rid)]
        core = {
            "decode_tok_s": round(
                sched.decode_tokens
                / max(engine.executor.t_decode, 1e-9), 2),
            "total_tok_s": round(
                sum(len(s) for s in streams) / max(dt, 1e-9), 2),
            "tp_ops_in_region": len({op for op, _
                                     in registry.tp_stats()}),
        }
        return streams, core

    cfgk, modelk, paramsk = build(args.serve_dispatch)
    n_dev = len(jax.devices())
    print(f"# {cfgk.name}: n_heads={cfgk.n_heads} "
          f"n_kv_heads={cfgk.n_kv_heads}, {n_dev} device(s) visible")
    oracle, _ = drive(modelk, paramsk, None)

    rows = []
    print("arch,schedule,tp,dispatch,tokens_match_oracle,"
          "kernels_match_reference,tp_ops_in_region,total_tok_s")
    tps = [1] + ([2] if n_dev >= 2 else [])
    if n_dev < 2:
        print("# only 1 device visible: skipping the tp=2 row (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    for tp in tps:
        mesh = make_serving_mesh(tp)
        streams, core = drive(modelk, paramsk, mesh)
        row = {
            "arch": cfgk.name, "cache": "paged",
            "schedule": f"continuous-tp{tp}",
            "dispatch": args.serve_dispatch, "slots": slots,
            "page_size": args.serve_page_size, "requests": n_req,
            "tp": tp, "devices": n_dev,
            "kv_sharded": tp_mod.kv_sharded(cfgk, tp),
            "tokens_match_oracle": streams == oracle,
            "backend": jax.default_backend(),
            **core,
        }
        if tp >= 2 and args.serve_dispatch != "reference":
            # route-invariance on the mesh itself: reference lowerings
            # under the SAME shard_map + collectives give the same tokens
            _, modelr, paramsr = build("reference")
            ref_streams, _ = drive(modelr, paramsr, mesh)
            row["kernels_match_reference"] = streams == ref_streams
        rows.append(row)
        print(f"{cfgk.name},continuous-tp{tp},{tp},{args.serve_dispatch},"
              f"{row['tokens_match_oracle']},"
              f"{row.get('kernels_match_reference', '')},"
              f"{row['tp_ops_in_region']},{row['total_tok_s']}",
              flush=True)
    _merge_serve_rows(args.serve_out, rows)


def run_progression() -> None:
    print("name,us_per_call,derived")
    bench_stencil()
    bench_matmul()
    bench_nbody()
    bench_histogram()
    bench_flash_attention()
    bench_lm_train_step()
    # staged-progression summary (the Fig. 7 shape): cumulative derived
    # speedup of each stage over the naive one
    print("\n# derived TPU staged speedups (paper Fig. 7 analogue)")
    by = {}
    for name, us, derived in ROWS:
        for kern in ("stencil", "matmul", "nbody"):
            if name.startswith(kern):
                by.setdefault(kern, []).append((name, derived))
    for kern, stages in by.items():
        base = stages[0][1]
        prog = " | ".join(f"{n.split('_', 1)[1]}: {base / d:,.0f}x"
                          for n, d in stages)
        print(f"# {kern}: {prog}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tune", action="store_true",
                    help="sweep the repro.tune design space instead of the "
                         "Fig. 7 progression")
    ap.add_argument("--tune-cache", default=None,
                    help="plan-cache JSON path (default: "
                         "results/tuned_plans.json or $REPRO_TUNE_CACHE)")
    ap.add_argument("--tune-out", default="results/BENCH_tune.json",
                    help="tuned-vs-heuristic report JSON path")
    ap.add_argument("--tune-reps", type=int, default=3,
                    help="timing reps per candidate (median taken)")
    ap.add_argument("--train-grad", action="store_true",
                    help="attention-backward timing rows "
                         "(fused recompute kernel vs reference VJP)")
    ap.add_argument("--train-grad-out",
                    default="results/BENCH_train_grad.json",
                    help="backward-timing report JSON path")
    ap.add_argument("--prefill", action="store_true",
                    help="ragged prefill-attention timing rows "
                         "(Pallas kernel vs gather-and-mask reference)")
    ap.add_argument("--prefill-out", default="results/BENCH_prefill.json",
                    help="prefill-timing report JSON path")
    ap.add_argument("--serve", action="store_true",
                    help="serving-runtime decode-throughput rows "
                         "(paged vs dense cache)")
    ap.add_argument("--serve-arch", default="gemma-2b")
    ap.add_argument("--serve-dispatch", default="auto",
                    choices=("auto", "kernels", "reference"))
    ap.add_argument("--serve-page-size", type=int, default=8,
                    help="paged layout page size for the smoke workload "
                         "(0 = tuned-plan pick)")
    ap.add_argument("--serve-out", default="results/BENCH_serve.json",
                    help="serve-throughput report JSON path")
    ap.add_argument("--serve-continuous", action="store_true",
                    help="continuous-batching engine rows (TTFT + "
                         "per-token latency percentiles) vs the static "
                         "run-to-completion schedule")
    ap.add_argument("--serve-requests", type=int, default=6,
                    help="continuous workload size (requests)")
    ap.add_argument("--serve-rate", type=float, default=0.0,
                    help="continuous Poisson arrival rate "
                         "(0 = burst at t=0, deterministic)")
    ap.add_argument("--serve-token-budget", type=int, default=0,
                    help="continuous per-iteration token budget "
                         "(0 = slots x page_size)")
    ap.add_argument("--serve-speculative", action="store_true",
                    help="speculative-decoding rows: continuous-spec{ngram,"
                         "model} vs a plain continuous baseline on the "
                         "same seeded stream (streams must match exactly)")
    ap.add_argument("--serve-draft-tokens", type=int, default=3,
                    help="draft tokens per verify step (window = draft+1)")
    ap.add_argument("--serve-sharded", action="store_true",
                    help="tensor-parallel serving rows: continuous-tp1 "
                         "(degenerate mesh, bit-identical) and, with >= 2 "
                         "visible devices, continuous-tp2 (sharded heads + "
                         "KV pools vs the single-device oracle)")
    args = ap.parse_args(argv)
    if args.tune:
        run_tune(args)
    elif args.train_grad:
        run_train_grad(args)
    elif args.prefill:
        run_prefill(args)
    elif args.serve:
        run_serve(args)
    elif args.serve_continuous:
        run_serve_continuous(args)
    elif args.serve_speculative:
        run_serve_speculative(args)
    elif args.serve_sharded:
        run_serve_sharded(args)
    else:
        run_progression()


if __name__ == "__main__":
    main()
