"""Host data pipeline: deterministic synthetic LM shards with prefetch.

Paper tie-ins:
* memory access extraction (§4.1): batch generation runs on a background
  thread, decoupled from the accelerator step loop — compute never waits on
  the "memory module";
* memory oversubscription (§4.2): the prefetch queue holds ``prefetch``
  batches ahead of the consumer;
* striping (§4.3): each host generates only its own shard of the global
  batch (deterministic in (seed, step, host) so restarts resume exactly).

The synthetic stream is a Zipf-ish token mixture with a Markov flavor — it
has enough learnable structure that loss decreases (used by the end-to-end
example to demonstrate real training), while being fully reproducible
offline.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2          # §4.2 oversubscription depth
    input_mode: str = "tokens"
    d_model: int = 0           # for embeddings mode

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Deterministic synthetic language modeling stream.

    Token t+1 = (a * token_t + drift) mod V with noise — a learnable
    first-order structure.  Every (seed, step, host, row) is independent,
    so any host can regenerate any batch (elastic restarts, §fault
    tolerance)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 1009 + cfg.host_id)
        b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab_size
        start = rng.integers(0, v, size=(b, 1))
        mult = 31 if v > 31 else 3
        toks = [start]
        for _ in range(s):
            nxt = (toks[-1] * mult + 7) % v
            noise = rng.integers(0, v, size=(b, 1))
            take_noise = rng.random((b, 1)) < 0.1
            toks.append(np.where(take_noise, noise, nxt))
        seq = np.concatenate(toks, axis=1).astype(np.int32)  # (b, s+1)
        batch = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if cfg.input_mode == "embeddings":
            emb = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
            batch = {"embeddings": emb, "labels": seq[:, 1:]}
        return batch


def make_pipeline(cfg: DataConfig, start_step: int = 0,
                  stop_event: Optional[threading.Event] = None
                  ) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator (§4.1 + §4.2)."""
    src = SyntheticLM(cfg)
    q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
    stop = stop_event or threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(src.batch_at(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    return gen()
