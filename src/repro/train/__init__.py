from .steps import TrainStepConfig, make_train_step, make_serve_step  # noqa: F401
