"""Step functions: training (grad + AdamW) and serving (one-token decode).

Pipelined loop fusion (§2.4): the forward, backward, gradient-clip and
optimizer update all live in ONE jit — one XLA "pipeline" with a single
fill/drain, no host round-trips between phases.  Microbatch gradient
accumulation (when enabled) is a scan whose per-microbatch reduce-scatter
overlaps the next microbatch's compute under GSPMD — the §3.3 streaming
pattern at step granularity.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.transformer import Model
from ..optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update
from ..optim.compress import CompressorConfig, compress_gradients


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    compress: Optional[CompressorConfig] = None
    # NamedSharding tree matching the param structure.  Constraining the
    # gradients to the parameter (FSDP-striped §4.3) layout right after the
    # backward pass lets GSPMD reduce-scatter per layer inside the scan
    # instead of materializing the full-depth unsharded f32 gradient stack
    # (which for a 67B model is ~270 GB/device).
    grad_shardings: Optional[Any] = None


def make_train_step(model: Model, cfg: TrainStepConfig = TrainStepConfig()
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    (The optional error-feedback residual for gradient compression rides
    inside opt_state as ``opt_state[1]`` when compression is on.)
    """

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if cfg.compress is not None:
            opt_state, residual = opt_state
        if cfg.microbatches > 1:
            def split(x):
                b = x.shape[0]
                mb = cfg.microbatches
                return x.reshape((mb, b // mb) + x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                if cfg.grad_shardings is not None:
                    g = jax.lax.with_sharding_constraint(
                        g, cfg.grad_shardings)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), metrics

            # accumulate in the gradient's own dtype: f32-master archs get
            # f32 accumulators; bf16-param archs (the 1T MoE) accumulate in
            # bf16 — type demotion §4.4, without which the accumulator alone
            # is 16 GiB/device.
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            if cfg.grad_shardings is not None:
                g0 = jax.lax.with_sharding_constraint(g0,
                                                      cfg.grad_shardings)
            (grads, loss_sum), all_metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbatch)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], all_metrics)
            metrics["loss"] = loss_sum / cfg.microbatches
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if cfg.grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads,
                                                     cfg.grad_shardings)
        if cfg.compress is not None:
            grads, residual = compress_gradients(grads, residual,
                                                 cfg.compress)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, cfg.opt)
        metrics = {**metrics, **opt_metrics}
        if cfg.compress is not None:
            new_opt = (new_opt, residual)
        return new_params, new_opt, metrics

    return train_step


def init_train_state(model: Model, cfg: TrainStepConfig, rng: jax.Array
                     ) -> Tuple[Any, Any]:
    params = model.init(rng)
    opt = adamw_init(params, cfg.opt)
    if cfg.compress is not None:
        from ..optim.compress import init_residual
        opt = (opt, init_residual(params))
    return params, opt


def abstract_train_state(model: Model, cfg: TrainStepConfig):
    """ShapeDtypeStructs for (params, opt_state) — dry-run currency."""
    def build():
        params = model.init(jax.random.key(0))
        opt = adamw_init(params, cfg.opt)
        if cfg.compress is not None:
            from ..optim.compress import init_residual
            opt = (opt, init_residual(params))
        return params, opt

    return jax.eval_shape(build)


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, cache, batch, pos) -> (logits, new_cache).

    One new token for every sequence in the batch against the resident
    KV/state cache (delay buffers §2.2)."""

    def serve_step(params, cache, batch, pos):
        return model.decode_step(params, cache, batch, pos)

    return serve_step
