"""Error-feedback int8 gradient compression (type demotion §4.4 on the wire).

Data-parallel gradient all-reduce is the dominant cross-pod collective (the
only inter-pod traffic in the default layout).  Demoting the wire format to
block-scaled int8 cuts that term ~3.9x at the cost of quantization noise;
the classic error-feedback residual keeps SGD/Adam convergence (the
quantization error of step t is added back into the gradient of step t+1,
so bias does not accumulate).

Usage: wrap gradients before the optimizer —
    comp, residual = compress_gradients(grads, residual, cfg)
Under `jax.jit` + sharding, the dequantized gradient is what crosses the
`pod`/`data` axes (GSPMD reduces the int8-roundtripped f32 values); on a
real deployment the quantized payload itself is what the wire carries — the
dry-run's collective-bytes accounting for the compressed variant is
adjusted accordingly in the §Perf hillclimb.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.memory import dequantize_block, quantize_block

Params = Any


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    block: int = 128
    enabled: bool = True
    min_size: int = 4096     # don't compress small leaves (norms, biases)


def init_residual(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_gradients(grads: Params, residual: Params,
                       cfg: CompressorConfig) -> Tuple[Params, Params]:
    """Returns (decompressed-after-compression grads, new residual)."""
    if not cfg.enabled:
        return grads, residual

    def one(g, r):
        g = g.astype(jnp.float32)
        if g.size < cfg.min_size:
            return g, jnp.zeros_like(g)
        corrected = g + r
        qb = quantize_block(corrected, cfg.block)
        deq = dequantize_block(qb)
        return deq, corrected - deq

    out = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_res


def compressed_wire_bytes(n_elems: int, block: int = 128) -> float:
    """Bytes/elt on the wire: int8 payload + f32 scale per block."""
    return n_elems * (1.0 + 4.0 / block)
