"""AdamW from scratch, with an int8-moment variant (type demotion §4.4).

The int8 variant stores both Adam moments as block-scaled int8
(``repro.core.memory.QuantizedBlock``): 1.03 bytes/param per moment instead
of 4.  For the 1T-parameter kimi-k2 arch this is the difference between
14 TB of optimizer+weight state (does not fit 512 x 16 GiB = 8 TiB) and
~4.2 TB (fits) — see EXPERIMENTS.md §Dry-run.  The quantization error is
re-absorbed every step because the moments are re-quantized from the
freshly-updated f32 value (no error accumulation beyond one step's worth);
tests bound the training-trajectory divergence vs f32 moments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.memory import QuantizedBlock, dequantize_block, quantize_block

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    int8_moments: bool = False
    moment_block: int = 128
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    count: jax.Array
    m: Params            # f32 tree, or QuantizedBlock tree
    v: Params


def _q(x: jax.Array, cfg: AdamWConfig):
    return quantize_block(x, cfg.moment_block)


def adamw_init(params: Params, cfg: AdamWConfig) -> AdamWState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _q(z, cfg) if cfg.int8_moments else z

    zeros = jax.tree.map(zero_like, params)
    m = zeros
    v = jax.tree.map(zero_like, params)
    return AdamWState(count=jnp.zeros((), jnp.int32), m=m, v=v)


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio (all traced jnp)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def _is_qb(x) -> bool:
    return isinstance(x, QuantizedBlock)


def adamw_update(grads: Params, state: AdamWState, params: Params,
                 cfg: AdamWConfig) -> Tuple[Params, AdamWState, Dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mf = dequantize_block(m) if _is_qb(m) else m
        vf = dequantize_block(v) if _is_qb(v) else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        mhat = mf / c1
        vhat = vf / c2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on the master weight
        new_p = p.astype(jnp.float32) - lr * (step_ + cfg.weight_decay
                                              * p.astype(jnp.float32))
        new_m = _q(mf, cfg) if _is_qb(m) else mf
        new_v = _q(vf, cfg) if _is_qb(v) else vf
        return new_p.astype(p.dtype), new_m, new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(state.m, is_leaf=_is_qb)[0]
    flat_v = jax.tree.flatten(state.v, is_leaf=_is_qb)[0]
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(count, new_m, new_v), metrics
