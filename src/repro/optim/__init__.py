from .adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from .compress import CompressorConfig, compress_gradients  # noqa: F401
