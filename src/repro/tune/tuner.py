"""Benchmark-driven sweep over the per-kernel design spaces.

``tune()`` runs one (kernel, shape, dtype) cell: enumerate the pruned
candidate plans (``space.py``), time each through the shared harness
(``measure.py``), pick the fastest, and persist it in the ``PlanCache`` so
the ``ops.py`` wrappers pick it up via ``plan="tuned"``.

The candidate list always starts with the exact heuristic plan the kernel
would use on its own, so ``best_us <= heuristic_us`` holds *within the same
sweep's measurements* by construction — the tuned plan is never slower than
the heuristic beyond re-measurement noise.

Kernels are imported lazily inside the input/call builders: ``ops.py``
imports ``tune.cache`` at module level, and keeping this module free of
top-level kernel imports breaks the cycle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .cache import PlanCache, make_key
from .measure import Harness, Measurement
from .space import SPACES, PlanDict

# Default problem shapes per kernel for `benchmarks/run.py --tune` (kept
# interpret-mode-small; on a real TPU pass production shapes instead).
DEFAULT_SHAPES: Dict[str, List[Tuple[int, ...]]] = {
    "matmul": [(256, 256, 256), (384, 128, 512)],
    "stencil": [(128, 256), (256, 512)],
    "attention": [(1, 2, 128, 64), (1, 4, 256, 64)],
    "flash_attention_bwd": [(1, 2, 128, 64), (1, 4, 256, 64)],
    # (slots, heads, n_pages, page_size, head_dim): two page-size layouts
    # so the serve scheduler's page-size pick has entries to compare
    "decode_attention": [(4, 4, 8, 32, 64), (4, 4, 4, 64, 64)],
    "histogram": [(1 << 14, 256), (1 << 16, 256)],
    "nbody": [(256,), (512,)],
}


def _matmul_inputs(shape, dtype):
    m, k, n = shape
    a = jax.random.normal(jax.random.key(0), (m, k), dtype)
    b = jax.random.normal(jax.random.key(1), (k, n), dtype)
    return (a, b)


def _stencil_inputs(shape, dtype):
    return (jax.random.normal(jax.random.key(0), shape, dtype),)


def _attention_inputs(shape, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    return tuple(jax.random.normal(kk, shape, dtype) for kk in ks)


def _flash_bwd_inputs(shape, dtype):
    """Backward cell: run the (reference-level) forward once to build the
    (o, lse) residuals, then time the backward candidates on a fixed
    cotangent — the sweep never times the forward."""
    from ..kernels.attention import flash_attention
    from ..core.plan import Level
    ks = jax.random.split(jax.random.key(0), 4)
    q, k, v = (jax.random.normal(kk, shape, dtype) for kk in ks[:3])
    o, lse = flash_attention(q, k, v, level=Level.T1_PIPELINED, plan=None,
                             return_residuals=True)
    do = jax.random.normal(ks[3], shape, jnp.float32)
    return (q, k, v, o, lse, do)


def _decode_attention_inputs(shape, dtype):
    """Paged ragged-decode cell: a shared pool with page 0 reserved, a
    shuffled (deterministic) page table, and staggered per-slot lengths so
    the sweep times the masked-tail path the serve loop actually runs."""
    b, h, n_pages, page, hd = shape
    hkv = max(1, h // 2)                       # exercise GQA grouping
    pool = 1 + b * n_pages
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    k_pages = jax.random.normal(ks[1], (pool, page, hkv, hd), dtype)
    v_pages = jax.random.normal(ks[2], (pool, page, hkv, hd), dtype)
    perm = jax.random.permutation(jax.random.key(3), pool - 1) + 1
    table = perm[:b * n_pages].reshape(b, n_pages).astype(jnp.int32)
    lengths = ((jnp.arange(b) + 1) * (n_pages * page) // b).astype(jnp.int32)
    return (q, k_pages, v_pages, table, lengths)


def _histogram_inputs(shape, dtype):
    n, n_bins = shape
    return (jax.random.randint(jax.random.key(0), (n,), 0, n_bins, dtype),
            n_bins)


def _nbody_inputs(shape, dtype):
    (n,) = shape
    pos = jax.random.normal(jax.random.key(0), (3, n), dtype)
    mass = jax.random.uniform(jax.random.key(1), (n,), dtype) + 0.1
    return (pos, mass)


def _call_matmul(args, plan):
    from ..kernels.matmul import matmul
    return matmul(*args, plan=plan)


def _call_stencil(args, plan):
    from ..kernels.stencil import jacobi4
    return jacobi4(*args, steps=1, plan=plan)


def _call_attention(args, plan):
    from ..kernels.attention import flash_attention
    return flash_attention(*args, plan=plan)


def _call_flash_bwd(args, plan):
    from ..kernels.attention import flash_attention_bwd
    return flash_attention_bwd(*args, plan=plan)


def _call_decode_attention(args, plan):
    from ..kernels.attention import decode_attention
    return decode_attention(*args, plan=plan)


def _call_histogram(args, plan):
    from ..kernels.histogram import histogram
    return histogram(*args, plan=plan)


def _call_nbody(args, plan):
    from ..kernels.nbody import nbody_accel
    return nbody_accel(*args, plan=plan)


@dataclasses.dataclass(frozen=True)
class KernelTuneSpec:
    name: str
    make_inputs: Callable[[Sequence[int], Any], tuple]
    call: Callable[[tuple, PlanDict], jax.Array]
    default_dtype: Any


KERNELS: Dict[str, KernelTuneSpec] = {
    "matmul": KernelTuneSpec("matmul", _matmul_inputs, _call_matmul,
                             jnp.float32),
    "stencil": KernelTuneSpec("stencil", _stencil_inputs, _call_stencil,
                              jnp.float32),
    "attention": KernelTuneSpec("attention", _attention_inputs,
                                _call_attention, jnp.bfloat16),
    "flash_attention_bwd": KernelTuneSpec("flash_attention_bwd",
                                          _flash_bwd_inputs,
                                          _call_flash_bwd, jnp.bfloat16),
    "decode_attention": KernelTuneSpec("decode_attention",
                                       _decode_attention_inputs,
                                       _call_decode_attention,
                                       jnp.bfloat16),
    "histogram": KernelTuneSpec("histogram", _histogram_inputs,
                                _call_histogram, jnp.int32),
    "nbody": KernelTuneSpec("nbody", _nbody_inputs, _call_nbody,
                            jnp.float32),
}


@dataclasses.dataclass
class TuneResult:
    kernel: str
    shape: Tuple[int, ...]
    dtype: str
    backend: str
    best: PlanDict
    best_us: float
    heuristic_us: float
    rows: List[dict]             # [{"plan": ..., "us": ..., "ok": ...}]

    @property
    def speedup(self) -> float:
        return self.heuristic_us / max(self.best_us, 1e-9)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        d["speedup"] = self.speedup
        d["key"] = make_key(self.kernel, self.shape, self.dtype,
                            self.backend)
        return d


def tune(kernel: str, shape: Sequence[int], *, dtype: Any = None,
         cache: Optional[PlanCache] = None,
         harness: Optional[Harness] = None,
         max_candidates: Optional[int] = None,
         log: Optional[Callable[[str], None]] = None) -> TuneResult:
    """Sweep one (kernel, shape) cell; returns and (optionally) caches the
    winner.  ``harness`` is injectable for deterministic tests."""
    spec = KERNELS[kernel]
    dtype = dtype or spec.default_dtype
    harness = harness or Harness()
    dtype_bytes = jnp.dtype(dtype).itemsize
    space_kw = {} if max_candidates is None \
        else {"max_candidates": max_candidates}
    candidates = SPACES[kernel](tuple(shape), dtype_bytes, **space_kw)
    args = spec.make_inputs(tuple(shape), dtype)

    rows: List[dict] = []
    best_i, best_m = None, None
    for i, cand in enumerate(candidates):
        fn = functools.partial(spec.call, args, cand)
        m: Measurement = harness.measure(fn)
        rows.append({"plan": cand, "us": m.us, "ok": m.ok,
                     **({"error": m.error} if not m.ok else {})})
        if log:
            log(f"  [{kernel} {shape}] {cand} -> "
                f"{m.us:.1f}us{'' if m.ok else ' (FAILED: ' + m.error + ')'}")
        if m.ok and (best_m is None or m.us < best_m.us):
            best_i, best_m = i, m
    if best_m is None:
        raise RuntimeError(
            f"every candidate failed for {kernel} {shape}: {rows}")

    heuristic_us = rows[0]["us"]      # candidate 0 is always the heuristic
    backend = jax.default_backend()
    result = TuneResult(kernel=kernel, shape=tuple(shape),
                        dtype=jnp.dtype(dtype).name, backend=backend,
                        best=candidates[best_i], best_us=best_m.us,
                        heuristic_us=heuristic_us, rows=rows)
    if cache is not None:
        cache.put(kernel, shape, dtype, result.best,
                  us=round(result.best_us, 3),
                  heuristic_us=round(heuristic_us, 3),
                  candidates=len(candidates))
    return result


def tune_all(shapes: Optional[Dict[str, List[Tuple[int, ...]]]] = None, *,
             cache: Optional[PlanCache] = None,
             harness: Optional[Harness] = None,
             max_candidates: Optional[int] = None,
             log: Optional[Callable[[str], None]] = None) -> List[TuneResult]:
    """Sweep every kernel over its shape list (default: DEFAULT_SHAPES)."""
    shapes = shapes or DEFAULT_SHAPES
    results = []
    for kernel, shape_list in shapes.items():
        for shape in shape_list:
            results.append(tune(kernel, shape, cache=cache, harness=harness,
                                max_candidates=max_candidates, log=log))
    return results
