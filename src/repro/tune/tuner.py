"""Benchmark-driven sweep over the per-kernel design spaces.

``tune()`` runs one (kernel, shape, dtype) cell: enumerate the pruned
candidate plans, time each through the shared harness (``measure.py``),
pick the fastest, and persist it in the ``PlanCache`` so the ``ops.py``
wrappers pick it up via ``plan="tuned"``.

The candidate list always starts with the exact heuristic plan the kernel
would use on its own, so ``best_us <= heuristic_us`` holds *within the same
sweep's measurements* by construction — the tuned plan is never slower than
the heuristic beyond re-measurement noise.

Since the registry redesign this module holds NO per-op tables: the
candidate space, input builder, timed call, default dtype, and default
shapes all come from each op's ``TuneSpec`` declaration in
``repro.kernels.registry`` — registering a kernel there is the whole
hookup.  ``KERNELS`` / ``DEFAULT_SHAPES`` remain as module attributes
(resolved lazily through ``__getattr__`` so importing ``repro.tune`` never
eagerly imports the kernel modules).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .cache import PlanCache, make_key
from .measure import Harness, Measurement
from .space import PlanDict


@dataclasses.dataclass(frozen=True)
class KernelTuneSpec:
    """Tuner-facing view of one registered op's ``TuneSpec``."""

    name: str
    make_inputs: Callable[[Sequence[int], Any], tuple]
    call: Callable[[tuple, PlanDict], jax.Array]
    default_dtype: Any
    space: Callable[..., List[PlanDict]]
    default_shapes: Tuple[Tuple[int, ...], ...]


def _registry_kernels() -> Dict[str, KernelTuneSpec]:
    """The tunable-op table, derived from the registry (no parallel copy)."""
    from ..kernels import registry
    out: Dict[str, KernelTuneSpec] = {}
    for name, spec in registry.tunable().items():
        t = spec.tune
        out[name] = KernelTuneSpec(
            name=name, make_inputs=t.make_inputs, call=t.call,
            default_dtype=t.default_dtype, space=t.space,
            default_shapes=tuple(tuple(s) for s in t.default_shapes))
    return out


def _default_shapes() -> Dict[str, List[Tuple[int, ...]]]:
    return {name: list(spec.default_shapes)
            for name, spec in _registry_kernels().items()}


def __getattr__(name: str):
    # lazy: building these imports the kernel op modules, which must not
    # happen as a side effect of ``import repro.tune``
    if name == "KERNELS":
        return _registry_kernels()
    if name == "DEFAULT_SHAPES":
        return _default_shapes()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class TuneResult:
    kernel: str
    shape: Tuple[int, ...]
    dtype: str
    backend: str
    best: PlanDict
    best_us: float
    heuristic_us: float
    rows: List[dict]             # [{"plan": ..., "us": ..., "ok": ...}]

    @property
    def speedup(self) -> float:
        return self.heuristic_us / max(self.best_us, 1e-9)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        d["speedup"] = self.speedup
        d["key"] = make_key(self.kernel, self.shape, self.dtype,
                            self.backend)
        return d


def tune(kernel: str, shape: Sequence[int], *, dtype: Any = None,
         cache: Optional[PlanCache] = None,
         harness: Optional[Harness] = None,
         max_candidates: Optional[int] = None,
         log: Optional[Callable[[str], None]] = None) -> TuneResult:
    """Sweep one (kernel, shape) cell; returns and (optionally) caches the
    winner.  ``harness`` is injectable for deterministic tests."""
    spec = _registry_kernels()[kernel]
    dtype = dtype or spec.default_dtype
    harness = harness or Harness()
    dtype_bytes = jnp.dtype(dtype).itemsize
    space_kw = {} if max_candidates is None \
        else {"max_candidates": max_candidates}
    candidates = spec.space(tuple(shape), dtype_bytes, **space_kw)
    args = spec.make_inputs(tuple(shape), dtype)

    rows: List[dict] = []
    best_i, best_m = None, None
    for i, cand in enumerate(candidates):
        fn = functools.partial(spec.call, args, cand)
        m: Measurement = harness.measure(fn)
        rows.append({"plan": cand, "us": m.us, "ok": m.ok,
                     **({"error": m.error} if not m.ok else {})})
        if log:
            log(f"  [{kernel} {shape}] {cand} -> "
                f"{m.us:.1f}us{'' if m.ok else ' (FAILED: ' + m.error + ')'}")
        if m.ok and (best_m is None or m.us < best_m.us):
            best_i, best_m = i, m
    if best_m is None:
        raise RuntimeError(
            f"every candidate failed for {kernel} {shape}: {rows}")

    heuristic_us = rows[0]["us"]      # candidate 0 is always the heuristic
    backend = jax.default_backend()
    result = TuneResult(kernel=kernel, shape=tuple(shape),
                        dtype=jnp.dtype(dtype).name, backend=backend,
                        best=candidates[best_i], best_us=best_m.us,
                        heuristic_us=heuristic_us, rows=rows)
    if cache is not None:
        cache.put(kernel, shape, dtype, result.best,
                  us=round(result.best_us, 3),
                  heuristic_us=round(heuristic_us, 3),
                  candidates=len(candidates))
    return result


def tune_all(shapes: Optional[Dict[str, List[Tuple[int, ...]]]] = None, *,
             cache: Optional[PlanCache] = None,
             harness: Optional[Harness] = None,
             max_candidates: Optional[int] = None,
             log: Optional[Callable[[str], None]] = None) -> List[TuneResult]:
    """Sweep every registered tunable op over its shape list (default:
    the registry's declared default shapes)."""
    shapes = shapes or _default_shapes()
    results = []
    for kernel, shape_list in shapes.items():
        for shape in shape_list:
            results.append(tune(kernel, shape, cache=cache, harness=harness,
                                max_candidates=max_candidates, log=log))
    return results
