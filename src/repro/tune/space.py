"""Per-kernel candidate enumeration — the paper's design space, pruned.

Each function returns a list of candidate plan dicts for one kernel at one
problem shape.  A plan dict holds the kernel's tunable call kwargs plus an
optional ``"level"`` (paper stage T1→T3, as an int for JSON friendliness).
The paper's transformation parameters map onto the kernels' knobs as:

  tile geometry (§3.4)    -> bm/bn/bk (matmul), block_rows (stencil)
  vector width (§3.1)     -> lane-dim block sizes: block_kv, block (histogram),
                             block_sources (nbody)
  accumulator lanes (§2.1)-> row-dim accumulator tiles: block_q,
                             block_targets
  prefetch depth (§4.2)   -> double-buffering (TilePlanner double_buffer)
  level (T1→T3)           -> reference lowering vs Pallas kernel

Every candidate is feasibility-pruned against the VMEM budget through the
same ``TilePlanner`` working-set arithmetic the heuristics use, so the
tuner never times (or caches) a plan the hardware could not hold.  The
first candidate of every space is the exact heuristic the kernel would
pick on its own — the sweep can therefore only match or beat the default,
which is what makes tuned-vs-heuristic rows meaningful.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

from ..core.model import TPU_V5E, HardwareSpec
from ..core.plan import Level, TUNE_PREFETCH_DEPTHS
from ..core.scaling import TilePlanner

PlanDict = Dict[str, Any]

# modest default: sweeps stay tens-of-candidates even on big shapes
MAX_CANDIDATES = 8


def _dedup(cands: List[PlanDict], cap: int) -> List[PlanDict]:
    seen, out = set(), []
    for c in cands:
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            out.append(c)
        if len(out) >= cap:
            break
    return out


def _divisors(n: int, cands: Sequence[int]) -> List[int]:
    return [c for c in cands if c <= n and n % c == 0]


def matmul_space(shape: Sequence[int], dtype_bytes: int = 4, *,
                 hw: HardwareSpec = TPU_V5E,
                 max_candidates: int = MAX_CANDIDATES) -> List[PlanDict]:
    """shape = (m, k, n) for C[m,n] = A[m,k] @ B[k,n]."""
    m, k, n = shape
    heur = TilePlanner(hw).plan_matmul(m, n, k, in_bytes=dtype_bytes)
    # knob sweep of the heuristic tiles goes BEFORE the tile enumeration so
    # the max_candidates cap can never silently drop a whole axis: prefetch
    # depth 1 (§4.2 off) halves the A/B working set, so it is feasible
    # whenever the double-buffered plan is
    cands: List[PlanDict] = [
        {"level": int(Level.T3_REPLICATED), "bm": heur.bm, "bn": heur.bn,
         "bk": heur.bk, "prefetch_depth": pf}
        for pf in sorted(TUNE_PREFETCH_DEPTHS, reverse=True)
    ]
    cands.append({"level": int(Level.T1_PIPELINED)})
    for plan in TilePlanner(hw).enumerate_matmul(m, n, k,
                                                 in_bytes=dtype_bytes):
        cands.append({"level": int(Level.T3_REPLICATED), "bm": plan.bm,
                      "bn": plan.bn, "bk": plan.bk, "prefetch_depth": 2})
    return _dedup(cands, max_candidates)


def quantized_matmul_space(shape: Sequence[int], dtype_bytes: int = 4, *,
                           hw: HardwareSpec = TPU_V5E,
                           max_candidates: int = MAX_CANDIDATES
                           ) -> List[PlanDict]:
    """shape = (m, k, n) — the int8-weight matmul's own plan namespace.

    Same geometry axes as ``matmul_space``; ``dtype_bytes`` is the
    ACTIVATION width, and charging the int8 B tile at that width is a
    conservative over-estimate, so every emitted candidate stays feasible
    under the plain-matmul VMEM arithmetic the cache reuses."""
    return matmul_space(shape, dtype_bytes, hw=hw,
                        max_candidates=max_candidates)


def stencil_space(shape: Sequence[int], dtype_bytes: int = 4, *,
                  hw: HardwareSpec = TPU_V5E,
                  max_candidates: int = MAX_CANDIDATES) -> List[PlanDict]:
    """shape = (rows, cols)."""
    rows, cols = shape
    planner = TilePlanner(hw)
    feasible = [br for br, _ in planner.enumerate_stencil(
        rows, cols, dtype_bytes=dtype_bytes,
        candidates=_divisors(rows, (8, 16, 32, 64, 128, 256, 512, 1024)))]
    try:
        br_heur, _ = planner.plan_stencil(rows, cols,
                                          dtype_bytes=dtype_bytes)
        br_heur = min(br_heur, rows)
        while rows % br_heur:
            br_heur //= 2
    except ValueError:
        # rows too small for the planner's default candidate grid: the
        # "heuristic" becomes the best divisor-aligned feasible block
        br_heur = feasible[0] if feasible else None
    cands: List[PlanDict] = []
    if br_heur is not None:
        cands.append({"level": int(Level.T3_REPLICATED),
                      "block_rows": br_heur})
    cands.append({"level": int(Level.T1_PIPELINED)})
    for br in sorted(set(feasible), reverse=True):
        cands.append({"level": int(Level.T3_REPLICATED), "block_rows": br})
    return _dedup(cands, max_candidates)


def attention_space(shape: Sequence[int], dtype_bytes: int = 2, *,
                    hw: HardwareSpec = TPU_V5E,
                    max_candidates: int = MAX_CANDIDATES) -> List[PlanDict]:
    """shape = (batch, heads, seq, head_dim)."""
    _, _, s, hd = shape
    budget = TilePlanner(hw).budget
    cands: List[PlanDict] = [
        {"level": int(Level.T3_REPLICATED), "block_q": min(512, s),
         "block_kv": min(512, s)},
        {"level": int(Level.T1_PIPELINED)},
    ]
    for bq in _divisors(s, (512, 256, 128, 64, 32)):
        for bkv in _divisors(s, (512, 256, 128, 64, 32)):
            # working set: Q tile + K/V tiles + logits tile + O/m/l carry,
            # double-buffered KV streams (§4.2)
            vmem = (bq * hd + 2 * 2 * bkv * hd + bq * bkv
                    + 2 * bq * hd) * dtype_bytes
            if vmem <= budget:
                cands.append({"level": int(Level.T3_REPLICATED),
                              "block_q": bq, "block_kv": bkv})
    return _dedup(cands, max_candidates)


def _attn_bwd_vmem(bq: int, bkv: int, hd: int, dtype_bytes: int) -> int:
    """Working set of the fused backward's larger (dKV) kernel: K/V tiles
    resident, Q streamed double-buffered (§4.2) in the input dtype; dO
    streams, the f32 dK/dV accumulators, the recomputed P and dS tiles,
    and the lse/di row carries all in f32."""
    return ((2 * bkv * hd + 2 * 2 * bq * hd) * dtype_bytes
            + (2 * 2 * bq * hd + 2 * bkv * hd + 2 * bq * bkv + 2 * bq) * 4)


def flash_attention_bwd_space(shape: Sequence[int], dtype_bytes: int = 2, *,
                              hw: HardwareSpec = TPU_V5E,
                              max_candidates: int = MAX_CANDIDATES
                              ) -> List[PlanDict]:
    """shape = (batch, heads, seq, head_dim) — same key as the forward.

    The backward design space is the recompute schedule: ``block_q`` /
    ``block_kv`` tile geometry for the dQ/dKV kernels (level T3), or level
    T1 — the dense reference VJP, i.e. the "stash the whole score matrix"
    schedule that wins when (S, S) is small enough to re-derive wholesale.
    The tuner's per-shape level pick IS the recompute-vs-stash threshold.
    """
    _, _, s, hd = shape
    budget = TilePlanner(hw).budget
    cands: List[PlanDict] = [
        {"level": int(Level.T3_REPLICATED), "block_q": min(256, s),
         "block_kv": min(256, s)},
        {"level": int(Level.T1_PIPELINED)},
    ]
    for bq in _divisors(s, (256, 128, 64, 32)):
        for bkv in _divisors(s, (256, 128, 64, 32)):
            if _attn_bwd_vmem(bq, bkv, hd, dtype_bytes) <= budget:
                cands.append({"level": int(Level.T3_REPLICATED),
                              "block_q": bq, "block_kv": bkv})
    return _dedup(cands, max_candidates)


def histogram_space(shape: Sequence[int], dtype_bytes: int = 4, *,
                    hw: HardwareSpec = TPU_V5E,
                    max_candidates: int = MAX_CANDIDATES) -> List[PlanDict]:
    """shape = (n_values, n_bins)."""
    n, n_bins = shape
    budget = TilePlanner(hw).budget
    cands: List[PlanDict] = [
        {"level": int(Level.T3_REPLICATED), "block": min(2048, n)},
        {"level": int(Level.T1_PIPELINED)},
    ]
    for block in _divisors(n, (8192, 4096, 2048, 1024, 512, 256)):
        if block % 8:
            continue
        # one-hot tile (block, n_bins) + value block + bin accumulator
        vmem = (block * n_bins + block) * dtype_bytes + n_bins * 4
        if vmem <= budget:
            cands.append({"level": int(Level.T3_REPLICATED), "block": block})
    return _dedup(cands, max_candidates)


def nbody_space(shape: Sequence[int], dtype_bytes: int = 4, *,
                hw: HardwareSpec = TPU_V5E,
                max_candidates: int = MAX_CANDIDATES) -> List[PlanDict]:
    """shape = (n_bodies,)."""
    (n,) = shape
    budget = TilePlanner(hw).budget
    cands: List[PlanDict] = [
        {"level": int(Level.T3_REPLICATED), "block_targets": min(512, n),
         "block_sources": min(512, n)},
        {"level": int(Level.T1_PIPELINED)},
    ]
    for bt in _divisors(n, (512, 256, 128, 64, 32)):
        for bs in _divisors(n, (512, 256, 128, 64, 32)):
            # resident targets (pos+acc) + streamed source block (pos+mass,
            # double-buffered) + (bt, bs) pairwise distance tile
            vmem = (4 * bt + 2 * 4 * bs + bt * bs) * dtype_bytes
            if vmem <= budget:
                cands.append({"level": int(Level.T3_REPLICATED),
                              "block_targets": bt, "block_sources": bs})
    return _dedup(cands, max_candidates)


def _decode_vmem(grp: int, ppt: int, page: int, hd: int, pf: int,
                 dtype_bytes: int) -> int:
    """Per-grid-step working set of the paged decode kernel: q group tile,
    ``ppt`` K and V page streams (x ``pf`` pipeline buffers, §4.2), the
    (grp, ppt*page) score tile, and the m/l/acc carry."""
    return (grp * hd + 2 * pf * ppt * page * hd + grp * ppt * page
            + 2 * grp * hd) * dtype_bytes


def decode_attention_space(shape: Sequence[int], dtype_bytes: int = 2, *,
                           hw: HardwareSpec = TPU_V5E,
                           max_candidates: int = MAX_CANDIDATES
                           ) -> List[PlanDict]:
    """shape = (slots, heads, n_pages, page_size, head_dim).

    The decode plan space is the serving-cache design space: ``page_size``
    echoes the pool layout the plan was tuned on (the serve scheduler picks
    its layout by comparing tuned entries across page sizes),
    ``pages_per_tile`` is the KV-tile geometry the kernel consumes, and
    ``prefetch_depth`` is the §4.2 pipeline-buffer count the feasibility
    arithmetic charges for.
    """
    from ..kernels.attention.decode import heuristic_pages_per_tile
    b, h, n_pages, page, hd = shape
    budget = TilePlanner(hw).budget
    grp = h                      # conservative GQA bound (grp = h / hkv)
    ppt_h = heuristic_pages_per_tile(n_pages, page)
    cands: List[PlanDict] = [
        {"level": int(Level.T3_REPLICATED), "page_size": page,
         "pages_per_tile": ppt_h, "prefetch_depth": pf}
        for pf in sorted(TUNE_PREFETCH_DEPTHS, reverse=True)
    ]
    # the reference lowering also records the layout it was timed on, so
    # the serve scheduler's page-size pick works whichever level wins
    cands.append({"level": int(Level.T1_PIPELINED), "page_size": page})
    for ppt in (16, 8, 4, 2, 1):
        if ppt > n_pages:
            continue
        for pf in sorted(TUNE_PREFETCH_DEPTHS, reverse=True):
            if _decode_vmem(grp, ppt, page, hd, pf, dtype_bytes) <= budget:
                cands.append({"level": int(Level.T3_REPLICATED),
                              "page_size": page, "pages_per_tile": ppt,
                              "prefetch_depth": pf})
    return _dedup(cands, max_candidates)


def _prefill_vmem(rows: int, ppt: int, page: int, hd: int, pf: int,
                  dtype_bytes: int) -> int:
    """Per-grid-step working set of the paged prefill kernel: the
    (chunk*grp, hd) query tile, ``ppt`` K and V page streams (x ``pf``
    pipeline buffers, §4.2), the (rows, ppt*page) score tile, and the
    m/l/acc carry."""
    return (rows * hd + 2 * pf * ppt * page * hd + rows * ppt * page
            + 2 * rows * hd) * dtype_bytes


def prefill_attention_space(shape: Sequence[int], dtype_bytes: int = 2, *,
                            hw: HardwareSpec = TPU_V5E,
                            max_candidates: int = MAX_CANDIDATES
                            ) -> List[PlanDict]:
    """shape = (slots, chunk, heads, n_pages, page_size, head_dim).

    The prefill plan space mirrors decode's (it is the same paged-KV
    streaming problem with a chunk of query rows instead of one):
    ``page_size`` echoes the pool layout, ``pages_per_tile`` is the
    KV-tile geometry, ``prefetch_depth`` the §4.2 pipeline-buffer count —
    but feasibility charges for the (chunk * grp, ppt * page) score tile,
    which is what separates it from the decode space.
    """
    from ..kernels.attention.decode import heuristic_pages_per_tile
    b, c, h, n_pages, page, hd = shape
    budget = TilePlanner(hw).budget
    rows = c * h                 # conservative GQA bound (grp = h / hkv)
    ppt_h = heuristic_pages_per_tile(n_pages, page)
    cands: List[PlanDict] = [
        {"level": int(Level.T3_REPLICATED), "page_size": page,
         "pages_per_tile": ppt_h, "prefetch_depth": pf}
        for pf in sorted(TUNE_PREFETCH_DEPTHS, reverse=True)
    ]
    cands.append({"level": int(Level.T1_PIPELINED), "page_size": page})
    for ppt in (16, 8, 4, 2, 1):
        if ppt > n_pages:
            continue
        for pf in sorted(TUNE_PREFETCH_DEPTHS, reverse=True):
            if _prefill_vmem(rows, ppt, page, hd, pf, dtype_bytes) <= budget:
                cands.append({"level": int(Level.T3_REPLICATED),
                              "page_size": page, "pages_per_tile": ppt,
                              "prefetch_depth": pf})
    return _dedup(cands, max_candidates)


SPACES = {
    "matmul": matmul_space,
    "quantized_matmul": quantized_matmul_space,
    "stencil": stencil_space,
    "attention": attention_space,
    "flash_attention_bwd": flash_attention_bwd_space,
    "decode_attention": decode_attention_space,
    "prefill_attention": prefill_attention_space,
    "histogram": histogram_space,
    "nbody": nbody_space,
}


# ------------------------------------------------------------- feasibility
def plan_feasible(kernel: str, shape: Sequence[int], plan: PlanDict, *,
                  dtype_bytes: int = 4, hw: HardwareSpec = TPU_V5E) -> bool:
    """Is a tuned plan dict VMEM-feasible for ``shape``?

    The single feasibility oracle behind the cache's nearest-shape lookup:
    a plan tuned on shape A may only be transplanted onto query shape B if
    its working set — computed through the same TilePlanner arithmetic the
    heuristics and the space enumerations use — fits the VMEM budget at B
    (and, where a kernel demands it, its tiles divide B's dims).  Non-T3
    plans (reference lowerings) claim no VMEM and are always feasible.
    """
    level = plan.get("level")
    if level is not None and level != int(Level.T3_REPLICATED):
        return True
    budget = TilePlanner(hw).budget
    if kernel == "quantized_matmul":
        # int8 B only shrinks the working set vs the plain-matmul charge
        return plan_feasible("matmul", shape, plan,
                             dtype_bytes=dtype_bytes, hw=hw)
    if kernel == "matmul":
        m, k, n = shape
        bm = min(plan["bm"], m)
        bn = min(plan["bn"], n)
        bk = min(plan["bk"], k)
        if m % bm or n % bn or k % bk:
            return False      # matmul_pallas rejects ragged grids
        planner = TilePlanner(
            hw, double_buffer=plan.get("prefetch_depth", 2) >= 2)
        try:
            planner.plan_from_tiles(m, n, k, bm, bn, bk,
                                    in_bytes=dtype_bytes)
        except ValueError:
            return False
        return True
    if kernel == "attention":
        _, _, s, hd = shape
        bq = min(plan["block_q"], s)
        bkv = min(plan["block_kv"], s)
        vmem = (bq * hd + 2 * 2 * bkv * hd + bq * bkv
                + 2 * bq * hd) * dtype_bytes
        return vmem <= budget
    if kernel == "flash_attention_bwd":
        _, _, s, hd = shape
        bq = min(plan["block_q"], s)
        bkv = min(plan["block_kv"], s)
        return _attn_bwd_vmem(bq, bkv, hd, dtype_bytes) <= budget
    if kernel == "decode_attention":
        _, h, n_pages, page, hd = shape
        # the kernel pads the logical page axis, so pages_per_tile never
        # needs to divide n_pages — clamp and recheck the working set
        # against the QUERY layout's page size (plans transplant across
        # page sizes; tile geometry is what carries over)
        ppt = max(1, min(plan["pages_per_tile"], n_pages))
        pf = 2 if plan.get("prefetch_depth", 2) >= 2 else 1
        return _decode_vmem(h, ppt, page, hd, pf, dtype_bytes) <= budget
    if kernel == "prefill_attention":
        _, c, h, n_pages, page, hd = shape
        ppt = max(1, min(plan["pages_per_tile"], n_pages))
        pf = 2 if plan.get("prefetch_depth", 2) >= 2 else 1
        return _prefill_vmem(c * h, ppt, page, hd, pf,
                             dtype_bytes) <= budget
    if kernel == "stencil":
        rows, cols = shape
        br = min(plan["block_rows"], rows)
        if rows % br:
            return False
        halo = 1
        vmem = ((br + 2 * halo) * (cols + 2 * halo) + br * cols) \
            * dtype_bytes * 2
        return vmem <= budget
    if kernel == "histogram":
        n, n_bins = shape
        block = min(plan["block"], n)
        if n % block:
            return False
        vmem = (block * n_bins + block) * dtype_bytes + n_bins * 4
        return vmem <= budget
    if kernel == "nbody":
        (n,) = shape
        bt = min(plan["block_targets"], n)
        bs = min(plan["block_sources"], n)
        if n % bt or n % bs:
            return False
        vmem = (4 * bt + 2 * 4 * bs + bt * bs) * dtype_bytes
        return vmem <= budget
    return False                  # unknown kernel: never transplant
