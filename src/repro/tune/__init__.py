"""Autotuning over the paper's transformation design space (repro.tune).

The paper's thesis is that HLS transformations form a *parameterized design
space* a performance engineer sweeps against hardware budgets.  This package
makes that sweep executable for the Pallas kernels:

  space.py   — per-kernel candidate enumeration, VMEM-feasibility-pruned
               through the same TilePlanner arithmetic the heuristics use
  measure.py — the shared timing harness (median-of-reps, injectable clock)
  tuner.py   — the sweep driver; winners beat-or-match the heuristic by
               construction (the heuristic is always candidate 0)
  cache.py   — JSON persistence keyed by (kernel, shape, dtype, backend);
               ``ops.py`` wrappers consult it for ``plan="tuned"`` and fall
               back to TilePlanner heuristics on a miss

Entry points: ``benchmarks/run.py --tune`` (sweep + CSV/JSON report) and
``kernels.<k>(..., plan="tuned")`` (serve/train-time consumption after
``cache.preload``).
"""
from .cache import (PlanCache, default_cache, default_cache_path,
                    lookup_scope, lookup_stats, make_key, parse_key,
                    preload, reset_lookup_stats, resolve_plan,
                    resolve_plan_source, shape_distance)
from .measure import Harness, Measurement
from .space import SPACES, plan_feasible
from .tuner import TuneResult, tune, tune_all

__all__ = [
    "PlanCache", "default_cache", "default_cache_path", "lookup_scope",
    "lookup_stats", "make_key", "parse_key", "preload",
    "reset_lookup_stats", "resolve_plan", "resolve_plan_source",
    "shape_distance", "Harness", "Measurement", "SPACES", "plan_feasible",
    "DEFAULT_SHAPES", "KERNELS", "TuneResult", "tune", "tune_all",
]


def __getattr__(name):
    # KERNELS / DEFAULT_SHAPES are derived from the kernel registry, which
    # must not be imported as a side effect of ``import repro.tune`` (the
    # kernel op modules themselves import this package) — resolve lazily.
    if name in ("KERNELS", "DEFAULT_SHAPES"):
        from . import tuner
        return getattr(tuner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
