"""Persisted tuned-plan cache: JSON keyed by (kernel, shape, dtype, backend).

File format (``results/tuned_plans.json`` by default, override with the
``REPRO_TUNE_CACHE`` env var)::

    {
      "version": 1,
      "entries": {
        "matmul|256x256x256|float32|cpu": {
          "plan": {"level": 3, "bm": 256, "bn": 256, "bk": 128},
          "us": 812.4,              # best measured wall time
          "heuristic_us": 1034.9,   # the TilePlanner/default plan's time
          "candidates": 8           # sweep size that produced this entry
        },
        ...
      }
    }

``plan`` is a flat dict of the kernel's tunable kwargs; ``level`` (the paper's
T1→T3 stage, stored as an int) is optional and overrides the caller's level
when present.  Lookups are exact-key first; on a miss, ``get_nearest`` falls
back to the geometrically closest tuned shape (same kernel/dtype/backend/
rank) whose plan is VMEM-feasible for the query shape per the TilePlanner
working-set arithmetic (``repro.tune.space.plan_feasible``), and only then
to the ``TilePlanner`` heuristics (``resolve_plan`` below).  Per-route
lookup counters (``lookup_stats``) let end-to-end tests prove the cache was
consulted.

This module is intentionally import-light (no dependency on the tuner or the
kernels) because the ``kernels/*/ops.py`` wrappers import ``resolve_plan``
from here: keeping it leaf-level avoids an import cycle with
``repro.tune.tuner``, which calls into the kernels.
"""
from __future__ import annotations

import contextlib
import json
import math
import os
from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import numpy as np

CACHE_VERSION = 1
_REPO_ROOT = Path(__file__).resolve().parents[3]


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return Path(env)
    return _REPO_ROOT / "results" / "tuned_plans.json"


def _dtype_name(dtype: Any) -> str:
    return np.dtype(dtype).name


def _backend_name(backend: Optional[str] = None) -> str:
    if backend is not None:
        return backend
    import jax
    return jax.default_backend()


def make_key(kernel: str, shape: Sequence[int], dtype: Any,
             backend: Optional[str] = None) -> str:
    shape_s = "x".join(str(int(d)) for d in shape)
    return f"{kernel}|{shape_s}|{_dtype_name(dtype)}|{_backend_name(backend)}"


def parse_key(key: str) -> Tuple[str, Tuple[int, ...], str, str]:
    kernel, shape_s, dtype, backend = key.split("|")
    return kernel, tuple(int(d) for d in shape_s.split("x")), dtype, backend


def shape_distance(a: Sequence[int], b: Sequence[int]) -> float:
    """Geometric closeness: sum of squared log dim ratios.  Symmetric,
    zero iff equal, and scale-aware — (256,256,256) is nearer to
    (512,512,512) than (256,256,4096) is, which is what plan transplanting
    wants (tile geometry tracks dim magnitudes, not absolute deltas)."""
    return sum((math.log(x / y)) ** 2 for x, y in zip(a, b))


class PlanCache:
    """In-memory dict of tuned plans with JSON load/save."""

    def __init__(self, path: Union[str, Path, None] = None):
        self.path = Path(path) if path is not None else default_cache_path()
        self.entries: Dict[str, dict] = {}

    def __len__(self) -> int:
        return len(self.entries)

    def load(self) -> "PlanCache":
        if self.path.exists():
            try:
                data = json.loads(self.path.read_text())
            except (json.JSONDecodeError, OSError):
                data = {}
            if isinstance(data, dict) \
                    and data.get("version") == CACHE_VERSION:
                self.entries = dict(data.get("entries", {}))
        return self

    def save(self) -> Path:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {"version": CACHE_VERSION, "entries": self.entries}
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        tmp.replace(self.path)
        return self.path

    def get(self, kernel: str, shape: Sequence[int], dtype: Any,
            backend: Optional[str] = None) -> Optional[dict]:
        return self.entries.get(make_key(kernel, shape, dtype, backend))

    def get_nearest(self, kernel: str, shape: Sequence[int], dtype: Any,
                    backend: Optional[str] = None) -> Optional[dict]:
        """Nearest-shape fallback for an exact-key miss.

        Among entries with the same kernel/dtype/backend and rank, return
        the one whose tuned shape is geometrically closest to the query
        (``shape_distance``) AND whose plan is VMEM-feasible for the query
        shape (``repro.tune.space.plan_feasible``) — an infeasible nearest
        neighbour is skipped, never "clamped into" feasibility.  Iteration
        is over sorted keys with (distance, key) tie-breaking, so the
        result is deterministic under dict-ordering shuffles.  Returns the
        entry dict or None (-> heuristic fallback).
        """
        from .space import plan_feasible   # lazy: keeps this module leaf-y
        qshape = tuple(int(d) for d in shape)
        if any(d <= 0 for d in qshape):
            return None
        dname = _dtype_name(dtype)
        bname = _backend_name(backend)
        dtype_bytes = np.dtype(dtype).itemsize
        best: Optional[Tuple[float, str, dict]] = None
        for key in sorted(self.entries):
            try:
                ker, eshape, edt, eb = parse_key(key)
            except ValueError:
                continue
            if (ker, edt, eb) != (kernel, dname, bname) \
                    or len(eshape) != len(qshape) \
                    or any(d <= 0 for d in eshape):
                continue
            plan = self.entries[key].get("plan", {})
            try:
                feasible = plan_feasible(kernel, qshape, plan,
                                         dtype_bytes=dtype_bytes)
            except (KeyError, TypeError, ValueError):
                feasible = False
            if not feasible:
                continue
            cand = (shape_distance(qshape, eshape), key, self.entries[key])
            if best is None or cand[:2] < best[:2]:
                best = cand
        return best[2] if best is not None else None

    def put(self, kernel: str, shape: Sequence[int], dtype: Any,
            plan: Dict[str, Any], *, backend: Optional[str] = None,
            **stats: Any) -> str:
        key = make_key(kernel, shape, dtype, backend)
        self.entries[key] = {"plan": dict(plan), **stats}
        return key


# ------------------------------------------------------------- default cache
_default: Optional[PlanCache] = None


def default_cache(*, reload: bool = False) -> PlanCache:
    """Process-wide cache the ops wrappers consult for ``plan="tuned"``.

    Loaded lazily from ``default_cache_path()`` on first use; call with
    ``reload=True`` (or ``preload``) after tuning or after pointing
    ``REPRO_TUNE_CACHE`` somewhere else.
    """
    global _default
    if _default is None or reload \
            or _default.path != default_cache_path():
        _default = PlanCache().load()
    return _default


def preload(*, log=None) -> int:
    """Serve/train/perf startup hook: (re)load the tuned-plan cache so the
    first request/step already runs tuned kernels.  Returns the entry count.
    """
    cache = default_cache(reload=True)
    if log is not None:
        log(f"[tune] loaded {len(cache)} tuned plan(s) from {cache.path}")
    return len(cache)


# (route, count) counters for "tuned" lookups, incremented at trace time.
# End-to-end tests reset these, run a serve/train step, and assert the
# cache was consulted — exact hit, nearest-shape hit, or honest miss.
_lookups: Dict[str, int] = {"exact": 0, "nearest": 0, "miss": 0}


def reset_lookup_stats() -> None:
    for k in _lookups:
        _lookups[k] = 0


def lookup_stats() -> Dict[str, int]:
    return dict(_lookups)


@contextlib.contextmanager
def lookup_scope():
    """Isolated lookup-counter scope: zeroed on entry, restored on exit —
    the tune-cache twin of ``kernels.dispatch.stats_scope`` so test probes
    never leak counts across modules."""
    saved = dict(_lookups)
    reset_lookup_stats()
    try:
        yield lookup_stats
    finally:
        for k in _lookups:
            _lookups[k] = saved.get(k, 0)


def resolve_plan_source(kernel: str, shape: Sequence[int], dtype: Any,
                        level, plan
                        ) -> Tuple[Any, Optional[Dict[str, Any]], str]:
    """``resolve_plan`` plus the lookup route that produced the result.

    Returns ``(level, kwargs, source)`` where ``source`` is ``"exact"`` /
    ``"nearest"`` (tuned-cache hits), ``"heuristic"`` (cache miss or a
    non-tuned plan argument), or ``"explicit"`` (a verbatim kwargs dict).
    The kernel registry threads ``source`` into its route counters so
    ``dispatch.stats()`` and ``lookup_stats()`` can never disagree about
    why a route was taken.
    """
    from ..core.plan import Level

    if plan is None or plan == "heuristic":
        return level, None, "heuristic"
    source = "explicit"
    if plan == "tuned":
        cache = default_cache()
        entry = cache.get(kernel, shape, dtype)
        if entry is not None:
            source = "exact"
            _lookups["exact"] += 1
        else:
            entry = cache.get_nearest(kernel, shape, dtype)
            source = "nearest" if entry is not None else "heuristic"
            _lookups["nearest" if entry is not None else "miss"] += 1
        if entry is None:
            return level, None, source
        plan = entry.get("plan", {})
    if isinstance(plan, dict):
        kwargs = dict(plan)
        if "level" in kwargs:
            level = Level(kwargs.pop("level"))
        return level, kwargs, source
    raise ValueError(
        f"plan must be 'tuned', 'heuristic', None, or a kwargs dict; "
        f"got {plan!r}")


def resolve_plan(kernel: str, shape: Sequence[int], dtype: Any,
                 level, plan) -> Tuple[Any, Optional[Dict[str, Any]]]:
    """Resolve an ops wrapper's ``plan=`` argument to (level, kwargs).

    ``plan`` may be:
      * ``None`` or ``"heuristic"`` — keep the wrapper's built-in heuristics,
      * ``"tuned"`` — consult the default PlanCache: exact key, then
        nearest-shape (``PlanCache.get_nearest``), then heuristics on a
        full miss (never an error: tuning is an optimization),
      * a dict of tuned kwargs (possibly with ``"level"``) — use verbatim.

    Concrete plan objects (e.g. a TilePlan) are the wrapper's own business
    and should not be passed here.  Returns the possibly-overridden level
    and a kwargs dict or ``None``.
    """
    level, kwargs, _ = resolve_plan_source(kernel, shape, dtype, level, plan)
    return level, kwargs
