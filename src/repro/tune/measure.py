"""Shared measurement harness for the autotuner.

One code path times every candidate of every kernel so numbers are
comparable within a sweep: warmup calls (compile/trace amortized), per-rep
wall times, median-of-reps (robust to scheduler noise), failures captured
rather than raised — an infeasible candidate simply loses the sweep.

The clock is injectable so tests can drive the tuner with a deterministic
stub and assert the search itself (ordering, tie-breaks, cache writes) is
reproducible.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable

import jax


@dataclasses.dataclass(frozen=True)
class Measurement:
    us: float                # median wall microseconds per call (inf if !ok)
    reps: int
    ok: bool = True
    error: str = ""


class Harness:
    """Times zero-arg callables returning jax arrays (or pytrees)."""

    def __init__(self, *, reps: int = 3, warmup: int = 1,
                 clock: Callable[[], float] = time.perf_counter):
        self.reps = max(1, reps)
        self.warmup = max(0, warmup)
        self.clock = clock

    def measure(self, fn: Callable[[], object]) -> Measurement:
        try:
            for _ in range(self.warmup):
                jax.block_until_ready(fn())
            times = []
            for _ in range(self.reps):
                t0 = self.clock()
                jax.block_until_ready(fn())
                times.append((self.clock() - t0) * 1e6)
            return Measurement(us=statistics.median(times), reps=self.reps)
        except Exception as e:  # candidate failed: it loses, tuning goes on
            return Measurement(us=float("inf"), reps=0, ok=False,
                               error=f"{type(e).__name__}: {e}")
