"""Expert-parallel MoE via shard_map: explicit all-to-all dispatch.

GSPMD cannot partition the global scatter-dispatch of ``moe.moe_apply``
(indexed writes into the expert-sharded buffer force replication — the
dry-run measured ~4000 s/step of collective time on kimi-k2).  This module
is the TPU-native form of the paper's streaming dataflow (§3.3) + striping
(§4.3): every device is a PE:

  1. route the LOCAL token shard (tokens arrive sharded over the data axes
     (batch) and the model axis (sequence, from Megatron-SP));
  2. build per-expert send buffers with branch-free capacity masks (§2.7);
  3. ``all_to_all`` over `model` moves payloads to the expert owners (the
     FIFO channels between PEs);
  4. each device runs its E/n_ep experts on ITS OWN row's slots; expert
     weights are STORED fully sharded — experts over the EP axes, d_expert
     striped over `data` (ZeRO-3, §4.3) — and all-gathered over `data` at
     use (backward reduce-scatters the gradient automatically: grad of
     all_gather is psum_scatter).  Slots never cross the data axis, so no
     partial-sum mixing of different rows' tokens can occur;
  5. reverse all_to_all returns outputs; owners combine with top-k gates.

Capacity is per (device, expert): C = ceil(T_dev * k * cf / E) rounded to
the sublane (§3.1), so expert FLOPs stay proportional to active params.
Experts pad up to a multiple of the model axis (dummies get -inf router
logits; their slots stay empty).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.memory import DtypePolicy
from ..kernels import dispatch as kdispatch
from .layers import mlp_apply
from .moe import MoESpec, _act
from ..runtime.compat import shard_map

Params = Dict[str, jax.Array]


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    r = 1
    for a in axes:
        r *= mesh.shape[a]
    return r


def _local_dispatch(tokens, logits, s: MoESpec, e_pad: int, cap: int):
    """Route T_dev local tokens -> (E_pad, cap, d) send buffer + combine
    metadata.  Pure local ops (§2.7 branch-free capacity masking)."""
    t_dev, _ = tokens.shape
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E_pad)
    gate, eidx = jax.lax.top_k(probs, s.top_k)
    if s.norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    tk = t_dev * s.top_k
    flat_e = eidx.reshape(tk)
    flat_t = jnp.repeat(jnp.arange(t_dev), s.top_k)
    flat_g = gate.reshape(tk)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(flat_e, length=e_pad)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(tk) - starts[se]
    keep = rank < cap
    safe_rank = jnp.where(keep, rank, cap)
    buf = jnp.zeros((e_pad, cap, tokens.shape[1]), tokens.dtype)
    buf = buf.at[se, safe_rank].set(
        jnp.where(keep[:, None], tokens[st], 0), mode="drop")
    return buf, gate, eidx, se, st, sg, keep, safe_rank


def moe_apply_sharded(p: Params, s: MoESpec, x: jax.Array, dt: DtypePolicy,
                      *, mesh: Mesh, dp_axes: Tuple[str, ...],
                      model_axis: str = "model",
                      ep_axes: Tuple[str, ...] = ("model",)
                      ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) sharded P(dp, model-if-divisible, None).
    Expert weights: wg/wu (E, d, f) P(ep_axes, None, data); wd (E, f, d)
    P(ep_axes, data, None).  ``ep_axes`` is the expert-parallel axis set —
    ("pod", "model") for the trillion-param arch stripes expert state over
    all 512 chips and routes tokens cross-pod (the a2a spans both axes).
    Returns (out like x, aux loss scalar)."""
    cdt = dt.compute
    n_model = mesh.shape[model_axis]
    n_ep = _axes_size(mesh, ep_axes)
    data_axis = "data"
    e_pad = s.e_pad
    assert e_pad % n_ep == 0, (e_pad, n_ep)
    e_loc = e_pad // n_ep
    b, sq, d = x.shape
    dp_sz = _axes_size(mesh, dp_axes)
    batch_ok = b % dp_sz == 0
    seq_ax = model_axis if (sq % n_model == 0 and sq > 1) else None
    t_dev = (b * sq) // ((dp_sz if batch_ok else 1)
                         * (n_model if seq_ax else 1))
    cap = math.ceil(t_dev * s.top_k * s.capacity_factor / s.n_experts)
    cap = max(8, -(-cap // 8) * 8)

    ep = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    x_spec = P(dp_axes if batch_ok else None, seq_ax, None)
    wgu_spec = P(ep, None, data_axis)
    wd_spec = P(ep, data_axis, None)
    red_axes = (*dp_axes, model_axis) if seq_ax else tuple(dp_axes)

    def body(xl, router, wg, wu, wd):
        # ZeRO-3 (§4.3): gather the f-striped expert weights over `data`
        # for this layer's compute; grads reduce-scatter automatically
        # (transpose of all_gather is psum_scatter).
        if mesh.shape[data_axis] > 1:
            wg = jax.lax.all_gather(wg, data_axis, axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, data_axis, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, data_axis, axis=1, tiled=True)
        bl, sl, _ = xl.shape
        tokens = xl.reshape(bl * sl, d)
        logits = (tokens.astype(jnp.float32)
                  @ router.astype(jnp.float32))
        if e_pad != s.n_experts:
            logits = jnp.pad(logits, ((0, 0), (0, e_pad - s.n_experts)),
                             constant_values=-1e30)
        buf, gate, eidx, se, st, sg, keep, safe_rank = _local_dispatch(
            tokens.astype(cdt), logits, s, e_pad, cap)

        # load-balance aux loss on true (unpadded) experts
        probs = jax.nn.softmax(logits[:, :s.n_experts], axis=-1)
        me = jax.lax.pmean(probs.mean(axis=0), red_axes)
        ce = jax.lax.pmean(
            jax.nn.one_hot(eidx[:, 0], s.n_experts).mean(axis=0), red_axes)
        aux = s.aux_loss_coef * s.n_experts * jnp.sum(me * ce)

        # ---- dispatch a2a over the EP axes (§3.3 channels) ----
        send = buf.reshape(n_ep, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, ep_axes, 0, 0, tiled=False)
        # recv: (n_ep_src, e_loc, cap, d) -> (e_loc, src*cap, d)
        recv = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_ep * cap, d)

        # ---- expert FFN; d_expert striped over `data` (§4.3); the
        # per-device expert contractions route through dispatch so tuned
        # Pallas plans reach the shard_map path too ----
        gmm = functools.partial(kdispatch.grouped_matmul, policy=s.dispatch)
        g = gmm(recv, wg.astype(cdt))
        if s.activation in ("swiglu", "geglu"):
            u = gmm(recv, wu.astype(cdt))
            h = _act(g, s.activation) * u
        else:
            h = _act(g, s.activation)
        out = gmm(h, wd.astype(cdt))

        # ---- return a2a + local combine ----
        back = out.reshape(e_loc, n_ep, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(back, ep_axes, 0, 0, tiled=False)
        back = back.reshape(e_pad, cap, d)
        per_assign = back[se, safe_rank]
        per_assign = jnp.where(keep[:, None], per_assign, 0)
        per_assign = per_assign * sg[:, None].astype(cdt)
        combined = jnp.zeros((bl * sl, d), cdt).at[st].add(per_assign)
        return combined.reshape(bl, sl, d), aux

    body_sm = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), wgu_spec, wgu_spec, wd_spec),
        out_specs=(x_spec, P()),
        check_vma=False)
    out, aux = body_sm(x, p["router"], p["wg"], p["wu"], p["wd"])
    if s.n_shared_experts:
        out = out + mlp_apply(p["shared"], x.astype(cdt), s.activation, dt)
    return out, aux
