"""RG-LRU recurrent block (RecurrentGemma / Griffin).

TPU adaptation: the RG-LRU recurrence h_t = a_t * h_{t-1} + b_t is
*diagonal*, so unlike RWKV's matrix state it maps onto
``jax.lax.associative_scan`` — a log-depth parallel pipeline instead of a
sequential one.  In the paper's terms this is the ultimate accumulation
interleaving: all N partial accumulations proceed concurrently and collapse
in log2(N) stages.  The width-4 temporal conv is a literal delay buffer
(§2.2): a 3-deep shift register carried as decode state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from ..core.memory import DtypePolicy

Params = Dict[str, jax.Array]

_C = 8.0  # Griffin's fixed gate sharpness


@dataclasses.dataclass(frozen=True)
class GriffinSpec:
    d_model: int
    lru_width: int
    conv_width: int = 4
    block_width: int = 256        # block-diagonal gate projections

    @property
    def n_blocks(self) -> int:
        return self.lru_width // self.block_width


def rglru_block_init(key, s: GriffinSpec) -> Params:
    ks = jax.random.split(key, 7)
    d, w = s.d_model, s.lru_width
    nb, bw = s.n_blocks, s.block_width
    return {
        "w_main": dense_init(ks[0], (d, w)),
        "w_gate": dense_init(ks[1], (d, w)),
        "conv_w": 0.01 * jax.random.normal(ks[2], (s.conv_width, w)),
        "conv_b": jnp.zeros((w,), jnp.float32),
        # block-diagonal recurrence/input gates (Griffin appendix)
        "wa": dense_init(ks[3], (nb, bw, bw), in_axis_size=bw),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": dense_init(ks[4], (nb, bw, bw), in_axis_size=bw),
        "bx": jnp.zeros((w,), jnp.float32),
        # Lambda parametrizes a in (0,1): a = sigmoid(lam)
        "lam": jnp.linspace(2.2, 5.5, w),     # a^c in ~(0.9, 0.996)
        "w_out": dense_init(ks[5], (w, d)),
    }


def _block_diag(x: jax.Array, w: jax.Array, s: GriffinSpec) -> jax.Array:
    """x: (..., lru) @ block-diag w (nb, bw, bw) -> (..., lru)."""
    shape = x.shape
    x = x.reshape(shape[:-1] + (s.n_blocks, s.block_width))
    y = jnp.einsum("...nc,ncd->...nd", x, w)
    return y.reshape(shape)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array) -> jax.Array:
    """Depthwise causal conv, width K.  x: (B,S,w); prev: (B,K-1,w) delay
    buffer (§2.2).  Implemented as K shifted multiplies (unrolled taps)."""
    k = w.shape[0]
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)   # (B, S+K-1, w)
    out = jnp.zeros_like(x)
    sq = x.shape[1]
    for i in range(k):
        out = out + xp[:, i:i + sq, :] * w[k - 1 - i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _rglru_coeffs(p: Params, s: GriffinSpec, x: jax.Array):
    """Gates + log-recurrence weight, all f32.  x: (..., lru)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag(xf, p["wa"].astype(jnp.float32), s)
                       + p["ba"])
    i = jax.nn.sigmoid(_block_diag(xf, p["wx"].astype(jnp.float32), s)
                       + p["bx"])
    log_a = -_C * r * jax.nn.softplus(p["lam"])     # log a_t <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) in a numerically safe form
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = multiplier * i * xf
    return a, b


def rglru_scan(a: jax.Array, b: jax.Array, h0=None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative_scan over axis 1 (S)."""
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block_apply(p: Params, s: GriffinSpec, x: jax.Array,
                      dt: DtypePolicy) -> jax.Array:
    """Full Griffin recurrent block: in-proj -> conv -> RG-LRU -> gate -> out."""
    cdt = dt.compute
    b = x.shape[0]
    gate = jax.nn.gelu(x @ p["w_gate"].astype(cdt), approximate=True)
    main = x @ p["w_main"].astype(cdt)
    prev = jnp.zeros((b, s.conv_width - 1, s.lru_width), cdt)
    main = _causal_conv(main, p["conv_w"], p["conv_b"], prev)
    a, bb = _rglru_coeffs(p, s, main)
    h = rglru_scan(a, bb).astype(cdt)
    return (h * gate) @ p["w_out"].astype(cdt)


def rglru_block_decode(p: Params, s: GriffinSpec, x: jax.Array, cache,
                       dt: DtypePolicy):
    """x: (B,1,d); cache = {"h": (B,lru) f32, "conv": (B,K-1,lru)}."""
    cdt = dt.compute
    gate = jax.nn.gelu(x @ p["w_gate"].astype(cdt), approximate=True)
    main = x @ p["w_main"].astype(cdt)                     # (B,1,lru)
    conv_buf = cache["conv"]
    main_c = _causal_conv(main, p["conv_w"], p["conv_b"], conv_buf)
    new_conv = jnp.concatenate([conv_buf[:, 1:], main.astype(conv_buf.dtype)],
                               axis=1)
    a, bb = _rglru_coeffs(p, s, main_c)
    h = a[:, 0] * cache["h"] + bb[:, 0]                    # (B, lru)
    out = (h[:, None].astype(cdt) * gate) @ p["w_out"].astype(cdt)
    return out, {"h": h, "conv": new_conv}


def griffin_cache_init(b: int, s: GriffinSpec, dtype) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((b, s.lru_width), jnp.float32),
        "conv": jnp.zeros((b, s.conv_width - 1, s.lru_width), dtype),
    }
