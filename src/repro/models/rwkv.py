"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

TPU adaptation (DESIGN.md §Arch-applicability): the per-timestep recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

is a loop-carried dependency in the paper's sense (§2.1) — a naive scan has
initiation interval = the full state-update latency and no MXU utilization.
We apply **tiled accumulation interleaving (§2.1.2)**: the sequence is strip-
mined into chunks of C tokens; within a chunk all interactions are batched
matmuls (MXU work), and only one state matrix per chunk crosses the scan —
the classic chunked linear-attention formulation.  Numerical safety: all
decay ratios are exponentials of *non-positive* log-sums, so nothing
overflows; underflow is the mathematically-correct limit.

Structure simplifications vs. the reference implementation (documented):
token-shift mixing coefficients are static per-channel (RWKV5-style lerp)
rather than data-dependent ddlerp; the decay LoRA is kept (it is the "data-
dependent decay" headline feature).  Parameter count matches 7B to <2%.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from ..core.memory import DtypePolicy

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class RwkvSpec:
    d_model: int
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 64
    d_ff: int = 0                # channel-mix width
    # intra-chunk algorithm: "direct" materializes the (c, c, hd) decay
    # tensor (elementwise/VPU form); "matmul" is the §2.1.1-transposed
    # sub-chunked form whose off-diagonal blocks are boundary-normalized
    # MXU matmuls (EXPERIMENTS.md §Perf-1)
    intra: str = "direct"
    subchunk: int = 16

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


# --------------------------------------------------------------------------
# time mix
# --------------------------------------------------------------------------

def time_mix_init(key, s: RwkvSpec) -> Params:
    ks = jax.random.split(key, 8)
    d = s.d_model
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),   # shift-lerp for r,k,v,g,w
        "wr": dense_init(ks[0], (d, d)),
        "wk": dense_init(ks[1], (d, d)),
        "wv": dense_init(ks[2], (d, d)),
        "wg": dense_init(ks[3], (d, d)),
        "wo": dense_init(ks[4], (d, d)),
        "w0": -6.0 * jnp.ones((d,), jnp.float32),    # base log-log decay
        "wa": dense_init(ks[5], (d, s.decay_lora)),
        "wb": 0.01 * dense_init(ks[6], (s.decay_lora, d)),
        "u": jnp.zeros((s.n_heads, s.head_dim), jnp.float32),  # bonus
        "ln_scale": jnp.ones((d,), jnp.float32),     # group-norm on output
        "ln_bias": jnp.zeros((d,), jnp.float32),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Delay buffer of depth one (§2.2): x_{t-1}, seeded by `prev`."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _rkvgw(p: Params, s: RwkvSpec, x: jax.Array, x_prev: jax.Array,
           dt: DtypePolicy):
    cdt = dt.compute
    xx = _token_shift(x, x_prev)
    mix = [x + (xx - x) * p["mu"][i].astype(x.dtype) for i in range(5)]
    r = mix[0] @ p["wr"].astype(cdt)
    k = mix[1] @ p["wk"].astype(cdt)
    v = mix[2] @ p["wv"].astype(cdt)
    g = mix[3] @ p["wg"].astype(cdt)
    # data-dependent decay (LoRA), in f32: w in (0, 1)
    lw = -jnp.exp(
        p["w0"].astype(jnp.float32)
        + jnp.tanh(mix[4].astype(jnp.float32) @ p["wa"].astype(jnp.float32))
        @ p["wb"].astype(jnp.float32))          # log(w) <= 0, (B, S, d)
    return r, k, v, g, lw


def _heads(x: jax.Array, s: RwkvSpec) -> jax.Array:
    b, sq, d = x.shape
    return x.reshape(b, sq, s.n_heads, s.head_dim)


def _group_norm(p: Params, o: jax.Array, s: RwkvSpec, eps=1e-5) -> jax.Array:
    """Per-head layer norm (RWKV's GroupNorm(n_heads))."""
    b, sq, h, hd = o.shape
    mean = o.mean(axis=-1, keepdims=True)
    var = o.var(axis=-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + eps)
    o = o.reshape(b, sq, h * hd)
    return o * p["ln_scale"] + p["ln_bias"]


def _intra_direct(rj, kj, vj, cum, ecum, c):
    """Direct per-channel form: materializes the (c, c, hd) decay tensor
    (VPU-elementwise; memory-bound — the §Perf-1 baseline)."""
    expo = ecum[:, :, None] - cum[:, None, :, :, :]          # (b,c,c,h,hd)
    expo = jnp.where(jnp.tril(jnp.ones((c, c), bool), k=-1)
                     [None, :, :, None, None], expo, -jnp.inf)
    a = jnp.einsum("bchk,bdhk,bcdhk->bcdh", rj, kj,
                   jnp.exp(jnp.maximum(expo, -60.0))
                   * (expo > -jnp.inf))
    return jnp.einsum("bcdh,bdhv->bchv", a, vj)


def _intra_matmul(rj, kj, vj, cum, ecum, c, sc):
    """Sub-chunked matmul form (paper §2.1.1 transposition + §3.1/3.2 on
    the MXU).  Off-diagonal (a > b) sub-blocks factor the decay as
        exp(ecum_i - cum_j) = exp(ecum_i - m_a') * exp(m_a' - m_b)
                              * exp(m_b - cum_j)
    with m_x = cum at sub-chunk x's end and a' = a-1; cum is a cumsum of
    log-decays (<= 0), hence DECREASING, so every exponent above is <= 0 —
    numerically safe, and the contraction over channels becomes a plain
    (sc, hd) @ (hd, sc) matmul.  Diagonal blocks use the direct form at
    (sc, sc, hd) cost.  No (c, c, hd) tensor is ever materialized."""
    b_, cdim, h, hd = rj.shape
    nsc = c // sc
    # boundaries m[x] = cum at last element of sub-chunk x; m[-1] ~ 0
    cum_s = cum.reshape(b_, nsc, sc, h, hd)
    ecum_s = ecum.reshape(b_, nsc, sc, h, hd)
    m = cum_s[:, :, -1]                                      # (b,nsc,h,hd)
    m_prev = jnp.concatenate(
        [jnp.zeros_like(m[:, :1]), m[:, :-1]], axis=1)
    r_s = rj.reshape(b_, nsc, sc, h, hd)
    k_s = kj.reshape(b_, nsc, sc, h, hd)
    v_s = vj.reshape(b_, nsc, sc, h, hd)
    ra = r_s * jnp.exp(ecum_s - m_prev[:, :, None])          # <=0 exponents
    kb = k_s * jnp.exp(m[:, :, None] - cum_s)                # <=0 exponents

    outs = []
    for a in range(nsc):
        o_a = jnp.zeros((b_, sc, h, hd), rj.dtype)
        for b in range(a):
            # decay across the (b, a-1] boundary gap, folded into kb
            gap = jnp.exp(m_prev[:, a] - m[:, b])            # (b_,h,hd) <=0
            kba = kb[:, b] * gap[:, None]
            att = jnp.einsum("bchk,bdhk->bcdh", ra[:, a], kba)
            o_a = o_a + jnp.einsum("bcdh,bdhv->bchv", att, v_s[:, b])
        # diagonal block: direct form at (sc, sc, hd)
        expo = ecum_s[:, a, :, None] - cum_s[:, a, None, :]
        expo = jnp.where(jnp.tril(jnp.ones((sc, sc), bool), k=-1)
                         [None, :, :, None, None], expo, -jnp.inf)
        att_d = jnp.einsum("bchk,bdhk,bcdhk->bcdh", r_s[:, a], k_s[:, a],
                           jnp.exp(jnp.maximum(expo, -60.0))
                           * (expo > -jnp.inf))
        o_a = o_a + jnp.einsum("bcdh,bdhv->bchv", att_d, v_s[:, a])
        outs.append(o_a)
    return jnp.concatenate(outs, axis=1)


def wkv_chunked(r, k, v, lw, u, *, chunk: int, state=None,
                unroll: bool = False, intra: str = "direct",
                subchunk: int = 16):
    """Chunked WKV recurrence.

    r,k,v: (B, S, H, hd) compute dtype; lw: (B, S, H, hd) f32 log-decay
    (<=0); u: (H, hd) bonus.  Returns (o (B,S,H,hd) f32, final state
    (B,H,hd,hd) f32).  `unroll=True` python-unrolls the chunk loop (dry-run
    cost compiles).  `intra` selects the intra-chunk algorithm (§Perf-1).
    """
    b, sq, h, hd = r.shape
    c = min(chunk, sq)
    while c > 1 and sq % c:
        c //= 2
    n_chunks = sq // c
    sc = min(subchunk, c)
    use_matmul = intra == "matmul" and c % sc == 0 and c > sc
    f32 = jnp.float32

    def reshape_c(x):
        return x.reshape(b, n_chunks, c, h, hd)

    rc, kc, vc, lwc = map(reshape_c, (r, k, v, lw))

    if state is None:
        state = jnp.zeros((b, h, hd, hd), f32)

    def chunk_step(S, args):
        rj, kj, vj, lwj = args                   # (b, c, h, hd)
        rj = rj.astype(f32)
        kj = kj.astype(f32)
        vj = vj.astype(f32)
        cum = jnp.cumsum(lwj, axis=1)            # inclusive, (b,c,h,hd)
        ecum = cum - lwj                         # exclusive
        total = cum[:, -1]                       # (b,h,hd)
        # inter-chunk: o_i += (r_i * exp(ecum_i)) @ S        [exponent <= 0]
        r_in = rj * jnp.exp(ecum)
        o_inter = jnp.einsum("bchk,bhkv->bchv", r_in, S)
        if use_matmul:
            o_intra = _intra_matmul(rj, kj, vj, cum, ecum, c, sc)
        else:
            o_intra = _intra_direct(rj, kj, vj, cum, ecum, c)
        # bonus diagonal term
        diag = jnp.einsum("bchk,hk,bchk->bch", rj, u.astype(f32), kj)
        o_diag = diag[..., None] * vj
        # state update: S' = diag(exp(total)) S + sum_j (k_j exp(total-cum_j)) v_j
        k_dec = kj * jnp.exp(total[:, None] - cum)           # exponent <= 0
        S_new = jnp.exp(total)[..., None] * S \
            + jnp.einsum("bchk,bchv->bhkv", k_dec, vj)
        return S_new, o_inter + o_intra + o_diag

    args = tuple(jnp.moveaxis(x, 1, 0) for x in (rc, kc, vc, lwc))
    if unroll:
        outs = []
        S = state
        for i in range(n_chunks):
            S, o = chunk_step(S, tuple(a[i] for a in args))
            outs.append(o)
        o = jnp.stack(outs, axis=0)
    else:
        # remat the chunk body: the (c, c, hd) decay tensor is recomputed
        # in the backward pass instead of being stacked for all chunks
        S, o = jax.lax.scan(jax.checkpoint(chunk_step), state, args)
    o = jnp.moveaxis(o, 0, 1).reshape(b, sq, h, hd)
    return o, S


def time_mix_apply(p: Params, s: RwkvSpec, x: jax.Array, dt: DtypePolicy,
                   *, unroll: bool = False, hook=None) -> jax.Array:
    b = x.shape[0]
    hook = hook or (lambda t, _role: t)
    r, k, v, g, lw = _rkvgw(p, s, x, jnp.zeros((b, s.d_model), x.dtype), dt)
    rh, kh, vh, lwh = (hook(_heads(t, s), "q") for t in (r, k, v, lw))
    o, _ = wkv_chunked(rh, kh, vh, lwh, p["u"], chunk=s.chunk, unroll=unroll,
                       intra=s.intra, subchunk=s.subchunk)
    o = hook(o, "q")
    o = _group_norm(p, o, s).astype(dt.compute)
    o = o * jax.nn.silu(g)
    return o @ p["wo"].astype(dt.compute)


def time_mix_decode(p: Params, s: RwkvSpec, x: jax.Array, cache, dt):
    """x: (B, 1, d); cache = {"state": (B,H,hd,hd) f32, "xprev": (B,d)}."""
    r, k, v, g, lw = _rkvgw(p, s, x, cache["xprev"], dt)
    f32 = jnp.float32
    rh, kh, vh = (_heads(t, s)[:, 0].astype(f32) for t in (r, k, v))
    w = jnp.exp(_heads(lw, s)[:, 0])                       # (B,H,hd)
    S = cache["state"]
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    o = jnp.einsum("bhk,bhkv->bhv", rh, S) \
        + jnp.einsum("bhk,hk,bhk->bh", rh, p["u"].astype(f32), kh)[..., None] * vh
    S = w[..., None] * S + kv
    o = _group_norm(p, o[:, None], s).astype(dt.compute)
    o = o * jax.nn.silu(g[:, 0])[:, None, :].reshape(o.shape)
    out = o @ p["wo"].astype(dt.compute)
    new_cache = {"state": S, "xprev": x[:, 0].astype(cache["xprev"].dtype)}
    return out, new_cache


# --------------------------------------------------------------------------
# channel mix
# --------------------------------------------------------------------------

def channel_mix_init(key, s: RwkvSpec) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d, ff = s.d_model, s.d_ff
    return {
        "mu": 0.5 * jnp.ones((2, d), jnp.float32),
        "wk": dense_init(k1, (d, ff)),
        "wv": dense_init(k2, (ff, d)),
        "wr": dense_init(k3, (d, d)),
    }


def channel_mix_apply(p: Params, s: RwkvSpec, x: jax.Array, dt: DtypePolicy,
                      x_prev=None) -> jax.Array:
    cdt = dt.compute
    b = x.shape[0]
    prev = x_prev if x_prev is not None \
        else jnp.zeros((b, s.d_model), x.dtype)
    xx = _token_shift(x, prev)
    xk = x + (xx - x) * p["mu"][0].astype(x.dtype)
    xr = x + (xx - x) * p["mu"][1].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(cdt)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(cdt)) * (k @ p["wv"].astype(cdt))


def rwkv_cache_init(b: int, s: RwkvSpec, dtype) -> Dict[str, jax.Array]:
    return {
        "state": jnp.zeros((b, s.n_heads, s.head_dim, s.head_dim),
                           jnp.float32),
        "xprev": jnp.zeros((b, s.d_model), dtype),
        "cm_xprev": jnp.zeros((b, s.d_model), dtype),
    }
