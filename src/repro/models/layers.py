"""Core layers: norms, rotary embeddings, attention, MLPs, losses.

Paper tie-ins (DESIGN.md §2):
* blockwise attention = tiled accumulation interleaving (§2.1.2) applied to
  the softmax reduction — the running (max, denom, acc) triple is the
  "accumulation buffer", revisited once per KV tile;
* sliding windows = delay buffering (§2.2);
* all masks are branch-free `where` predication = condition flattening (§2.7);
* dtype policy application = type demotion (§4.4).

Every matmul/attention contraction in this module routes through
``repro.kernels.dispatch`` (the reference lowerings live there too), so
tuned Pallas plans reach the models end-to-end; ``AttnSpec.dispatch`` /
the ``policy`` arguments carry the ``ArchConfig.dispatch`` knob.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import quant
from ..core.memory import DtypePolicy
from ..kernels import dispatch

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: Optional[int] = None,
               dtype=jnp.float32) -> jax.Array:
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, shape, dtype)


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """Variance in f32; the normalize/scale multiplies stay in the input
    dtype (type demotion §4.4) — this also keeps XLA from materializing a
    full-precision copy of the residual stream per layer."""
    dt = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    return x * inv * (1.0 + p["scale"]).astype(dt)


# --------------------------------------------------------------------------
# rotary position embeddings (RoPE + M-RoPE)
# --------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 1e4,
               mrope_sections: Tuple[int, ...] = ()) -> jax.Array:
    """x: (B, S, H, hd). positions: (B, S) int32, or (B, S, 3) for M-RoPE.

    M-RoPE (qwen2-vl): the hd/2 frequency slots are split into
    ``mrope_sections`` groups, each rotated by its own position stream
    (temporal / height / width).
    """
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                       # (hd/2,)
    if mrope_sections:
        assert positions.ndim == 3 and positions.shape[-1] == len(
            mrope_sections)
        sec_ids = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.asarray(mrope_sections),
            total_repeat_length=hd // 2)                 # (hd/2,)
        # pos_per_freq[b, s, f] = positions[b, s, sec_ids[f]]
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sec_ids[None, None, :],
                             positions.shape[:2] + (hd // 2,)),
            axis=-1)                                     # (B, S, hd/2)
        angle = pos * freqs[None, None, :]
    else:
        angle = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,hd/2)
    sin = jnp.sin(angle)[:, :, None, :]
    cos = jnp.cos(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: int = 0              # 0 = global causal; >0 = sliding window
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = ()
    qkv_bias: bool = False
    softcap: float = 0.0
    # kernel-routing policy ("kernels" | "reference" | "auto"), copied from
    # ArchConfig.dispatch by the model builder
    dispatch: str = "auto"
    # "" = float weight GEMMs (dispatch.matmul); "int8" = per-channel
    # quantized projections through dispatch.quantized_matmul (§4.4),
    # copied from ArchConfig.weights_dtype by the model builder
    weights_dtype: str = ""


def project(x: jax.Array, w: jax.Array, *, policy: str = "auto",
            weights_dtype: str = "", tp: Optional[str] = None) -> jax.Array:
    """Contract x (..., K) with w (K, ...) at the configured weight dtype.

    ``"int8"`` quantizes the weight per output channel and routes through
    ``dispatch.quantized_matmul`` (fused in-kernel dequant); under jit the
    quantization is constant-folded against the weight, so the GEMM itself
    streams int8 from HBM.  Anything else is a plain ``dispatch.matmul``.
    ``tp`` names the op's sharding contract ("col"/"row") — inert outside
    an active ``registry.tp_scope`` so model code stays mesh-agnostic.
    """
    if weights_dtype == "int8":
        k = w.shape[0]
        w_q, w_scale = quant.quantize_channelwise(w.reshape(k, -1))
        out = dispatch.quantized_matmul(x, w_q, w_scale, policy=policy,
                                        tp=tp)
        return out.reshape(x.shape[:-1] + w.shape[1:]).astype(x.dtype)
    return dispatch.matmul(x, w, policy=policy, tp=tp)


def attention_init(key, s: AttnSpec) -> Params:
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, (s.d_model, s.n_heads, s.head_dim), s.d_model),
        "wk": dense_init(kk, (s.d_model, s.n_kv_heads, s.head_dim), s.d_model),
        "wv": dense_init(kv, (s.d_model, s.n_kv_heads, s.head_dim), s.d_model),
        "wo": dense_init(ko, (s.n_heads, s.head_dim, s.d_model),
                         s.n_heads * s.head_dim),
    }
    if s.qkv_bias:
        p["bq"] = jnp.zeros((s.n_heads, s.head_dim), jnp.float32)
        p["bk"] = jnp.zeros((s.n_kv_heads, s.head_dim), jnp.float32)
        p["bv"] = jnp.zeros((s.n_kv_heads, s.head_dim), jnp.float32)
    return p


def _qkv(p: Params, s: AttnSpec, x: jax.Array, positions: jax.Array,
         dt: DtypePolicy):
    cdt = dt.compute
    # (b,s,d) x (d,h,k) -> (b,s,h,k): dispatch contracts last-vs-first, so
    # the weight tensors pass through un-reshaped
    # q/k/v are column-parallel under tensor parallelism (heads device-
    # local; MQA pools replicate instead, which "col" degrades to cleanly)
    mm = functools.partial(project, policy=s.dispatch,
                           weights_dtype=s.weights_dtype, tp="col")
    q = mm(x, p["wq"].astype(cdt))
    k = mm(x, p["wk"].astype(cdt))
    v = mm(x, p["wv"].astype(cdt))
    if s.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = apply_rope(q, positions, theta=s.rope_theta,
                   mrope_sections=s.mrope_sections)
    k = apply_rope(k, positions, theta=s.rope_theta,
                   mrope_sections=s.mrope_sections)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA: (B,S,Hkv,hd) -> (B,S,H,hd) by group broadcast."""
    b, sq, hkv, hd = k.shape
    g = n_heads // hkv
    if g == 1:
        return k
    return jnp.broadcast_to(k[:, :, :, None, :], (b, sq, hkv, g, hd)) \
        .reshape(b, sq, n_heads, hd)


def _out_proj(p: Params, s: AttnSpec, out: jax.Array,
              dt: DtypePolicy) -> jax.Array:
    """(B, S, H, hd) -> (B, S, d) via wo (H, hd, d)."""
    b, sq = out.shape[:2]
    wo = p["wo"].astype(dt.compute)
    return project(
        out.reshape(b, sq, s.n_heads * s.head_dim),
        wo.reshape(s.n_heads * s.head_dim, s.d_model),
        policy=s.dispatch, weights_dtype=s.weights_dtype)


def attention_naive(p: Params, s: AttnSpec, x: jax.Array,
                    positions: jax.Array, dt: DtypePolicy) -> jax.Array:
    """T0/T1 reference: materializes the full (S, S) score tensor."""
    q, k, v = _qkv(p, s, x, positions, dt)
    k = _expand_kv(k, s.n_heads)
    v = _expand_kv(v, s.n_heads)
    out = dispatch.attention(
        q, k, v, causal=True, window=s.window, softcap=s.softcap,
        accum_dtype=dt.accum, out_dtype=dt.compute, impl="naive",
        policy=s.dispatch)
    return _out_proj(p, s, out, dt)


def attention_blockwise(p: Params, s: AttnSpec, x: jax.Array,
                        positions: jax.Array, dt: DtypePolicy, *,
                        block_q: int = 512, block_kv: int = 512,
                        unroll: bool = False, q_splits: int = 4,
                        hook=None) -> jax.Array:
    """Blockwise (flash-style) attention.

    The tiled XLA formulation itself (accumulation interleaving §2.1.2 on
    the softmax reduction, q un-blocked for SPMD sanity, ``q_splits``
    static causal quarters) lives in ``dispatch`` as the blockwise
    reference lowering; on the kernel route the same tiling runs as the
    Pallas flash kernel with tuned block geometry.  The ``hook(t, role)``
    lets the runtime constrain q/k/v shardings on either route.
    ``unroll=True`` (dry-run cost compiles) python-unrolls the KV scans so
    ``cost_analysis`` counts every tile with identical math/FLOPs.
    """
    del block_q  # q is not blocked in this formulation
    hook = hook or (lambda t, _role: t)
    q, k, v = _qkv(p, s, x, positions, dt)
    q = hook(q, "q")
    k = hook(k, "kv")
    v = hook(v, "kv")
    k = _expand_kv(k, s.n_heads)
    v = _expand_kv(v, s.n_heads)
    out = dispatch.attention(
        q, k, v, causal=True, window=s.window, softcap=s.softcap,
        accum_dtype=dt.accum, out_dtype=dt.compute, impl="blockwise",
        block_kv=block_kv, q_splits=q_splits, unroll=unroll,
        policy=s.dispatch)
    return _out_proj(p, s, out, dt)


def attention_decode(p: Params, s: AttnSpec, x: jax.Array, pos: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     dt: DtypePolicy,
                     positions_override: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a KV cache.

    x: (B, 1, d).  pos: scalar int32 current position (batch-uniform).
    caches: (B, C, Hkv, hd) where C = S_max (global) or window (rolling —
    the delay-buffer §2.2 layout: slot = pos mod window).
    Returns (out (B,1,d), k_cache, v_cache).
    """
    b = x.shape[0]
    cap = k_cache.shape[1]
    positions = (positions_override if positions_override is not None
                 else jnp.full((b, 1), pos, jnp.int32))
    q, k, v = _qkv(p, s, x, positions, dt)
    slot = pos % cap if s.window > 0 else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), slot, axis=1)

    kk = _expand_kv(k_cache.astype(dt.compute), s.n_heads)
    vv = _expand_kv(v_cache.astype(dt.compute), s.n_heads)
    idx = jnp.arange(cap)
    if s.window > 0:
        # rolling buffer: slot i holds absolute position
        #   pos - ((slot - i) mod cap)
        age = (slot - idx) % cap
        valid = (age >= 0) & (pos - age >= 0) & (age < s.window)
    else:
        valid = idx <= pos
    # the rolling-cache validity mask replaces causal/window, so this
    # always takes the dispatch reference route (no ragged-decode kernel)
    out = dispatch.attention(
        q, kk, vv, softcap=s.softcap, mask=valid[None, None, None, :],
        accum_dtype=dt.accum, out_dtype=dt.compute, impl="naive",
        policy=s.dispatch)
    out = _out_proj(p, s, out, dt)
    return out, k_cache, v_cache


def attention_decode_paged(p: Params, s: AttnSpec, x: jax.Array,
                           lengths: jax.Array, table: jax.Array,
                           k_pages: jax.Array, v_pages: jax.Array,
                           dt: DtypePolicy,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           positions_override: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                      Optional[jax.Array],
                                      Optional[jax.Array]]:
    """One-token ragged decode against the paged KV cache.

    x: (B, 1, d).  lengths: (B,) int32 tokens already cached per slot —
    the new token lands at position ``lengths[b]`` (the scheduler must
    have a page allocated there; inactive slots point at the trash page).
    table: (B, n_pages) int32 logical->physical page ids into the shared
    (P, page, Hkv, hd) pools.  int8 pools additionally carry ``k_scale`` /
    ``v_scale`` (P, Hkv) f32: the append runs the running-max requantize
    (``core.quant``) and the scales ride into the kernel's scalar-prefetch
    path.  Returns (out (B,1,d), k_pages, v_pages, k_scale, v_scale).
    """
    b = x.shape[0]
    page = k_pages.shape[1]
    positions = (positions_override if positions_override is not None
                 else lengths[:, None].astype(jnp.int32))
    q, k, v = _qkv(p, s, x, positions, dt)
    # memory banking (§4.3): the write lands in whatever physical page the
    # slot's table maps position lengths[b] to — no rectangle to reshape
    pid = table[jnp.arange(b), lengths // page]
    off = lengths % page
    if k_scale is not None:
        # quantize-on-write: gather the B target pages, append with the
        # running-max rescale, scatter pages + scales back (slots are
        # distinct; inactive slots all hit the never-read trash page)
        pk, sk = quant.append_token_quantized(
            k_pages[pid], k_scale[pid], k[:, 0], off)
        pv, sv = quant.append_token_quantized(
            v_pages[pid], v_scale[pid], v[:, 0], off)
        k_pages = k_pages.at[pid].set(pk)
        v_pages = v_pages.at[pid].set(pv)
        k_scale = k_scale.at[pid].set(sk)
        v_scale = v_scale.at[pid].set(sv)
    else:
        k_pages = k_pages.at[pid, off].set(k[:, 0].astype(k_pages.dtype))
        v_pages = v_pages.at[pid, off].set(v[:, 0].astype(v_pages.dtype))
    # GQA grouping happens inside the decode kernel/reference, so the
    # pools stay at Hkv heads end-to-end (no expanded copy in HBM)
    out = dispatch.decode_attention(
        q[:, 0], k_pages, v_pages, table, lengths + 1, k_scale, v_scale,
        window=s.window, softcap=s.softcap, accum_dtype=dt.accum,
        out_dtype=dt.compute, policy=s.dispatch)
    return (_out_proj(p, s, out[:, None], dt), k_pages, v_pages,
            k_scale, v_scale)


def attention_prefill_paged(p: Params, s: AttnSpec, x: jax.Array,
                            starts: jax.Array, tables: jax.Array,
                            k_pages: jax.Array, v_pages: jax.Array,
                            dt: DtypePolicy,
                            k_scale: Optional[jax.Array] = None,
                            v_scale: Optional[jax.Array] = None,
                            positions_override: Optional[jax.Array] = None
                            ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                       Optional[jax.Array],
                                       Optional[jax.Array]]:
    """Chunked prefill: one page-aligned chunk each from B DISTINCT slots.

    x: (B, C, d) with C == page_size (each chunk fills exactly one page;
    the caller pads final partial chunks — padded positions are never
    read back because every later attention masks kpos >= length).
    starts: (B,) int32 page-aligned chunk offsets; tables: (B, n_pages)
    each slot's page ids.  Chunk b's queries sit at ``starts[b] + [0, C)``
    and attend causally over that slot's cached history plus the chunk
    itself.  Slots must be distinct (each chunk writes its own physical
    page).  int8 pools carry ``k_scale`` / ``v_scale`` (P, Hkv) f32: a
    whole-page write gets a clean abs-max scale (``quant.quantize_pages``).
    Returns (out (B,C,d), k_pages, v_pages, k_scale, v_scale).
    """
    b, c, _ = x.shape
    page = k_pages.shape[1]
    positions = (positions_override if positions_override is not None
                 else (starts[:, None] + jnp.arange(c)[None, :]
                       ).astype(jnp.int32))
    q, k, v = _qkv(p, s, x, positions, dt)
    pid = tables[jnp.arange(b), starts // page]
    if k_scale is not None:
        pk, sk = quant.quantize_pages(k)       # k (B, C=page, Hkv, hd)
        pv, sv = quant.quantize_pages(v)
        k_pages = k_pages.at[pid].set(pk)
        v_pages = v_pages.at[pid].set(pv)
        k_scale = k_scale.at[pid].set(sk)
        v_scale = v_scale.at[pid].set(sv)
    else:
        k_pages = k_pages.at[pid].set(k.astype(k_pages.dtype))
        v_pages = v_pages.at[pid].set(v.astype(v_pages.dtype))
    # multi-token ragged prefill through dispatch: each chunk's queries
    # attend causally over the cached history plus the chunk itself (just
    # written into its page); GQA grouping happens inside the kernel /
    # reference, so the pools stay at Hkv heads end-to-end
    out = dispatch.prefill_attention(
        q, k_pages, v_pages, tables, starts, k_scale, v_scale,
        window=s.window, softcap=s.softcap, accum_dtype=dt.accum,
        out_dtype=dt.compute, policy=s.dispatch)
    return _out_proj(p, s, out, dt), k_pages, v_pages, k_scale, v_scale


def attention_verify_paged(p: Params, s: AttnSpec, x: jax.Array,
                           lengths: jax.Array, table: jax.Array,
                           k_pages: jax.Array, v_pages: jax.Array,
                           dt: DtypePolicy,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           positions_override: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                      Optional[jax.Array],
                                      Optional[jax.Array]]:
    """Speculative verify: score W candidate tokens per slot in one pass.

    x: (B, W, d) — slot b's candidate tokens occupy positions
    ``lengths[b] + [0, W)``, which are NOT page-aligned (a draft window
    starts wherever decode left off).  The whole-page write of
    ``attention_prefill_paged`` is therefore unusable here; instead the
    candidates append token-by-token exactly like the decode path (W is a
    static python loop — W is small, typically <= 5).  Appends may span a
    page boundary; the scheduler guarantees pages exist for the full
    window.  The ragged ``prefill_attention`` op then scores all W
    queries causally against history + the window itself — its mask is
    pure position arithmetic (kpos <= qpos), so mid-page ``starts`` are
    legal on kernel and reference routes alike.  Rejected drafts are
    rolled back by the HOST truncating ``lengths``; their stale K/V
    payload (and any int8 running-max scale growth) stays in the pool,
    masked off by every later ``kpos < length`` read.
    Returns (out (B,W,d), k_pages, v_pages, k_scale, v_scale).
    """
    b, w, _ = x.shape
    page = k_pages.shape[1]
    positions = (positions_override if positions_override is not None
                 else (lengths[:, None] + jnp.arange(w)[None, :]
                       ).astype(jnp.int32))
    q, k, v = _qkv(p, s, x, positions, dt)
    n_logical = table.shape[1]
    for t in range(w):
        pos = lengths + t
        # Fixed-width windows mean padded rows can step past a slot's last
        # logical page (e.g. a slot one token from max_len).  Gather would
        # silently clamp the index into the slot's LAST real page; redirect
        # those writes to trash page 0 instead.
        idx = pos // page
        pid = jnp.where(idx < n_logical,
                        table[jnp.arange(b), jnp.minimum(idx, n_logical - 1)],
                        0)
        off = pos % page
        if k_scale is not None:
            pk, sk = quant.append_token_quantized(
                k_pages[pid], k_scale[pid], k[:, t], off)
            pv, sv = quant.append_token_quantized(
                v_pages[pid], v_scale[pid], v[:, t], off)
            k_pages = k_pages.at[pid].set(pk)
            v_pages = v_pages.at[pid].set(pv)
            k_scale = k_scale.at[pid].set(sk)
            v_scale = v_scale.at[pid].set(sv)
        else:
            k_pages = k_pages.at[pid, off].set(k[:, t].astype(k_pages.dtype))
            v_pages = v_pages.at[pid, off].set(v[:, t].astype(v_pages.dtype))
    out = dispatch.prefill_attention(
        q, k_pages, v_pages, table, lengths, k_scale, v_scale,
        window=s.window, softcap=s.softcap, accum_dtype=dt.accum,
        out_dtype=dt.compute, policy=s.dispatch)
    return _out_proj(p, s, out, dt), k_pages, v_pages, k_scale, v_scale


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, activation: str) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {"wg": dense_init(k1, (d, ff)),
                "wu": dense_init(k2, (d, ff)),
                "wd": dense_init(k3, (ff, d))}
    return {"wi": dense_init(k1, (d, ff)), "wd": dense_init(k2, (ff, d))}


def mlp_apply(p: Params, x: jax.Array, activation: str,
              dt: DtypePolicy, *, policy: str = "auto",
              weights_dtype: str = "") -> jax.Array:
    cdt = dt.compute
    # Megatron split: up-projections column-parallel (no collective), the
    # down-projection row-parallel (its psum is the block's one all-reduce)
    mm = functools.partial(project, policy=policy,
                           weights_dtype=weights_dtype, tp="col")
    mm_down = functools.partial(project, policy=policy,
                                weights_dtype=weights_dtype, tp="row")
    if activation in ("swiglu", "geglu"):
        g = mm(x, p["wg"].astype(cdt))
        u = mm(x, p["wu"].astype(cdt))
        act = jax.nn.silu(g) if activation == "swiglu" \
            else jax.nn.gelu(g, approximate=True)
        return mm_down(act * u, p["wd"].astype(cdt))
    h = mm(x, p["wi"].astype(cdt))
    h = jax.nn.relu(h) if activation == "relu" \
        else jax.nn.gelu(h, approximate=True)
    return mm_down(h, p["wd"].astype(cdt))


# --------------------------------------------------------------------------
# vocab-parallel cross entropy
# --------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy.  logits (..., V) f32; labels (...) int32.

    Written max/sum-first so GSPMD turns the vocab reductions into psums
    when V is sharded over the `model` axis (vocab-parallel loss) without
    ever gathering the full logits on one device (striping §4.3).
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    label_logit = jnp.take_along_axis(
        shifted, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - label_logit)


def chunked_xent(x: jax.Array, head: jax.Array, labels: jax.Array, *,
                 n_chunks: int, unroll: bool, remat: bool = True,
                 policy: str = "auto") -> jax.Array:
    """Head matmul + cross entropy, tiled over the sequence (§3.4 tiling).

    The (B, S, V) logits tensor of a 256k-vocab model is the largest
    activation in training by an order of magnitude; computing it one
    sequence-tile at a time (and rematerializing in the backward pass)
    keeps only (B, S/n_chunks, V) alive — the same transformation the
    paper applies to fit on-chip buffers.  x: (B, S, d) post-final-norm.
    """
    b, sq, d = x.shape
    while n_chunks > 1 and sq % n_chunks != 0:
        n_chunks //= 2
    c = sq // n_chunks
    xc = jnp.moveaxis(x.reshape(b, n_chunks, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n_chunks, c), 1, 0)

    def chunk(x_c, l_c):
        logits = dispatch.matmul(x_c, head, policy=policy) \
            .astype(jnp.float32)
        m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        shifted = logits - m
        lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
        label_logit = jnp.take_along_axis(
            shifted, l_c[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - label_logit)

    if remat:
        chunk = jax.checkpoint(chunk)

    if unroll or n_chunks == 1:
        total = jnp.zeros((), jnp.float32)
        for i in range(n_chunks):
            total = total + chunk(xc[i], lc[i])
    else:
        def body(tot, args):
            return tot + chunk(*args), None
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * sq)
