"""Model substrate: layers, MoE, RWKV6, RG-LRU, and the composable decoder."""

from .transformer import Model  # noqa: F401
