"""Mixture-of-Experts with sort-based capacity dispatch.

Paper tie-ins:
* experts sharded over the `model` mesh axis = memory striping (§4.3) —
  every chip's HBM holds E/n_model expert shards;
* fixed per-expert capacity + drop = condition flattening (§2.7): the
  variable-length token->expert routing becomes branch-free masked writes
  into a dense (E, C, d) buffer, which is what spatial hardware (MXU) wants;
* the dispatch gather/scatter is memory access extraction (§4.1): routing
  (addresses) is computed apart from the expert matmuls (compute).

Compute cost is proportional to *active* parameters (top_k + shared), times
the capacity factor — there is no dense-all-experts fallback, so the
dry-run's HLO FLOPs stay honest for the MoE archs (qwen2-moe, kimi-k2).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp_init, mlp_apply
from ..core.memory import DtypePolicy
from ..kernels import dispatch as kdispatch

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    n_shared_experts: int = 0
    shared_d_expert: int = 0      # width of the fused shared-expert MLP
    capacity_factor: float = 1.25
    activation: str = "swiglu"
    aux_loss_coef: float = 0.001
    norm_topk: bool = True
    # experts padded to a multiple of the EP axis (dummies never routed;
    # set by the runtime to the mesh's model-axis size)
    pad_to: int = 1
    # kernel-routing policy ("kernels" | "reference" | "auto"), copied
    # from ArchConfig.dispatch by the model builder
    dispatch: str = "auto"

    @property
    def e_pad(self) -> int:
        return -(-self.n_experts // self.pad_to) * self.pad_to

    def capacity(self, n_tokens: int) -> int:
        c = math.ceil(n_tokens * self.top_k * self.capacity_factor
                      / self.n_experts)
        return max(8, -(-c // 8) * 8)     # sublane-aligned (§3.1)


def moe_init(key, s: MoESpec) -> Params:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, d, f = s.e_pad, s.d_model, s.d_expert
    p = {
        "router": dense_init(kr, (d, e)),
        "wg": dense_init(kg, (e, d, f), in_axis_size=d),
        "wu": dense_init(ku, (e, d, f), in_axis_size=d),
        "wd": dense_init(kd, (e, f, d), in_axis_size=f),
    }
    if s.n_shared_experts:
        width = s.shared_d_expert or s.n_shared_experts * s.d_expert
        p["shared"] = mlp_init(ks, d, width, s.activation)
    return p


def _act(x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        return jax.nn.silu(x)
    if activation == "geglu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.relu(x)


def moe_apply(p: Params, s: MoESpec, x: jax.Array, dt: DtypePolicy,
              hook=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    ``hook(tensor, role)`` lets the runtime constrain the sharding of the
    (E, C, d) dispatch/expert buffers (EP striping §4.3) without the model
    knowing about meshes."""
    hook = hook or (lambda t, _role: t)
    b, sq, d = x.shape
    n_tok = b * sq
    cap = s.capacity(n_tok)
    tokens = x.reshape(n_tok, d)

    # ---- routing (f32 for a stable softmax) ----
    logits = kdispatch.matmul(tokens.astype(jnp.float32),
                              p["router"].astype(jnp.float32),
                              policy=s.dispatch)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate, eidx = jax.lax.top_k(probs, s.top_k)                # (T, K)
    if s.norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch-style) ----
    me = probs.mean(axis=0)                                   # (E,)
    one_hot_top1 = jax.nn.one_hot(eidx[:, 0], s.n_experts)
    ce = one_hot_top1.mean(axis=0)
    aux = s.aux_loss_coef * s.n_experts * jnp.sum(me * ce)

    # ---- sort-based dispatch: rank of each assignment within its expert ----
    tk = n_tok * s.top_k
    flat_e = eidx.reshape(tk)                                 # (T*K,)
    flat_t = jnp.repeat(jnp.arange(n_tok), s.top_k)
    flat_g = gate.reshape(tk)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(flat_e, length=s.e_pad)             # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(tk) - starts[se]                        # pos within expert
    keep = rank < cap                                         # capacity drop

    # ---- masked write into the dense (E, C, d) buffer ----
    cdt = dt.compute
    safe_rank = jnp.where(keep, rank, cap)                    # OOB -> dropped
    dispatch = jnp.zeros((s.e_pad, cap, d), cdt)
    dispatch = dispatch.at[se, safe_rank].set(
        tokens[st].astype(cdt), mode="drop")
    dispatch = hook(dispatch, "dispatch")

    # ---- expert FFN: (E, C, d) x (E, d, f) ----
    gmm = functools.partial(kdispatch.grouped_matmul, policy=s.dispatch)
    g = gmm(dispatch, p["wg"].astype(cdt))
    if s.activation in ("swiglu", "geglu"):
        u = gmm(dispatch, p["wu"].astype(cdt))
        h = _act(g, s.activation) * u
    else:
        h = _act(g, s.activation)
    expert_out = hook(gmm(h, p["wd"].astype(cdt)), "expert_out")

    # ---- combine: gather back, weight by gate, scatter-add per token ----
    back = expert_out[se, safe_rank]                          # (T*K, d)
    back = jnp.where(keep[:, None], back, 0.0)
    back = back * sg[:, None].astype(cdt)
    out = jnp.zeros((n_tok, d), cdt).at[st].add(back)

    if s.n_shared_experts:
        out = out + mlp_apply(p["shared"], tokens.astype(cdt),
                              s.activation, dt, policy=s.dispatch)
    return out.reshape(b, sq, d), aux


def moe_param_count(s: MoESpec) -> Tuple[int, int]:
    """(total, active-per-token) parameter counts for MODEL_FLOPS."""
    glu = 3 if s.activation in ("swiglu", "geglu") else 2
    per_expert = glu * s.d_model * s.d_expert
    shared_width = (s.shared_d_expert or s.n_shared_experts * s.d_expert) \
        if s.n_shared_experts else 0
    shared = glu * s.d_model * shared_width
    router = s.d_model * s.n_experts
    total = s.n_experts * per_expert + shared + router
    active = s.top_k * per_expert + shared + router
    return total, active
