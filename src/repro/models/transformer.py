"""The composable decoder LM: one implementation, ten architectures.

Layer stacking strategy (paper §2.5 loop flattening): the stack is split into
``prefix`` (unrolled), ``n_periods`` repetitions of the architecture's layer
*pattern* executed under one ``jax.lax.scan`` (compact HLO, one pipeline), and
``tail`` (unrolled remainder).  The scan body holds a whole pattern period so
heterogeneous stacks (gemma3's 5 local : 1 global, recurrentgemma's
2 recurrent : 1 attention) keep their true interleaving.

Execution modes (used by the dry-run; see DESIGN.md §6):
  run  — scanned layers, scanned attention tiles (the real thing)
  mem  — like run; used for the full-depth memory-proof compile
  cost — python-unrolled everything so ``cost_analysis`` counts every tile
         exactly once per execution (XLA does not multiply scan bodies by
         trip count); used on layer-truncated configs only.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerKind
from ..core.memory import BF16_POLICY, DtypePolicy
from . import griffin, layers, moe, moe_sharded, rwkv
from .layers import Params


@dataclasses.dataclass(frozen=True)
class ExecOptions:
    mode: str = "run"              # run | mem | cost
    block_q: int = 512
    block_kv: int = 512
    remat: bool = True
    # "full" = nothing_saveable (recompute everything);
    # "dots" = dots_with_no_batch_dims_saveable (save matmul outputs —
    # trades saved-activation residency against recompute HBM traffic)
    remat_policy: str = "full"
    attn_impl: str = "blockwise"   # blockwise | naive
    # residual-stream sharding constraint (Megatron-SP striping §4.3);
    # injected by the runtime so models stay mesh-agnostic.
    constrain: Optional[Any] = None
    # MoE dispatch-buffer constraint hook (EP striping §4.3)
    moe_constrain: Optional[Any] = None
    # q/k/v sharding hook (SP->TP transition at attention entry)
    attn_constrain: Optional[Any] = None
    # sequence tiles for the head-matmul + xent (§3.4)
    xent_chunks: int = 8
    # expert-parallel MoE: mesh + data axes enable the shard_map all-to-all
    # path (moe_sharded); expert count pads to expert_pad (EP axis size)
    moe_mesh: Optional[Any] = None
    moe_dp_axes: Tuple[str, ...] = ()
    moe_ep_axes: Tuple[str, ...] = ("model",)
    expert_pad: int = 1

    @property
    def unroll_inner(self) -> bool:
        return self.mode == "cost"

    @property
    def scan_layers(self) -> bool:
        return self.mode != "cost"


@dataclasses.dataclass(frozen=True)
class Layout:
    prefix: Tuple[LayerKind, ...]
    period: Tuple[LayerKind, ...]
    n_periods: int
    tail: Tuple[LayerKind, ...]


def make_layout(cfg: ArchConfig) -> Layout:
    kinds = cfg.layer_kinds()
    pre = tuple(cfg.prefix)
    rest = kinds[len(pre):]
    if cfg.pattern and len(rest) >= len(cfg.pattern):
        p = len(cfg.pattern)
        n_periods = len(rest) // p
        tail = rest[n_periods * p:]
        return Layout(pre, tuple(cfg.pattern), n_periods, tail)
    return Layout(kinds, (), 0, ())


# --------------------------------------------------------------------------
# per-layer specs
# --------------------------------------------------------------------------

def _attn_spec(cfg: ArchConfig, mixer: str) -> layers.AttnSpec:
    return layers.AttnSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        window=cfg.window if mixer == "swa" else 0,
        rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
        qkv_bias=cfg.qkv_bias, dispatch=cfg.dispatch,
        weights_dtype=cfg.weights_dtype)


def _moe_spec(cfg: ArchConfig, pad_to: int = 1) -> moe.MoESpec:
    return moe.MoESpec(
        d_model=cfg.d_model, n_experts=cfg.n_experts, top_k=cfg.top_k,
        d_expert=cfg.d_expert, n_shared_experts=cfg.n_shared_experts,
        shared_d_expert=cfg.shared_d_expert,
        capacity_factor=cfg.capacity_factor, activation=cfg.activation,
        pad_to=pad_to, dispatch=cfg.dispatch)


def _rwkv_spec(cfg: ArchConfig) -> rwkv.RwkvSpec:
    return rwkv.RwkvSpec(d_model=cfg.d_model, head_dim=cfg.rwkv_head_dim,
                         chunk=cfg.rwkv_chunk, d_ff=cfg.d_ff,
                         intra=cfg.rwkv_intra)


def _griffin_spec(cfg: ArchConfig) -> griffin.GriffinSpec:
    return griffin.GriffinSpec(
        d_model=cfg.d_model, lru_width=cfg.lru_width or cfg.d_model,
        conv_width=cfg.conv_width,
        block_width=min(256, cfg.lru_width or cfg.d_model))


# --------------------------------------------------------------------------
# layer init / apply / decode
# --------------------------------------------------------------------------

def layer_init(key, cfg: ArchConfig, kind: LayerKind,
               expert_pad: int = 1) -> Params:
    mixer, ffn = kind
    k1, k2 = jax.random.split(key)
    p: Params = {"ln1": layers.rmsnorm_init(cfg.d_model),
                 "ln2": layers.rmsnorm_init(cfg.d_model)}
    if mixer in ("attn", "swa"):
        p["attn"] = layers.attention_init(k1, _attn_spec(cfg, mixer))
    elif mixer == "rwkv":
        p["tm"] = rwkv.time_mix_init(k1, _rwkv_spec(cfg))
    elif mixer == "rglru":
        p["rec"] = griffin.rglru_block_init(k1, _griffin_spec(cfg))
    else:
        raise ValueError(mixer)
    if ffn == "mlp":
        p["mlp"] = layers.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.activation)
    elif ffn == "moe":
        p["moe"] = moe.moe_init(k2, _moe_spec(cfg, expert_pad))
    elif ffn == "rwkv_cm":
        p["cm"] = rwkv.channel_mix_init(k2, _rwkv_spec(cfg))
    else:
        raise ValueError(ffn)
    return p


def layer_apply(p: Params, cfg: ArchConfig, kind: LayerKind, x: jax.Array,
                positions: jax.Array, dt: DtypePolicy,
                opts: ExecOptions) -> Tuple[jax.Array, jax.Array]:
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    # residual-stream constraints are applied to the BRANCH outputs inside
    # the remat boundary (never to the carry): resharding the scan carry
    # makes XLA save an extra full-precision activation stack per layer.
    con = opts.constrain or (lambda t: t)
    h = layers.rmsnorm(p["ln1"], x)
    if mixer in ("attn", "swa"):
        spec = _attn_spec(cfg, mixer)
        if opts.attn_impl == "naive":
            h = layers.attention_naive(p["attn"], spec, h, positions, dt)
        else:
            h = layers.attention_blockwise(
                p["attn"], spec, h, positions, dt,
                block_q=opts.block_q, block_kv=opts.block_kv,
                unroll=opts.unroll_inner, hook=opts.attn_constrain)
    elif mixer == "rwkv":
        h = rwkv.time_mix_apply(p["tm"], _rwkv_spec(cfg), h, dt,
                                unroll=opts.unroll_inner,
                                hook=opts.attn_constrain)
    elif mixer == "rglru":
        h = griffin.rglru_block_apply(p["rec"], _griffin_spec(cfg), h, dt)
    x = x + con(h)
    h = layers.rmsnorm(p["ln2"], x)
    if ffn == "mlp":
        h = layers.mlp_apply(p["mlp"], h, cfg.activation, dt,
                             policy=cfg.dispatch,
                             weights_dtype=cfg.weights_dtype)
    elif ffn == "moe":
        spec = _moe_spec(cfg, opts.expert_pad)
        if opts.moe_mesh is not None:
            h, aux = moe_sharded.moe_apply_sharded(
                p["moe"], spec, h, dt, mesh=opts.moe_mesh,
                dp_axes=opts.moe_dp_axes, ep_axes=opts.moe_ep_axes)
        else:
            h, aux = moe.moe_apply(p["moe"], spec, h, dt,
                                   hook=opts.moe_constrain)
    elif ffn == "rwkv_cm":
        h = rwkv.channel_mix_apply(p["cm"], _rwkv_spec(cfg), h, dt)
    return x + con(h), aux


def layer_cache_init(cfg: ArchConfig, kind: LayerKind, batch: int,
                     max_len: int, dtype) -> Dict[str, Any]:
    mixer, ffn = kind
    cache: Dict[str, Any] = {}
    if mixer in ("attn", "swa"):
        cap = min(cfg.window, max_len) if mixer == "swa" else max_len
        cache["k"] = jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim),
                               dtype)
        cache["v"] = jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim),
                               dtype)
    elif mixer == "rwkv":
        cache.update(rwkv.rwkv_cache_init(batch, _rwkv_spec(cfg), dtype))
    elif mixer == "rglru":
        cache.update(griffin.griffin_cache_init(batch, _griffin_spec(cfg),
                                                dtype))
    if ffn == "rwkv_cm" and "cm_xprev" not in cache:
        cache["cm_xprev"] = jnp.zeros((batch, cfg.d_model), dtype)
    return cache


def layer_decode(p: Params, cfg: ArchConfig, kind: LayerKind, x: jax.Array,
                 cache: Dict[str, Any], pos: jax.Array, dt: DtypePolicy,
                 positions_override=None,
                 opts: Optional[ExecOptions] = None,
                 paged: Optional[Tuple[jax.Array, jax.Array]] = None
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode token through one layer.  ``paged`` = (lengths, table)
    switches attention layers to the paged-KV ragged path (``pos`` is then
    ignored — each slot decodes at its own length); recurrent mixers and
    FFNs are cache-layout-agnostic and run unchanged either way."""
    mixer, ffn = kind
    new_cache = dict(cache)
    h = layers.rmsnorm(p["ln1"], x)
    if mixer in ("attn", "swa"):
        spec = _attn_spec(cfg, mixer)
        if paged is not None:
            lengths, table = paged
            h, kp, vp, ks, vs = layers.attention_decode_paged(
                p["attn"], spec, h, lengths, table,
                cache["k_pages"], cache["v_pages"], dt,
                cache.get("k_scale"), cache.get("v_scale"),
                positions_override=positions_override)
            new_cache["k_pages"], new_cache["v_pages"] = kp, vp
            if ks is not None:
                new_cache["k_scale"], new_cache["v_scale"] = ks, vs
        else:
            h, new_cache["k"], new_cache["v"] = layers.attention_decode(
                p["attn"], spec, h, pos, cache["k"], cache["v"], dt,
                positions_override=positions_override)
    elif mixer == "rwkv":
        h, tm_cache = rwkv.time_mix_decode(p["tm"], _rwkv_spec(cfg), h,
                                           cache, dt)
        new_cache.update(tm_cache)
    elif mixer == "rglru":
        h, rec_cache = griffin.rglru_block_decode(
            p["rec"], _griffin_spec(cfg), h, cache, dt)
        new_cache.update(rec_cache)
    x = x + h
    h = layers.rmsnorm(p["ln2"], x)
    if ffn == "mlp":
        h = layers.mlp_apply(p["mlp"], h, cfg.activation, dt,
                             policy=cfg.dispatch,
                             weights_dtype=cfg.weights_dtype)
    elif ffn == "moe":
        spec = _moe_spec(cfg, opts.expert_pad if opts else 1)
        if opts is not None and opts.moe_mesh is not None:
            h, _ = moe_sharded.moe_apply_sharded(
                p["moe"], spec, h, dt, mesh=opts.moe_mesh,
                dp_axes=opts.moe_dp_axes, ep_axes=opts.moe_ep_axes)
        else:
            h, _ = moe.moe_apply(p["moe"], spec, h, dt)
    elif ffn == "rwkv_cm":
        h = rwkv.channel_mix_apply(p["cm"], _rwkv_spec(cfg), h, dt,
                                   x_prev=cache["cm_xprev"])
        new_cache["cm_xprev"] = x[:, 0].astype(cache["cm_xprev"].dtype)
    return x + h, new_cache


def layer_cache_init_paged(cfg: ArchConfig, kind: LayerKind, slots: int,
                           total_pages: int, page_size: int,
                           dtype) -> Dict[str, Any]:
    """Paged twin of ``layer_cache_init``: attention layers get shared
    (P, page, Hkv, hd) page pools instead of per-slot rectangles;
    recurrent state stays per-slot (it is O(1) per sequence already)."""
    mixer, ffn = kind
    cache: Dict[str, Any] = {}
    if mixer in ("attn", "swa"):
        shape = (total_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        cache["k_pages"] = jnp.zeros(shape, dtype)
        cache["v_pages"] = jnp.zeros(shape, dtype)
        if jnp.dtype(dtype) == jnp.int8:
            # per-(page, kv-head) f32 scales ride next to the pools; a
            # zero scale marks a clean page (the running-max append wipes
            # any stale payload on first write — see core.quant)
            cache["k_scale"] = jnp.zeros((total_pages, cfg.n_kv_heads),
                                         jnp.float32)
            cache["v_scale"] = jnp.zeros((total_pages, cfg.n_kv_heads),
                                         jnp.float32)
    elif mixer == "rwkv":
        cache.update(rwkv.rwkv_cache_init(slots, _rwkv_spec(cfg),
                                          _state_dtype(dtype)))
    elif mixer == "rglru":
        cache.update(griffin.griffin_cache_init(slots, _griffin_spec(cfg),
                                                _state_dtype(dtype)))
    if ffn == "rwkv_cm" and "cm_xprev" not in cache:
        cache["cm_xprev"] = jnp.zeros((slots, cfg.d_model),
                                      _state_dtype(dtype))
    return cache


def _state_dtype(pool_dtype):
    """Recurrent carried state never quantizes — int8 pools keep bf16
    state (paged serving requires attention-only stacks anyway, see
    ``paged_supported``)."""
    return jnp.bfloat16 if jnp.dtype(pool_dtype) == jnp.int8 else pool_dtype


def layer_prefill_paged(p: Params, cfg: ArchConfig, kind: LayerKind,
                        x: jax.Array, cache: Dict[str, Any],
                        starts: jax.Array, tables: jax.Array,
                        dt: DtypePolicy, positions_override=None,
                        opts: Optional[ExecOptions] = None
                        ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One page-aligned prompt chunk each of B distinct slots through one
    layer (x (B, C, d), starts (B,), tables (B, n_pages)).

    Only attention mixers support chunked prefill (recurrent mixers would
    need a carried-state sequence scan — the serve scheduler falls back to
    token-by-token prefill for those archs, see ``paged_supported``).
    """
    mixer, ffn = kind
    new_cache = dict(cache)
    h = layers.rmsnorm(p["ln1"], x)
    if mixer in ("attn", "swa"):
        spec = _attn_spec(cfg, mixer)
        h, kp, vp, ks, vs = layers.attention_prefill_paged(
            p["attn"], spec, h, starts, tables,
            cache["k_pages"], cache["v_pages"], dt,
            cache.get("k_scale"), cache.get("v_scale"),
            positions_override=positions_override)
        new_cache["k_pages"], new_cache["v_pages"] = kp, vp
        if ks is not None:
            new_cache["k_scale"], new_cache["v_scale"] = ks, vs
    else:
        raise ValueError(
            f"paged chunked prefill requires attention mixers, got {mixer}")
    x = x + h
    h = layers.rmsnorm(p["ln2"], x)
    if ffn == "mlp":
        h = layers.mlp_apply(p["mlp"], h, cfg.activation, dt,
                             policy=cfg.dispatch,
                             weights_dtype=cfg.weights_dtype)
    elif ffn == "moe":
        spec = _moe_spec(cfg, opts.expert_pad if opts else 1)
        h, _ = moe.moe_apply(p["moe"], spec, h, dt)
    else:
        raise ValueError(
            f"paged chunked prefill requires stateless FFNs, got {ffn}")
    return x + h, new_cache


def layer_verify_paged(p: Params, cfg: ArchConfig, kind: LayerKind,
                       x: jax.Array, cache: Dict[str, Any],
                       lengths: jax.Array, tables: jax.Array,
                       dt: DtypePolicy, positions_override=None,
                       opts: Optional[ExecOptions] = None
                       ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One speculative verify window of B distinct slots through one layer
    (x (B, W, d), lengths (B,), tables (B, n_pages)).  Same structural
    constraints as chunked prefill (attention mixers, stateless FFNs) —
    ``paged_supported`` gates both."""
    mixer, ffn = kind
    new_cache = dict(cache)
    h = layers.rmsnorm(p["ln1"], x)
    if mixer in ("attn", "swa"):
        spec = _attn_spec(cfg, mixer)
        h, kp, vp, ks, vs = layers.attention_verify_paged(
            p["attn"], spec, h, lengths, tables,
            cache["k_pages"], cache["v_pages"], dt,
            cache.get("k_scale"), cache.get("v_scale"),
            positions_override=positions_override)
        new_cache["k_pages"], new_cache["v_pages"] = kp, vp
        if ks is not None:
            new_cache["k_scale"], new_cache["v_scale"] = ks, vs
    else:
        raise ValueError(
            f"speculative verify requires attention mixers, got {mixer}")
    x = x + h
    h = layers.rmsnorm(p["ln2"], x)
    if ffn == "mlp":
        h = layers.mlp_apply(p["mlp"], h, cfg.activation, dt,
                             policy=cfg.dispatch,
                             weights_dtype=cfg.weights_dtype)
    elif ffn == "moe":
        spec = _moe_spec(cfg, opts.expert_pad if opts else 1)
        h, _ = moe.moe_apply(p["moe"], spec, h, dt)
    else:
        raise ValueError(
            f"speculative verify requires stateless FFNs, got {ffn}")
    return x + h, new_cache


def paged_supported(cfg: ArchConfig) -> bool:
    """Can this arch serve from a paged KV cache?  Requires every mixer to
    be attention-family and every FFN stateless (chunked prefill has no
    carried-state scan for recurrent layers)."""
    return all(m in ("attn", "swa") and f in ("mlp", "moe")
               for m, f in cfg.layer_kinds())


# --------------------------------------------------------------------------
# the model
# --------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ArchConfig, dt: DtypePolicy = BF16_POLICY,
                 opts: ExecOptions = ExecOptions()):
        self.cfg = cfg
        self.dt = dt
        self.opts = opts
        self.layout = make_layout(cfg)

    # ------------------------------ init ------------------------------
    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        lay = self.layout
        pdt = self.dt.param
        ke, kh = jax.random.split(jax.random.fold_in(rng, 0))
        params: Params = {
            "embed": layers.embed_init(
                ke, (cfg.vocab_size, cfg.d_model)).astype(pdt),
            "final_norm": layers.rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["head"] = layers.dense_init(
                kh, (cfg.d_model, cfg.vocab_size), cfg.d_model).astype(pdt)

        def cast(p):
            return jax.tree.map(lambda a: a.astype(pdt), p)

        li = 0
        prefix = []
        for kind in lay.prefix:
            prefix.append(cast(layer_init(
                jax.random.fold_in(rng, 1000 + li), cfg, kind,
                self.opts.expert_pad)))
            li += 1
        params["prefix"] = prefix
        stack = []
        if lay.n_periods:
            for j, kind in enumerate(lay.period):
                idxs = jnp.arange(lay.n_periods) * len(lay.period) \
                    + (1000 + li + j)

                def init_one(i):
                    return cast(layer_init(jax.random.fold_in(rng, i),
                                           cfg, kind,
                                           self.opts.expert_pad))
                stack.append(jax.vmap(init_one)(idxs))
            li += lay.n_periods * len(lay.period)
        params["stack"] = stack
        tail = []
        for kind in lay.tail:
            tail.append(cast(layer_init(
                jax.random.fold_in(rng, 1000 + li), cfg, kind,
                self.opts.expert_pad)))
            li += 1
        params["tail"] = tail
        return params

    def param_specs(self) -> Params:
        return jax.eval_shape(self.init, jax.random.key(0))

    # ------------------------------ forward ---------------------------
    def _embed(self, params: Params, batch: Dict[str, jax.Array]):
        cfg, dt = self.cfg, self.dt
        if cfg.input_mode == "embeddings":
            x = batch["embeddings"].astype(dt.compute)
        else:
            x = params["embed"].astype(dt.compute)[batch["tokens"]]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, dt.compute)
        return x

    def _positions(self, batch, b, s, offset=0):
        if self.cfg.mrope_sections:
            return batch["positions"]
        return jnp.broadcast_to(jnp.arange(offset, offset + s)[None, :],
                                (b, s)).astype(jnp.int32)

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        from ..kernels import dispatch
        x = layers.rmsnorm(params["final_norm"], x)
        head = params["embed"].T if self.cfg.tie_embeddings \
            else params["head"]
        return dispatch.matmul(x, head.astype(self.dt.compute),
                               policy=self.cfg.dispatch)

    def _run_stack(self, params: Params, x: jax.Array,
                   positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cfg, dt, opts, lay = self.cfg, self.dt, self.opts, self.layout
        aux_total = jnp.zeros((), jnp.float32)
        con = opts.constrain or (lambda t: t)
        x = con(x)

        def one(p, kind, x):
            base = functools.partial(layer_apply, cfg=cfg, kind=kind,
                                     positions=positions, dt=dt, opts=opts)
            if opts.remat:
                policy = (jax.checkpoint_policies.nothing_saveable
                          if opts.remat_policy == "full" else
                          jax.checkpoint_policies
                          .dots_with_no_batch_dims_saveable)
                fn = jax.checkpoint(
                    lambda p_, x_: base(p_, x=x_), policy=policy)
                return fn(p, x)
            return base(p, x=x)

        for p, kind in zip(params["prefix"], lay.prefix):
            x, aux = one(p, kind, x)
            aux_total += aux

        if lay.n_periods:
            if opts.scan_layers:
                def body(carry, period_params):
                    x, aux_c = carry
                    for j, kind in enumerate(lay.period):
                        x, aux = one(period_params[j], kind, x)
                        aux_c += aux
                    return (x, aux_c), None

                (x, aux_total), _ = jax.lax.scan(
                    body, (x, aux_total), tuple(params["stack"]))
            else:
                for i in range(lay.n_periods):
                    sl = jax.tree.map(lambda a: a[i], tuple(params["stack"]))
                    for j, kind in enumerate(lay.period):
                        x, aux = one(sl[j], kind, x)
                        aux_total += aux

        for p, kind in zip(params["tail"], lay.tail):
            x, aux = one(p, kind, x)
            aux_total += aux
        return x, aux_total

    def _head(self, params: Params) -> jax.Array:
        head = params["embed"].T if self.cfg.tie_embeddings \
            else params["head"]
        return head.astype(self.dt.compute)

    def loss_fn(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x = self._embed(params, batch)
        b, s = x.shape[:2]
        positions = self._positions(batch, b, s)
        x, aux = self._run_stack(params, x, positions)
        x = layers.rmsnorm(params["final_norm"], x)
        xent = layers.chunked_xent(
            x, self._head(params), batch["labels"],
            n_chunks=min(self.opts.xent_chunks, s),
            unroll=self.opts.unroll_inner, policy=self.cfg.dispatch)
        loss = xent + aux
        return loss, {"loss": loss, "xent": xent, "aux": aux}

    def forward(self, params: Params, batch) -> jax.Array:
        """Forward returning full logits (small-scale eval / tests)."""
        x = self._embed(params, batch)
        b, s = x.shape[:2]
        positions = self._positions(batch, b, s)
        x, _ = self._run_stack(params, x, positions)
        return self._logits(params, x)

    def prefill(self, params: Params, batch) -> jax.Array:
        """Inference prefill: run the stack, return ONLY the last
        position's logits (B, V) — what batched serving actually needs to
        begin decoding.  Forward-only: no loss, no optimizer state."""
        x = self._embed(params, batch)
        b, s = x.shape[:2]
        positions = self._positions(batch, b, s)
        x, _ = self._run_stack(params, x, positions)
        x_last = jax.lax.slice_in_dim(x, s - 1, s, axis=1)
        return self._logits(params, x_last)[:, 0]

    # ------------------------------ decode ----------------------------
    def init_cache(self, batch: int, max_len: int) -> List[Dict[str, Any]]:
        cfg, lay = self.cfg, self.layout
        out: Dict[str, Any] = {"prefix": [], "stack": [], "tail": []}
        for kind in lay.prefix:
            out["prefix"].append(layer_cache_init(cfg, kind, batch, max_len,
                                                  self.dt.compute))
        if lay.n_periods:
            for kind in lay.period:
                one = layer_cache_init(cfg, kind, batch, max_len,
                                       self.dt.compute)
                out["stack"].append(jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (lay.n_periods,) + a.shape), one))
        for kind in lay.tail:
            out["tail"].append(layer_cache_init(cfg, kind, batch, max_len,
                                                self.dt.compute))
        return out

    def cache_specs(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def decode_step(self, params: Params, cache, batch: Dict[str, jax.Array],
                    pos: jax.Array, paged=None):
        """One token for every sequence.  Returns (logits (B, V), cache).

        ``paged`` = (lengths (B,), table (B, n_pages)) switches attention
        layers onto the paged ragged path: every slot decodes at its own
        length (``pos`` is ignored) against the shared page pools.
        """
        cfg, dt, lay, opts = self.cfg, self.dt, self.layout, self.opts
        x = self._embed(params, batch)          # (B, 1, d)
        pos_override = batch.get("positions") if cfg.mrope_sections else None

        new_cache = {"prefix": [], "stack": [], "tail": []}
        for p, kind, c in zip(params["prefix"], lay.prefix, cache["prefix"]):
            x, nc = layer_decode(p, cfg, kind, x, c, pos, dt, pos_override,
                                 opts=opts, paged=paged)
            new_cache["prefix"].append(nc)

        if lay.n_periods:
            if opts.scan_layers:
                def body(x, slices):
                    pp, cc = slices
                    ncs = []
                    for j, kind in enumerate(lay.period):
                        x, nc = layer_decode(pp[j], cfg, kind, x, cc[j],
                                             pos, dt, pos_override,
                                             opts=opts, paged=paged)
                        ncs.append(nc)
                    return x, tuple(ncs)

                x, ncs = jax.lax.scan(
                    body, x, (tuple(params["stack"]), tuple(cache["stack"])))
                new_cache["stack"] = list(ncs)
            else:
                stacked_new = None
                for i in range(lay.n_periods):
                    pp = jax.tree.map(lambda a: a[i], tuple(params["stack"]))
                    cc = jax.tree.map(lambda a: a[i], tuple(cache["stack"]))
                    ncs = []
                    for j, kind in enumerate(lay.period):
                        x, nc = layer_decode(pp[j], cfg, kind, x, cc[j],
                                             pos, dt, pos_override,
                                             opts=opts, paged=paged)
                        ncs.append(nc)
                    ncs = tuple(ncs)
                    if stacked_new is None:
                        stacked_new = jax.tree.map(
                            lambda a: jnp.zeros((lay.n_periods,) + a.shape,
                                                a.dtype), ncs)
                    stacked_new = jax.tree.map(
                        lambda buf, a: buf.at[i].set(a), stacked_new, ncs)
                new_cache["stack"] = list(stacked_new)

        for p, kind, c in zip(params["tail"], lay.tail, cache["tail"]):
            x, nc = layer_decode(p, cfg, kind, x, c, pos, dt, pos_override,
                                 opts=opts, paged=paged)
            new_cache["tail"].append(nc)

        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    # ------------------------------ paged serving ---------------------
    def init_paged_cache(self, slots: int, max_len: int, page_size: int,
                         total_pages: Optional[int] = None
                         ) -> Dict[str, Any]:
        """Paged KV cache: per-attention-layer (P, page, Hkv, hd) pools.

        Physical page 0 is the TRASH page — the scheduler points inactive
        slots' tables at it so their (masked, discarded) decode writes
        never land in a live sequence.  ``total_pages`` defaults to full
        capacity (every slot can reach ``max_len``); pass something
        smaller to oversubscribe — serve capacity then scales with the
        page pool, not with slots x longest-sequence.

        The pool storage dtype follows ``cfg.kv_dtype`` ("" = the model
        compute dtype; "int8" adds per-(page, kv-head) f32 scale leaves —
        type demotion §4.4 applied to the dominant serving residency).
        """
        from ..core import quant
        cfg, lay = self.cfg, self.layout
        pool_dtype = quant.kv_dtype_of(cfg.kv_dtype, self.dt.compute)
        if total_pages is None:
            total_pages = 1 + slots * (-(-max_len // page_size))
        out: Dict[str, Any] = {"prefix": [], "stack": [], "tail": []}
        for kind in lay.prefix:
            out["prefix"].append(layer_cache_init_paged(
                cfg, kind, slots, total_pages, page_size, pool_dtype))
        if lay.n_periods:
            for kind in lay.period:
                one = layer_cache_init_paged(
                    cfg, kind, slots, total_pages, page_size, pool_dtype)
                out["stack"].append(jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (lay.n_periods,) + a.shape), one))
        for kind in lay.tail:
            out["tail"].append(layer_cache_init_paged(
                cfg, kind, slots, total_pages, page_size, pool_dtype))
        return out

    def prefill_step_paged(self, params: Params, cache,
                           tokens: jax.Array, starts: jax.Array,
                           tables: jax.Array, last_idx: jax.Array):
        """One page-aligned prompt chunk each of B DISTINCT slots through
        the stack — the continuous-batching engine's multi-slot prefill.

        tokens: (B, C) with C == page_size; starts: (B,) int32 chunk
        offsets (page-aligned); tables: (B, n_pages) each slot's page ids;
        last_idx: (B,) index of the last REAL prompt token within each
        chunk (the final, possibly padded, chunk wants its logits).
        The legacy single-slot convention (scalar ``starts``/``last_idx``,
        1-D ``tables``) is normalized to B == 1.
        Returns (logits (B, V) at last_idx, cache).
        """
        cfg, dt, lay, opts = self.cfg, self.dt, self.layout, self.opts
        starts = jnp.asarray(starts)
        tables = jnp.asarray(tables)
        last_idx = jnp.asarray(last_idx)
        if starts.ndim == 0:
            starts = starts[None]
        if tables.ndim == 1:
            tables = tables[None]
        if last_idx.ndim == 0:
            last_idx = last_idx[None]
        b, c = tokens.shape
        x = self._embed(params, {"tokens": tokens})
        pos_override = None
        if cfg.mrope_sections:
            pos_override = jnp.broadcast_to(
                (starts[:, None] + jnp.arange(c)[None, :])[:, :, None],
                (b, c, len(cfg.mrope_sections))).astype(jnp.int32)

        def one(p, kind, x, c_in):
            return layer_prefill_paged(p, cfg, kind, x, c_in, starts,
                                       tables, dt, pos_override,
                                       opts=opts)

        new_cache = {"prefix": [], "stack": [], "tail": []}
        for p, kind, cc in zip(params["prefix"], lay.prefix,
                               cache["prefix"]):
            x, nc = one(p, kind, x, cc)
            new_cache["prefix"].append(nc)
        if lay.n_periods:
            def body(x, slices):
                pp, cc = slices
                ncs = []
                for j, kind in enumerate(lay.period):
                    x, nc = one(pp[j], kind, x, cc[j])
                    ncs.append(nc)
                return x, tuple(ncs)
            if opts.scan_layers:
                x, ncs = jax.lax.scan(
                    body, x, (tuple(params["stack"]), tuple(cache["stack"])))
                new_cache["stack"] = list(ncs)
            else:
                raise NotImplementedError(
                    "paged prefill runs in scan mode (ExecOptions run/mem)")
        for p, kind, cc in zip(params["tail"], lay.tail, cache["tail"]):
            x, nc = one(p, kind, x, cc)
            new_cache["tail"].append(nc)

        x_last = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)
        return self._logits(params, x_last)[:, 0], new_cache

    def verify_step_paged(self, params: Params, cache, tokens: jax.Array,
                          lengths: jax.Array, tables: jax.Array):
        """Score W candidate tokens each of B distinct slots — the
        speculative-decoding verify forward.

        tokens: (B, W) — slot b's window is ``[last_emitted, d1..d_{W-1}]``
        occupying positions ``lengths[b] + [0, W)`` (NOT page-aligned; the
        scheduler guarantees pages exist for the span).  Unlike prefill,
        the caller needs logits at EVERY window position: row t predicts
        the token at position lengths+t+1, so acceptance compares draft
        t+1 against argmax(row t).  Returns (logits (B, W, V), cache).
        """
        cfg, dt, lay, opts = self.cfg, self.dt, self.layout, self.opts
        lengths = jnp.asarray(lengths)
        tables = jnp.asarray(tables)
        b, w = tokens.shape
        x = self._embed(params, {"tokens": tokens})
        pos_override = None
        if cfg.mrope_sections:
            pos_override = jnp.broadcast_to(
                (lengths[:, None] + jnp.arange(w)[None, :])[:, :, None],
                (b, w, len(cfg.mrope_sections))).astype(jnp.int32)

        def one(p, kind, x, c_in):
            return layer_verify_paged(p, cfg, kind, x, c_in, lengths,
                                      tables, dt, pos_override, opts=opts)

        new_cache = {"prefix": [], "stack": [], "tail": []}
        for p, kind, cc in zip(params["prefix"], lay.prefix,
                               cache["prefix"]):
            x, nc = one(p, kind, x, cc)
            new_cache["prefix"].append(nc)
        if lay.n_periods:
            def body(x, slices):
                pp, cc = slices
                ncs = []
                for j, kind in enumerate(lay.period):
                    x, nc = one(pp[j], kind, x, cc[j])
                    ncs.append(nc)
                return x, tuple(ncs)
            if opts.scan_layers:
                x, ncs = jax.lax.scan(
                    body, x, (tuple(params["stack"]), tuple(cache["stack"])))
                new_cache["stack"] = list(ncs)
            else:
                raise NotImplementedError(
                    "speculative verify runs in scan mode (ExecOptions "
                    "run/mem)")
        for p, kind, cc in zip(params["tail"], lay.tail, cache["tail"]):
            x, nc = one(p, kind, x, cc)
            new_cache["tail"].append(nc)
        return self._logits(params, x), new_cache


# --------------------------------------------------------------------------
# parameter accounting
# --------------------------------------------------------------------------

def param_counts(cfg: ArchConfig) -> Dict[str, float]:
    """Exact counts from the abstract param tree + MODEL_FLOPS conventions."""
    import math
    m = Model(cfg)
    specs = m.param_specs()
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(specs))
    embed = cfg.vocab_size * cfg.d_model
    # N for 6*N*D: exclude the gather-only input table; the LM-head matmul
    # counts (once, even when tied).
    n_flops = total - (0 if cfg.tie_embeddings else embed)
    n_active = n_flops
    if cfg.n_experts:
        per_total, per_active = moe.moe_param_count(_moe_spec(cfg))
        n_moe_layers = sum(1 for k in cfg.layer_kinds() if k[1] == "moe")
        n_active = n_flops - n_moe_layers * (per_total - per_active)
    return {"total": total, "embed": embed,
            "n_flops": n_flops, "n_active": n_active}
