"""Sharding rules: DP / FSDP / TP / EP mapped onto the production mesh.

Paper mapping (§4.3 memory striping + §3.2 replication across chips):
* the `model` axis stripes the *parallel* dimensions: attention heads,
  FFN hidden, experts (EP), vocab — Megatron-style tensor parallelism;
* the `data` (+`pod`) axes stripe the batch, and — when ``fsdp`` —
  additionally stripe weights and optimizer moments RAID-0 style (ZeRO-3),
  which is what lets the >=67B archs fit;
* the residual stream between layers is sequence-sharded over `model`
  (Megatron-SP), so saved activations stripe too;
* small leaves (norms, biases, scalars) are replicated.

Rules are *divisibility-guarded*: a dim is only sharded if the axis size
divides it, otherwise a fallback (or replication) is used — e.g. gemma-2b's
single KV head cannot split over 16 model ways, so its KV cache falls back
to striping the sequence dimension (flash-decode style) automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def _prod(it):
    r = 1
    for x in it:
        r *= x
    return r


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    dp_axes: Tuple[str, ...]              # ("pod", "data") or ("data",)
    model_axis: str = "model"
    fsdp: bool = True
    fsdp_axes: Tuple[str, ...] = ("data",)
    ep_axes: Tuple[str, ...] = ("model",)
    # §Perf-2 knobs: FSDP-striping V-x-d tables costs a gather per xent
    # chunk; seq-parallel attention avoids resharding the residual stream
    stripe_embed: bool = True
    attn_prefer_seq: bool = False

    @property
    def fsdp_axis(self) -> Axis:
        if not self.fsdp:
            return None
        return self.fsdp_axes if len(self.fsdp_axes) > 1 \
            else self.fsdp_axes[0]

    def axis_size(self, name: Axis) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            return _prod(self.mesh.shape[a] for a in name)
        return self.mesh.shape[name]

    # ------------------------------------------------------------------
    def _fit(self, dim: int, axis: Axis) -> Axis:
        size = self.axis_size(axis)
        if axis is None or size == 1 or dim % size != 0:
            return None
        return axis

    def spec_for(self, path: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for a parameter leaf, by its tree path."""
        model, fsdp = self.model_axis, self.fsdp_axis
        stacked = ".stack." in path or path.startswith("stack.")
        base = shape[1:] if stacked else shape

        def out(*axes):
            axes = list(axes) + [None] * (len(base) - len(axes))
            axes = [self._fit(d, a) for d, a in zip(base, axes)]
            if stacked:
                axes = [None] + axes
            return P(*axes)

        name = path.rsplit(".", 1)[-1]
        if name == "embed":
            return out(model, fsdp if self.stripe_embed else None)  # (V, d)
        if name == "head":
            return out(fsdp if self.stripe_embed else None, model)  # (d, V)
        if ".attn." in path:
            if name in ("wq", "wk", "wv"):
                # prefer TP on heads; MQA/GQA fall back to head_dim
                if base[1] % self.axis_size(model) == 0:
                    return out(fsdp, model, None)      # (d, H, hd)
                return out(fsdp, None, model)
            if name == "wo":
                if base[0] % self.axis_size(model) == 0:
                    return out(model, None, fsdp)      # (H, hd, d)
                return out(None, model, fsdp)
            if name in ("bq", "bk", "bv"):
                return out(model, None)                # (H, hd)
        if ".mlp." in path or ".shared." in path or ".cm." in path:
            if name in ("wg", "wu", "wi", "wk"):
                return out(fsdp, model)                # (d, ff)
            if name in ("wd", "wv"):
                return out(model, fsdp)                # (ff, d)
            if name == "wr":
                return out(fsdp, model)                # (d, d) channel-mix r
        if ".moe." in path:
            # matches moe_sharded's shard_map specs: experts over the EP
            # axes, d_expert striped over `data` (§4.3) — no weight gathers
            ep = self.ep_axes if len(self.ep_axes) > 1 else self.ep_axes[0]
            if name in ("wg", "wu"):
                return out(ep, None, "data")           # (E, d, f)
            if name == "wd":
                return out(ep, "data", None)           # (E, f, d)
            if name == "router":
                return out(None, None)                 # (d, E) replicated
        if ".tm." in path:                             # rwkv time mix
            if name in ("wr", "wk", "wv", "wg"):
                return out(fsdp, model)                # (d, d)
            if name == "wo":
                return out(model, fsdp)
            if name == "wa":
                return out(fsdp, None)                 # (d, lora)
            if name == "wb":
                return out(None, model)                # (lora, d)
            if name == "u":
                return out(model, None)                # (H, hd)
        if ".rec." in path:                            # griffin
            if name in ("w_main", "w_gate"):
                return out(fsdp, model)                # (d, lru)
            if name == "w_out":
                return out(model, fsdp)                # (lru, d)
            if name in ("wa", "wx"):
                return out(model, None, None)          # (nb, bw, bw)
            if name == "conv_w":
                return out(None, model)                # (K, lru)
            if name in ("lam", "ba", "bx", "conv_b"):
                return out(model)                      # (lru,)
        # norms, mu, scalars, everything small: replicate
        return P(*([None] * len(shape)))

    # ------------------------------------------------------------------
    def batch_spec(self, shape: Tuple[int, ...]) -> P:
        dp: Axis = self.dp_axes
        if shape[0] % self.axis_size(dp) != 0:
            # try intra-pod data axis alone, else replicate (e.g. batch=1)
            dp = "data" if shape[0] % self.axis_size("data") == 0 else None
        return P(*([dp] + [None] * (len(shape) - 1)))

    def activation_spec(self, shape: Tuple[int, ...]) -> Optional[P]:
        """Residual stream (B, S, d): batch over DP, sequence over model
        (Megatron-SP striping §4.3).  None if nothing fits."""
        if len(shape) != 3:
            return None
        dp: Axis = self.dp_axes
        if shape[0] % self.axis_size(dp) != 0:
            dp = None
        seq = self.model_axis \
            if shape[1] % self.axis_size(self.model_axis) == 0 \
            and shape[1] > 1 else None
        if dp is None and seq is None:
            return None
        return P(dp, seq, None)

    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """KV/state caches: batch over DP; heads (or sequence) over model."""
        stacked = ".stack." in path or path.startswith("stack.")
        base = shape[1:] if stacked else shape
        name = path.rsplit(".", 1)[-1]
        dp: Axis = self.dp_axes
        if base[0] % self.axis_size(dp) != 0:
            dp = "data" if base[0] % self.axis_size("data") == 0 else None
        axes: list = [dp] + [None] * (len(base) - 1)
        model = self.model_axis
        msz = self.axis_size(model)
        if name in ("k", "v") and len(base) == 4:      # (B, S, Hkv, hd)
            if base[2] % msz == 0:
                axes[2] = model
            elif base[1] % msz == 0:
                axes[1] = model                        # flash-decode S-shard
        elif name == "state" and len(base) == 4:       # rwkv (B, H, k, v)
            if base[1] % msz == 0:
                axes[1] = model
        elif name == "h" and len(base) == 2:           # rglru (B, lru)
            if base[1] % msz == 0:
                axes[1] = model
        elif name == "conv" and len(base) == 3:        # (B, K-1, lru)
            if base[2] % msz == 0:
                axes[2] = model
        elif name in ("xprev", "cm_xprev") and len(base) == 2:
            if base[1] % msz == 0:
                axes[1] = model
        if stacked:
            axes = [None] + axes
        return P(*axes)


def make_rules(mesh: Mesh, *, fsdp: bool = True,
               fsdp_axes: Optional[Tuple[str, ...]] = None,
               ep_axes: Optional[Tuple[str, ...]] = None) -> MeshRules:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if fsdp_axes is None:
        fsdp_axes = ("data",)
    fsdp_axes = tuple(a for a in fsdp_axes if a in mesh.axis_names)
    if ep_axes is None:
        ep_axes = ("model",)
    ep_axes = tuple(a for a in ep_axes if a in mesh.axis_names)
    return MeshRules(mesh=mesh, dp_axes=dp, fsdp=fsdp,
                     fsdp_axes=fsdp_axes or ("data",),
                     ep_axes=ep_axes or ("model",))


# --------------------------------------------------------------------------
# tree -> shardings
# --------------------------------------------------------------------------

def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts)


def tree_shardings(rules: MeshRules, tree: Any, kind: str = "param") -> Any:
    """NamedShardings for every leaf of an (abstract) pytree.

    kind: "param" | "batch" | "cache".  Optimizer moment trees reuse the
    param rules; QuantizedBlock moments: `q` keeps the param's shape so it
    shares the param spec, flat `scale` vectors stripe over all mesh axes
    when divisible (they are 1/128 the size of the moment)."""
    all_axes = tuple(rules.mesh.axis_names)
    n_all = _prod(rules.axis_size(a) for a in all_axes)

    def leaf(path, x):
        ps = _path_str(path)
        if kind == "batch":
            spec = rules.batch_spec(x.shape)
        elif kind == "cache":
            spec = rules.cache_spec(ps, x.shape)
        elif ps.endswith(".scale"):
            # scale mirrors the param's rank (blocks along the last axis)
            spec = rules.spec_for(ps[: -len(".scale")], x.shape)
        else:
            base = ps[:-2] if ps.endswith(".q") else ps
            spec = rules.spec_for(base, x.shape)
        return NamedSharding(rules.mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, tree)


def replicated(rules: MeshRules, tree: Any) -> Any:
    return jax.tree.map(
        lambda _: NamedSharding(rules.mesh, P()), tree)
