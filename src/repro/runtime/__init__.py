from .sharding import MeshRules, make_rules  # noqa: F401
