"""JAX-version compat shims (see ROADMAP.md "JAX-version compat policy").

Leaf module: imports only jax, so both ``runtime`` and ``models`` can use it
without cycles.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across the 0.4 -> 0.5+ API move.

    Newer JAX exposes it at the top level with a ``check_vma`` kwarg; 0.4.x
    has ``jax.experimental.shard_map.shard_map`` with the same semantics
    under ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
