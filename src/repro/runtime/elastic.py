"""Elastic scaling: re-shard a training state onto a different mesh.

When nodes join or leave, the orchestrator rebuilds a mesh and the state
must follow.  Because checkpoints are host numpy + the restore path places
every leaf with ``jax.device_put(leaf, target_sharding)``, resharding IS
restoring — this module just packages the two steps and recomputes the
sharding tree for the new mesh (striping §4.3 re-applied at the new width).

Tested in tests/test_fault_tolerance.py: train on an 8-device mesh, "lose"
half the cluster, resume on 4, then "regrow" to 8 — losses match the
uninterrupted run bit-for-bit (the data pipeline is step-deterministic).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax

from ..checkpoint.checkpoint import CheckpointManager
from .sharding import MeshRules, make_rules, tree_shardings


def reshard_state(state: Any, new_rules: MeshRules) -> Any:
    """Move a live state tree onto a new mesh (no checkpoint round-trip)."""
    import numpy as np
    shardings = tree_shardings(new_rules, state)
    return jax.tree.map(
        lambda leaf, sh: jax.device_put(np.asarray(leaf), sh),
        state, shardings)


def restore_on_mesh(ckpt: CheckpointManager, state_like: Any,
                    new_rules: MeshRules) -> Tuple[Any, int, dict]:
    """Restore the latest checkpoint directly onto a (different) mesh."""
    shardings = tree_shardings(new_rules, state_like)
    placed_like = jax.tree.map(
        lambda leaf, sh: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                              sharding=sh),
        state_like, shardings)
    return ckpt.restore(placed_like)
