"""Pipeline parallelism: streaming dataflow (§3.3) across a mesh axis.

The paper's iterative-stencil design — P replicated PEs connected by FIFO
channels, each computing one timestep — maps onto TPU pods as GPipe-style
pipeline parallelism: each `stage` (a contiguous group of layers) lives on
one slice of the ``stage`` mesh axis; microbatches stream through; the
channel between consecutive PEs is ``jax.lax.ppermute`` (the FIFO), and the
fill/drain bubble is exactly the paper's pipeline latency ``L`` in
``C = L + I*(N-1)``: with M microbatches and S stages the bubble fraction
is (S-1)/(M+S-1) — the §2.5 motivation at cluster scale.

Implementation: a shard_map over the stage axis running the classic
"rotating buffer" schedule.  All stages execute the same program (SPMD);
stage identity comes from ``jax.lax.axis_index``.  Used by the launch-time
option ``--pipeline-stages`` and validated numerically against the
unpartitioned model in tests (tests/test_pipeline_parallel.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from .compat import shard_map


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    x_microbatches: jax.Array,
    *,
    mesh: Mesh,
    stage_axis: str = "pod",
) -> jax.Array:
    """Run ``stage_fn`` as an S-stage pipeline over M microbatches.

    stage_params: pytree whose leaves have a leading stage axis (S, ...),
    sharded P(stage_axis, ...).  x_microbatches: (M, mb, ...) replicated
    over the stage axis.  Returns (M, mb, ...) outputs (from the last
    stage, broadcast).  M must be >= S.
    """
    n_stages = mesh.shape[stage_axis]
    m = x_microbatches.shape[0]
    assert m >= n_stages, (m, n_stages)
    n_ticks = m + n_stages - 1

    def body(params, xs):
        # params: (1, ...) local stage slice; xs: (M, mb, ...) replicated
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(stage_axis)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)        # current PE buffer
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outs = carry
            # stage 0 ingests microbatch t (when valid)
            feed = xs[jnp.clip(t, 0, m - 1)]
            inp = jnp.where(sid == 0, feed, state)
            out = stage_fn(params, inp)
            # FIFO channel to the next PE (§3.3): rotate downstream
            nxt = jax.lax.ppermute(
                out, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage emits microbatch t-(S-1)
            emit_idx = t - (n_stages - 1)
            valid = emit_idx >= 0
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[jnp.maximum(emit_idx, 0)].set(out),
                lambda o: o, outs)
            return (nxt, outs), None

        (state, outs), _ = jax.lax.scan(
            tick, (state, outs), jnp.arange(n_ticks))
        # every device computed `outs`, but only the last stage's is real;
        # broadcast it with a masked psum (one collective at pipeline exit)
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_vma=False)
    return fn(stage_params, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """The §1.2 pipeline model applied to the stage pipeline."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
