"""Fault tolerance: supervised training, straggler watch, failure injection.

At thousands of nodes the *expected* state is partial failure.  Components:

* ``Supervisor`` — wraps the step loop: on any step exception it restores
  the newest complete checkpoint and replays (the data pipeline is
  deterministic in step, so replay is exact).  Bounded restarts; escalates
  after ``max_restarts``.
* ``StragglerWatch`` — tracks per-step wall times; flags steps beyond
  ``k * MAD`` of the trailing window (at scale: per-host times via the same
  interface).  The train driver logs flags and can trigger an early
  checkpoint — the cheap, portable form of straggler mitigation; swapping
  the slow host is an orchestrator action this library signals, not takes.
* ``FailureInjector`` — deterministic fault schedule for tests/examples
  ("fail at step 7 and 13"), proving the restore path end-to-end.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, Optional, Sequence, Tuple

from ..checkpoint.checkpoint import CheckpointManager

log = logging.getLogger("repro.ft")


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_steps: Sequence[int] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_steps and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


class StragglerWatch:
    def __init__(self, window: int = 32, k: float = 4.0):
        self.window = deque(maxlen=window)
        self.k = k
        self.flags: list = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        if len(self.window) >= 8:
            med = sorted(self.window)[len(self.window) // 2]
            mad = sorted(abs(t - med) for t in self.window)[
                len(self.window) // 2]
            if seconds > med + self.k * max(mad, 0.05 * med, 1e-6):
                self.flags.append((step, seconds, med))
                self.window.append(seconds)
                return True
        self.window.append(seconds)
        return False


class Supervisor:
    """Restart-on-failure wrapper around a step function."""

    def __init__(self, ckpt: CheckpointManager, *, save_every: int = 50,
                 max_restarts: int = 5,
                 injector: Optional[FailureInjector] = None):
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.restarts = 0
        self.stragglers = StragglerWatch()

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            n_steps: int, *, start_step: int = 0,
            on_metrics: Optional[Callable[[int, Dict], None]] = None
            ) -> Tuple[Any, int]:
        """state -> final state.  ``step_fn(state, step) -> (state, metrics)``.

        The data batch is derived from `step` inside step_fn (deterministic
        pipeline), which is what makes replay-after-restore exact."""
        step = start_step
        while step < n_steps:
            try:
                t0 = time.time()
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                state, metrics = step_fn(state, step)
                dt = time.time() - t0
                if self.stragglers.observe(step, dt):
                    log.warning("straggler step %d: %.3fs", step, dt)
                if on_metrics is not None:
                    on_metrics(step, metrics)
                step += 1
                if step % self.save_every == 0:
                    self.ckpt.save(step, state, extra={"step": step})
            except Exception as e:  # noqa: BLE001 — the whole point
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%r); restoring", step, e)
                latest = self.ckpt.latest_step()
                if latest is None:
                    # nothing saved yet: restart from the initial state
                    step = start_step
                    continue
                state, step, _ = self.ckpt.restore(state)
        self.ckpt.save(n_steps, state, extra={"step": n_steps})
        return state, step
