"""Tensor-parallel paged serving: shard_map plumbing around the registry.

The serving TP scheme is the classic Megatron split, specialized to the
paged-KV decode/prefill stack (ROADMAP item 4; the paper's replication +
memory-partitioning transformations applied to attention heads so parallel
units never contend for one KV interface):

* q/k/v projections are **column-parallel** — each device owns a contiguous
  block of heads (``wq`` sharded on its head axis), so the ragged paged
  attention kernels run *unmodified* per shard against a device-local slice
  of the KV page pools.  The per-shard attention output is **all-gathered**
  back to full heads (the block's one gather), and ``wo`` stays replicated —
  which also keeps int8 per-output-channel weight scales bit-exact.
* MLP up-projections (``wg``/``wu``/``wi``) are column-parallel, the
  down-projection ``wd`` is **row-parallel** with a psum — the block's one
  all-reduce (this covers ``quantized_matmul`` too: int8 ``wd`` shards carry
  per-shard local scales).
* Embedding, norms, logits head, and MoE FFN weights stay replicated; the
  residual stream is replicated everywhere outside an attention/MLP interior.
* MQA (``n_kv_heads == 1``): KV pools and ``wk``/``wv`` replicate (every
  device appends identical K/V), only q-heads shard.

The ops themselves declare these contracts on their ``OpSpec.tp`` tables;
call sites in ``models/layers.py`` carry inert ``tp="col"``/``"row"`` tags,
and ``registry.call`` applies the collective only inside an active
``registry.tp_scope`` — which this module opens while tracing the
``shard_map`` body.  ``registry.call`` therefore stays the single routing
path inside the mapped region, and model code stays mesh-agnostic.

Host-side page metadata (``PageAllocator``, prefix trie, CoW stash) is
device-free and shared across shards: every device sees the same tables and
lengths; pages never cross devices.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..kernels import registry
from . import compat


# --------------------------------------------------------------------------
# eligibility
# --------------------------------------------------------------------------

def tp_error(cfg, tp: int) -> Optional[str]:
    """Why this arch can't serve at tensor-parallel degree ``tp``
    (None = supported).  tp == 1 is always supported (degenerate mesh)."""
    if tp <= 1:
        return None
    from ..models.transformer import paged_supported
    if not paged_supported(cfg):
        return f"{cfg.name}: paged serving requires attention-only stacks"
    if cfg.n_heads % tp:
        return f"{cfg.name}: n_heads={cfg.n_heads} not divisible by tp={tp}"
    if cfg.n_kv_heads != 1 and cfg.n_kv_heads % tp:
        return (f"{cfg.name}: n_kv_heads={cfg.n_kv_heads} not divisible by "
                f"tp={tp} (only MQA n_kv_heads=1 replicates)")
    if any(f == "mlp" for _, f in cfg.layer_kinds()) and cfg.d_ff % tp:
        return f"{cfg.name}: d_ff={cfg.d_ff} not divisible by tp={tp}"
    return None


def kv_sharded(cfg, tp: int) -> bool:
    """Do the KV page pools shard over the mesh (False = MQA replication)?"""
    return tp > 1 and cfg.n_kv_heads % tp == 0


# --------------------------------------------------------------------------
# partition-spec derivation (params + paged cache)
# --------------------------------------------------------------------------

def _dim_spec(ndim: int, d: int, axis: str) -> P:
    spec = [None] * ndim
    spec[d] = axis
    return P(*spec)


def _path_names(path) -> list:
    return [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]


def param_pspecs(params, cfg, tp: int, *, axis: str = "model"):
    """PartitionSpec tree for a ``Model.init`` params tree.

    Sharded dims are counted from the *trailing* end so the specs survive
    the scanned stack's extra leading ``n_periods`` axis unchanged:
    ``wq`` (d, H, hd) and bias (H, hd) shard ndim-2; ``wg``/``wu``/``wi``
    (d, ff) shard ndim-1; ``wd`` (ff, d) shards ndim-2.  Everything else
    (embed, norms, head, ``wo``, MoE weights) replicates.
    """
    kv = kv_sharded(cfg, tp)

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if "attn" in names:
            if name in ("wq", "bq"):
                return _dim_spec(leaf.ndim, leaf.ndim - 2, axis)
            if kv and name in ("wk", "wv", "bk", "bv"):
                return _dim_spec(leaf.ndim, leaf.ndim - 2, axis)
            return P()
        if "mlp" in names:
            if name in ("wg", "wu", "wi"):
                return _dim_spec(leaf.ndim, leaf.ndim - 1, axis)
            if name == "wd":
                return _dim_spec(leaf.ndim, leaf.ndim - 2, axis)
        return P()

    return jax.tree_util.tree_map_with_path(spec, params)


def cache_pspecs(cache, cfg, tp: int, *, axis: str = "model"):
    """PartitionSpec tree for a ``Model.init_paged_cache`` tree: pools
    (P, page, Hkv, hd) shard their kv-head axis (ndim-2), scales (P, Hkv)
    shard ndim-1 — or everything replicates under MQA / tp == 1."""
    kv = kv_sharded(cfg, tp)

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if kv and name in ("k_pages", "v_pages"):
            return _dim_spec(leaf.ndim, leaf.ndim - 2, axis)
        if kv and name in ("k_scale", "v_scale"):
            return _dim_spec(leaf.ndim, leaf.ndim - 1, axis)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def shard_tree(tree, specs, mesh):
    """device_put every leaf with its NamedSharding (host->mesh placement)."""
    return jax.tree.map(
        lambda leaf, s: jax.device_put(leaf, NamedSharding(mesh, s)),
        tree, specs)


# --------------------------------------------------------------------------
# shard_map'd step functions
# --------------------------------------------------------------------------

def sharded_paged_fns(model, mesh, *, axis: str = "model"):
    """(decode_fn, prefill_fn) running the model's paged steps under
    ``compat.shard_map`` with ``registry.tp_scope`` active in the body.

    Both take the same signatures as ``Model.decode_step`` /
    ``Model.prefill_step_paged`` (params and cache pre-sharded via
    ``shard_tree``; everything else replicated) and return replicated
    logits plus the cache in its input sharding.  ``check_vma=False``
    because the replicated outputs come from collectives the rep-checker
    can't prove (psum into residuals, gathered attention heads).
    """
    cfg = model.cfg
    tp = mesh.shape[axis]
    err = tp_error(cfg, tp)
    if err:
        raise ValueError(err)

    def wrap(step, n_rest):
        def run(params, cache, *rest):
            assert len(rest) == n_rest
            p_specs = param_pspecs(params, cfg, tp, axis=axis)
            c_specs = cache_pspecs(cache, cfg, tp, axis=axis)

            def body(params, cache, *rest):
                # the body executes at trace time, so the scope is active
                # exactly while registry.call sites inside the mapped
                # region are being traced — tags become live contracts
                with registry.tp_scope(axis):
                    return step(params, cache, *rest)

            return compat.shard_map(
                body, mesh=mesh,
                in_specs=(p_specs, c_specs) + (P(),) * n_rest,
                out_specs=(P(), c_specs),
                check_vma=False,
            )(params, cache, *rest)
        return run

    decode = wrap(model.decode_step, 3)       # batch, pos, paged
    prefill = wrap(model.prefill_step_paged, 4)  # tokens, starts, tables, last
    return decode, prefill
