"""Roofline term extraction from compiled dry-run artifacts.

* FLOPs / HBM bytes: ``compiled.cost_analysis()`` — verified to be
  per-partition numbers for SPMD modules, so totals are x chips.
* collective bytes: NOT in cost_analysis — parsed from the optimized HLO
  text.  For every all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute we record the per-partition operand bytes and the
  replica-group size, and model per-chip ICI traffic with the standard ring
  costs:

      all-gather      (n-1)   * operand      (operand = local shard)
      reduce-scatter  (n-1)/n * operand      (operand = full local buffer)
      all-reduce    2*(n-1)/n * operand
      all-to-all      (n-1)/n * operand
      collective-permute        operand      (one hop)

  ``collective_bytes`` (the EXPERIMENTS.md numerator) = per-chip traffic
  summed over chips, so ``collective_bytes / (chips * link_bw)`` is the
  mean per-chip serialized link time.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<shape>\([^=]*?\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(",
    re.M)

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{\{")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string; tuples summed."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    op: str
    operand_bytes: int        # per-partition
    group_size: int
    line: str

    @property
    def per_chip_traffic(self) -> float:
        n = max(self.group_size, 1)
        b = self.operand_bytes
        if self.op == "all-gather":
            # HLO prints the *result* (gathered) shape; operand = result/n.
            return b / n * (n - 1)
        if self.op == "reduce-scatter":
            return b * (n - 1) / n
        if self.op == "all-reduce":
            return 2.0 * b * (n - 1) / n
        if self.op == "all-to-all":
            return b * (n - 1) / n
        return float(b)       # collective-permute: one hop


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for m in _COLL_RE.finditer(hlo_text):
        line_end = hlo_text.find("\n", m.start())
        line = hlo_text[m.start(): line_end if line_end > 0 else None]
        op = m.group("op")
        shape = m.group("shape")
        gs = 1
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            gs = int(gi.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                gs = len([t for t in gl.group(1).split(",") if t.strip()])
            elif op == "collective-permute":
                gs = 2
        nbytes = _shape_bytes(shape)
        # shapes are printed for the RESULT; convert to operand bytes
        if op == "reduce-scatter":
            nbytes *= gs            # result is the scattered shard
        ops.append(CollectiveOp(op, nbytes, gs, line.strip()[:200]))
    return ops


@dataclasses.dataclass
class CollectiveStats:
    per_chip_bytes: float                 # serialized ICI traffic per chip
    by_op: Dict[str, float]
    count: int
    schedule: List[str]

    @staticmethod
    def empty() -> "CollectiveStats":
        return CollectiveStats(0.0, {}, 0, [])


def collective_stats(hlo_text: str) -> CollectiveStats:
    ops = parse_collectives(hlo_text)
    by_op: Dict[str, float] = defaultdict(float)
    total = 0.0
    sched = []
    for o in ops:
        t = o.per_chip_traffic
        by_op[o.op] += t
        total += t
        sched.append(f"{o.op} {o.operand_bytes/1e6:.2f}MB x{o.group_size}")
    return CollectiveStats(total, dict(by_op), len(ops), sched)


def analyze_compiled(compiled, chips: int) -> Dict[str, float]:
    """Extract per-device cost terms + totals from a compiled executable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    stats = collective_stats(compiled.as_text())
    ma = compiled.memory_analysis()
    out = {
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": bytes_dev,
        "collective_bytes_per_chip": stats.per_chip_bytes,
        "collective_count": stats.count,
        "collective_by_op": stats.by_op,
        "hlo_flops_total": flops_dev * chips,
        "hlo_bytes_total": bytes_dev * chips,
        "collective_bytes_total": stats.per_chip_bytes * chips,
    }
    if ma is not None:
        out.update({
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "output_bytes_per_device": int(ma.output_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "alias_bytes_per_device": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         - ma.alias_size_in_bytes
                                         + ma.temp_size_in_bytes),
        })
    return out


def combine_affine(base: Dict[str, float], per_kind: Dict[str, Dict[str, float]],
                   kind_counts: Dict[str, int],
                   keys: Tuple[str, ...] = (
                       "flops_per_device", "hbm_bytes_per_device",
                       "collective_bytes_per_chip")) -> Dict[str, float]:
    """cost(full) = cost(0 layers) + sum_k count_k * (cost(1 layer of k) -
    cost(0 layers)) — the affine extrapolation of DESIGN.md §6."""
    out = {}
    for key in keys:
        total = base.get(key, 0.0)
        for kind, counts in kind_counts.items():
            delta = per_kind[kind].get(key, 0.0) - base.get(key, 0.0)
            total += counts * delta
        out[key] = total
    return out
