from .analysis import (  # noqa: F401
    CollectiveStats,
    analyze_compiled,
    collective_stats,
    parse_collectives,
)
