"""Sharded, atomic, async-capable checkpointing.

Fault-tolerance contract (the 1000-node posture):
* every save is ATOMIC: written to ``step_XXXX.tmp/`` and renamed only
  after fsync — a crash mid-save never corrupts the latest checkpoint;
* saves are per-host SHARDED (each host writes only the leaves it owns —
  here: process 0 writes addressable shards), so no gather of the 1T-param
  state ever happens;
* ``keep`` checkpoints are retained; restore picks the newest complete one
  (a torn directory is skipped), so a node failure + restart loses at most
  one save interval;
* optional async mode ships the host copy on a background thread so the
  step loop is not blocked by the filesystem (§4.1 access extraction,
  applied to the checkpoint path).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any,
             extra: Optional[Dict[str, Any]] = None) -> Path:
        self.wait()
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(l) for l in leaves]   # device->host copy

        if self.async_save:
            t = threading.Thread(
                target=self._write, args=(step, host_leaves, extra),
                daemon=True)
            t.start()
            self._pending = t
            return self.dir / f"step_{step:08d}"
        return self._write(step, host_leaves, extra)

    def _write(self, step: int, host_leaves, extra) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "leaves.npz",
                 **{f"leaf_{i}": l for i, l in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "time": time.time(),
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # fsync the directory entry before the atomic rename
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, state_like: Any, step: Optional[int] = None
                ) -> Tuple[Any, int, Dict]:
        """Restore into the structure (and shardings) of ``state_like``.

        ``state_like`` may be a tree of arrays OR ShapeDtypeStructs with
        `.sharding` — leaves are device_put to their target sharding, so a
        checkpoint written on one mesh restores onto another (elastic
        resharding)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        data = np.load(path / "leaves.npz")
        leaves, treedef = _flatten(state_like)
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves; "
                f"state expects {len(leaves)}")
        new_leaves = []
        for i, like in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            sharding = getattr(like, "sharding", None)
            if isinstance(sharding, jax.sharding.Sharding):
                new_leaves.append(jax.device_put(arr, sharding))
            else:
                new_leaves.append(jax.numpy.asarray(arr))
        return (jax.tree_util.tree_unflatten(treedef, new_leaves),
                step, manifest.get("extra", {}))

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
