"""Pallas TPU kernels for the paper's compute hot-spots, each with
``ops.py`` (jit'd wrapper) and ``ref.py`` (pure-jnp oracle), validated in
interpret mode on CPU and targeting pl.pallas_call + BlockSpec on TPU.

Kernels mirror the paper's §6 application examples:
  matmul/    — §6.2 staged matrix multiplication (T0 naive ... T3 systolic)
  stencil/   — §6.1 4-point 2D Jacobi with delay-buffer halo BlockSpecs
  nbody/     — §6.3 tiled accumulation interleaving over resident particles
  histogram/ — §2.3 random-access buffering as one-hot MXU reduction
  attention/ — flash attention: §2.1 accumulation interleaving on softmax
  wkv/       — RWKV6 recurrence, sub-chunked MXU matmul form (§Perf-1)
"""
