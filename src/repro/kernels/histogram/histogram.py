"""Pallas histogram — random-access buffering (§2.3) without random access.

The paper's FPGA version scatters increments into an on-chip bin buffer and
breaks the read-modify-write dependency with banked partials (§2.1).  A TPU
has no scatter unit; the adaptation keeps the *structure* (on-chip partial
bins, revisited once per block) but turns the update into dataflow the
hardware has: a one-hot compare (VPU) reduced over the block (MXU-friendly
matmul with a ones-vector, here a sum over the sublane axis).  The bank
array is literally the 8-row sublane dimension: 8 partial histograms
accumulate independently (accumulation interleaving §2.1.3) and collapse
once at the end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import tpu_compiler_params


def _hist_kernel(v_ref, o_ref, acc_ref, *, n_blocks: int, n_bins: int,
                 banks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = v_ref[...]                              # (banks, bn // banks)
    # one-hot compare: (banks, bn/banks, n_bins) VPU predicate
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_bins), 2)
    onehot = (v[:, :, None] == bins).astype(jnp.int32)
    acc_ref[...] += onehot.sum(axis=1)          # (banks, n_bins) partials

    @pl.when(i == n_blocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].sum(axis=0, keepdims=True) \
            .astype(o_ref.dtype)


def histogram_pallas(values: jax.Array, n_bins: int = 256, *,
                     block: int = 2048, banks: int = 8,
                     interpret: bool = False) -> jax.Array:
    n = values.shape[0]
    block = min(block, n)
    assert n % block == 0 and block % banks == 0, (n, block, banks)
    n_blocks = n // block
    v2d = values.reshape(n_blocks * banks, block // banks)

    kernel = functools.partial(_hist_kernel, n_blocks=n_blocks,
                               n_bins=n_bins, banks=banks)
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((banks, block // banks), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_bins), jnp.int32),
        scratch_shapes=[pltpu.VMEM((banks, n_bins), jnp.int32)],
        compiler_params=tpu_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(v2d)
    return out[0]
