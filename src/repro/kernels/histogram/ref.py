"""Oracle for the histogram kernel (paper §2.3, Lst. 6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram_ref(values: jax.Array, n_bins: int = 256) -> jax.Array:
    """values: (N,) int32 in [0, n_bins) -> counts (n_bins,) int32."""
    return jnp.bincount(values, length=n_bins).astype(jnp.int32)
