"""jit'd wrapper for the histogram kernel."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ...tune.cache import resolve_plan
from ..common import interpret_default
from . import ref
from .histogram import histogram_pallas


@functools.partial(jax.jit, static_argnames=("n_bins", "level", "block",
                                             "interpret"))
def _histogram(values: jax.Array, n_bins: int, *, level: Level, block: int,
               interpret: bool) -> jax.Array:
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.histogram_ref(values, n_bins)
    n = values.shape[0]
    block = min(block, n)
    while n % block or block % 8:
        block //= 2
    return histogram_pallas(values, n_bins, block=max(block, 8),
                            interpret=interpret)


def histogram(values: jax.Array, n_bins: int = 256, *,
              level: Level = Level.T3_REPLICATED, block: int = 2048,
              plan: Union[str, dict, None] = "heuristic",
              interpret: Optional[bool] = None) -> jax.Array:
    """Histogram via one-hot MXU reduction (paper §2.3).

    ``plan`` selects the value-block size: ``"heuristic"`` (the ``block``
    argument), ``"tuned"`` (autotuner cache, heuristic on a miss), or a
    tuned kwargs dict (``block``, optional ``level``).
    """
    if interpret is None:
        interpret = interpret_default()
    level, kw = resolve_plan("histogram", (values.shape[0], n_bins),
                             values.dtype, level, plan)
    if kw:
        block = kw.get("block", block)
    return _histogram(values, n_bins, level=level, block=block,
                      interpret=interpret)


__all__ = ["histogram"]


# ------------------------------------------------------------ registration
# Tune-only OpSpec: no model dispatch surface, swept by the autotuner.
def _histogram_tune_inputs(shape, dtype):
    n, n_bins = shape
    return (jax.random.randint(jax.random.key(0), (n,), 0, n_bins, dtype),
            n_bins)


def _histogram_tune_call(args, plan):
    return histogram(*args, plan=plan)


def _register():
    from ...tune.space import histogram_space
    from .. import registry
    registry.register(registry.OpSpec(
        name="histogram",
        tune=registry.TuneSpec(
            space=histogram_space,
            make_inputs=_histogram_tune_inputs,
            call=_histogram_tune_call,
            default_dtype=jnp.int32,
            default_shapes=((1 << 14, 256), (1 << 16, 256)),
        ),
    ))


_register()
