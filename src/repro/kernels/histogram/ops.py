"""jit'd wrapper for the histogram kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ..common import interpret_default
from . import ref
from .histogram import histogram_pallas


@functools.partial(jax.jit, static_argnames=("n_bins", "level", "block",
                                             "interpret"))
def histogram(values: jax.Array, n_bins: int = 256, *,
              level: Level = Level.T3_REPLICATED, block: int = 2048,
              interpret: Optional[bool] = None) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.histogram_ref(values, n_bins)
    n = values.shape[0]
    block = min(block, n)
    while n % block or block % 8:
        block //= 2
    return histogram_pallas(values, n_bins, block=max(block, 8),
                            interpret=interpret)


__all__ = ["histogram"]
