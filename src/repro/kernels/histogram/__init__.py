from .ops import histogram  # noqa: F401
