"""Declarative op registry: one OpSpec per kernel, one generic call path.

The paper's thesis is that transformations become tractable when they are
*systematized* — a taxonomy of reusable transformations instead of ad-hoc
per-kernel rewrites (§2–§5).  FBLAS and TAPA make the same argument for
kernel *libraries*: a uniform module/interface contract over streaming
kernels is what makes the library composable and extensible.  Through
PRs 2–4 our dispatch layer grew the opposite way: every op hand-wired its
own eligibility check, reference lowering, custom VJP, route counters,
tuned-plan key, and tune-space hookup across four modules, so adding a
kernel meant a five-file scavenger hunt.

This module is the systematization.  Each op is a single :class:`OpSpec`
declaring:

* ``reference`` — the pure-XLA lowering (bit-identical to the pre-dispatch
  model code);
* ``kernel`` — the Pallas lowering (interpret mode on CPU);
* ``eligible`` — the trace-time structural predicate for the kernel route;
* ``plan_shape`` / ``plan_kernel`` — the tuned-plan key schema: the shape
  tuple this op's autotuner entries are keyed by, and (optionally) which
  kernel's plan namespace it shares (``grouped_matmul`` consults
  ``matmul`` plans);
* ``vjp_fwd`` / ``vjp_bwd`` — an optional custom-VJP pair (forward with
  residuals + backward schedule selection) wrapped generically in ONE
  ``jax.custom_vjp`` shared by every differentiable op;
* ``tune`` — a :class:`TuneSpec` (space factory, input builder, timed
  call, default shapes/dtype) the autotuner enumerates *from*, so
  ``tune.tuner`` holds no parallel op tables;
* ``stats_op`` — the route-counter scope;
* ``example`` / ``bad_example`` — a canonical dispatch-level call and a
  known-ineligible one, consumed by the registry completeness tests.

``call()`` is the one generic code path replacing the five hand-rolled
copies: eligibility → tuned-plan resolution (exact → nearest → heuristic,
tagged with its source so route counters and ``tune.cache.lookup_stats``
can never disagree) → the level gate (a tuned entry that says "the
reference lowering wins here" is honored under "auto"; an explicit
"kernels" policy forces the Pallas lowering, keeping tuned tile geometry)
→ route counting → the kernel (custom-VJP'd when declared) or reference
lowering.

Policy *resolution* (DispatchPolicy / env / backend gate) stays in
``repro.kernels.dispatch`` — the thin, backward-compatible facade layer —
which passes the collapsed ``mode`` and ``allow_kernels`` decision here.

Op modules register themselves at import; :func:`ensure_registered`
imports the known registration modules so lookups work from any entry
point (dispatch facades, the tuner, tests) without eager kernel imports.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import importlib
from collections import Counter
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax

from ..core.plan import Level
from ..tune.cache import resolve_plan_source

# Registration-module manifest (not an op table: each module declares its
# own OpSpecs; this only says where registrations live so lazy lookups can
# trigger them).  Adding a kernel = adding its ops module here.
_OP_MODULES = (
    "repro.kernels.matmul.ops",
    "repro.kernels.attention.ops",
    "repro.kernels.stencil.ops",
    "repro.kernels.histogram.ops",
    "repro.kernels.nbody.ops",
)


@dataclasses.dataclass(frozen=True)
class TuneSpec:
    """How the autotuner sweeps this op: candidate space, inputs, call."""

    space: Callable[..., list]            # (shape, dtype_bytes, **kw) -> plans
    make_inputs: Callable[..., tuple]     # (shape, dtype) -> call args
    call: Callable[..., Any]              # (args, plan_dict) -> jax value
    default_dtype: Any
    default_shapes: Tuple[Tuple[int, ...], ...]


@dataclasses.dataclass(frozen=True)
class TPContract:
    """One named way an op participates in tensor parallelism.

    The sharding contract an op declares for the mapped (``shard_map``)
    serving region: which dimension of each positional argument is
    device-local (sharded over the tensor-parallel mesh axis; ``None`` =
    replicated / identical on every device), which collective completes
    the op, and which output dimension that collective concatenates.
    ``registry.call`` applies the completing collective itself when a
    :func:`tp_scope` is active, so the mapped region's collectives live
    on exactly one code path — the same one that routes, counts, and
    plans every lowering.  Outside a tp scope the contract is inert: the
    same model code runs sharded and unsharded.

    * ``in_axes`` — per positional arg, the arg dimension sharded over
      the tp axis (``None`` = replicated).  Trailing optional args (e.g.
      quantization scales) may be omitted.
    * ``collective`` — ``"none"`` (output stays device-local, e.g. a
      column-parallel GEMM), ``"psum"`` (output is a partial sum over
      the sharded contraction — row-parallel GEMM all-reduce), or
      ``"all_gather"`` (output shards concatenate along ``gather_axis``
      — the attention ops' heads-local output becoming full-width).
    * ``gather_axis`` — output dim the ``all_gather`` concatenates.
    """

    in_axes: Tuple[Optional[int], ...] = ()
    collective: str = "none"                # none | psum | all_gather
    gather_axis: int = 0

    def __post_init__(self):
        if self.collective not in ("none", "psum", "all_gather"):
            raise ValueError(
                f"TPContract collective must be none|psum|all_gather, "
                f"got {self.collective!r}")


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One kernel's complete dispatch + tuning contract (see module doc)."""

    name: str
    reference: Optional[Callable] = None     # (ctx, *args) -> out
    kernel: Optional[Callable] = None        # (ctx, *args) -> out
    eligible: Optional[Callable] = None      # (statics, *args) -> bool
    plan_shape: Optional[Callable] = None    # (statics, *args) -> key shape
    plan_kernel: Optional[str] = None        # tuned-plan namespace (default: name)
    plan_dtype: Optional[Callable] = None    # (statics, *args) -> key dtype
    #   (default: args[0].dtype; paged-attention ops key on the POOL dtype
    #   so int8-cache plans never transplant onto bf16 pools)
    vjp_fwd: Optional[Callable] = None       # (ctx, *args) -> (out, residuals)
    vjp_bwd: Optional[Callable] = None       # (ctx, residuals, g) -> grads
    tune: Optional[TuneSpec] = None
    stats_op: Optional[str] = None           # route-counter scope (default: name)
    example: Optional[Callable] = None       # (dtype) -> (args, statics)
    bad_example: Optional[Callable] = None   # () -> (args, statics)
    # mesh-awareness: the sharding contracts this op supports inside a
    # shard_map'd serving region, keyed by the call site's ``tp=`` tag
    # ("col" | "row" | "heads" | ...).  An op with no contracts can only
    # be called untagged inside a tp scope.
    tp: Optional[Dict[str, TPContract]] = None

    @property
    def dispatchable(self) -> bool:
        return self.reference is not None


@dataclasses.dataclass(frozen=True)
class OpCtx:
    """Hashable static call context handed to every lowering callable.

    Hashability is the custom-VJP contract: the ctx rides as a nondiff
    argument through the shared ``jax.custom_vjp``, so statics and plan
    values must be hashable (ints/bools/strings/dtypes).
    """

    op: str
    mode: str                                   # kernels | reference | auto
    level: int                                  # resolved Level, as int
    plan: Tuple[Tuple[str, Any], ...] = ()      # resolved tuned kwargs
    statics: Tuple[Tuple[str, Any], ...] = ()   # op-specific static kwargs

    @property
    def kw(self) -> Dict[str, Any]:
        return dict(self.statics)

    @property
    def plan_kwargs(self) -> Dict[str, Any]:
        return dict(self.plan)

    def ops_plan(self) -> Dict[str, Any]:
        """The resolved plan as the kwargs-dict form the ``ops.py``
        wrappers accept (``plan=<dict>`` short-circuits their own cache
        lookup, so a dispatch-level call resolves the plan exactly once)."""
        return {"level": self.level, **dict(self.plan)}


# ------------------------------------------------------------ the registry
_REGISTRY: Dict[str, OpSpec] = {}
_ensured = False


def register(spec: OpSpec) -> OpSpec:
    if not isinstance(spec, OpSpec):
        raise TypeError(f"register() wants an OpSpec, got {type(spec)}")
    _REGISTRY[spec.name] = spec
    return spec


def ensure_registered() -> None:
    """Import every registration module once (idempotent, lazy).

    The flag flips only after every module imported cleanly, so a
    transient import failure is retried on the next lookup instead of
    leaving a permanently half-populated registry."""
    global _ensured
    if _ensured:
        return
    for mod in _OP_MODULES:
        importlib.import_module(mod)
    _ensured = True


def get(name: str) -> OpSpec:
    if name not in _REGISTRY:
        ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown op {name!r}; registered: {sorted(_REGISTRY)}") from None


def ops() -> Dict[str, OpSpec]:
    """All registered OpSpecs, registration order (stable)."""
    ensure_registered()
    return dict(_REGISTRY)


def dispatchable() -> Dict[str, OpSpec]:
    """Ops with a dispatch surface (a reference lowering to route against)."""
    return {n: s for n, s in ops().items() if s.dispatchable}


def tunable() -> Dict[str, OpSpec]:
    """Ops the autotuner sweeps (``tune.tuner`` enumerates from this)."""
    return {n: s for n, s in ops().items() if s.tune is not None}


# ------------------------------------------------------------------- stats
# (op, route) counters, incremented at trace time, plus (op, route, source)
# plan-source counters: ``source`` is the tuned-plan lookup route (exact |
# nearest | heuristic) that produced the routing decision, so
# ``dispatch.stats()`` and ``tune.cache.lookup_stats()`` tell one story —
# e.g. a tuned entry that picks the reference lowering shows up as
# (op, "reference", "exact"), matching the cache's exact-hit count.
_stats: Counter = Counter()
_plan_stats: Counter = Counter()
# (op, route) counters ticked ONLY while a tp_scope is active — the probe
# that proves registry.call fired INSIDE the shard_map'd serving region
# (sharded serving that silently routed outside the mapped region would
# show tp_stats() == {}).
_tp_stats: Counter = Counter()


def reset_stats() -> None:
    _stats.clear()
    _plan_stats.clear()
    _tp_stats.clear()


def stats() -> Dict[Tuple[str, str], int]:
    return dict(_stats)


def plan_source_stats() -> Dict[Tuple[str, str, str], int]:
    return dict(_plan_stats)


def tp_stats() -> Dict[Tuple[str, str], int]:
    return dict(_tp_stats)


@contextlib.contextmanager
def stats_scope():
    """Isolated counter scope: zeroed on entry, restored on exit.

    Tests and probes read routes via the yielded ``stats`` accessor without
    leaking counts into (or absorbing counts from) other test modules.
    """
    saved = Counter(_stats)
    saved_plan = Counter(_plan_stats)
    saved_tp = Counter(_tp_stats)
    reset_stats()
    try:
        yield stats
    finally:
        _stats.clear()
        _stats.update(saved)
        _plan_stats.clear()
        _plan_stats.update(saved_plan)
        _tp_stats.clear()
        _tp_stats.update(saved_tp)


def count_route(op: str, route: str, source: Optional[str] = None) -> None:
    """Public counter hook for op-declared schedules (e.g. the attention
    backward counts its own fused-vs-stash route from inside its VJP)."""
    _stats[(op, route)] += 1
    if source is not None:
        _plan_stats[(op, route, source)] += 1
    if _TP_AXIS is not None:
        _tp_stats[(op, route)] += 1


# ------------------------------------------------------ tensor-parallel scope
# The serving runtime (runtime/tp.py) enters a tp_scope while TRACING the
# shard_map body, so every registry.call issued from model code inside the
# mapped region (a) sees the mesh axis name for its declared completing
# collective and (b) ticks the tp route counters.  Like the route counters,
# this is a trace-time mechanism: jit caches replay it for free.
_TP_AXIS: Optional[str] = None


def tp_axis() -> Optional[str]:
    """The active mapped mesh axis name, or None outside a tp_scope."""
    return _TP_AXIS


@contextlib.contextmanager
def tp_scope(axis: str):
    """Mark the dynamic extent of tracing a shard_map'd serving region.

    Inside the scope, ops called with a ``tp=`` tag complete themselves
    with the collective their :class:`TPContract` declares over ``axis``;
    outside it, tags are inert annotations of the parallel structure."""
    global _TP_AXIS
    prev = _TP_AXIS
    _TP_AXIS = str(axis)
    try:
        yield
    finally:
        _TP_AXIS = prev


# ------------------------------------------------- dense-score tripwire
# Trace-time shape-assertion hook for reference attention lowerings:
# inside a ``forbid_dense_scores()`` scope, any path that would materialize
# a dense (Sq, Skv) score tensor raises instead of tracing.  Tests wrap a
# ``dispatch="kernels"`` train step in it to PROVE the fused routes carried
# the whole graph — counters say which route ran, the tripwire says no
# other route could have.
_forbid_dense = False


@contextlib.contextmanager
def forbid_dense_scores():
    global _forbid_dense
    prev = _forbid_dense
    _forbid_dense = True
    try:
        yield
    finally:
        _forbid_dense = prev


def assert_no_dense_scores(where: str, sq: int, skv: int) -> None:
    if _forbid_dense:
        raise AssertionError(
            f"dense ({sq}, {skv}) attention scores would be materialized "
            f"in {where} inside a forbid_dense_scores() scope")


# ------------------------------------------------------- the generic path
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _vjp_call(name: str, ctx: OpCtx, *args):
    return _REGISTRY[name].kernel(ctx, *args)


def _vjp_call_fwd(name: str, ctx: OpCtx, *args):
    spec = _REGISTRY[name]
    if spec.vjp_fwd is not None:
        return spec.vjp_fwd(ctx, *args)
    return spec.kernel(ctx, *args), args


def _vjp_call_bwd(name: str, ctx: OpCtx, res, g):
    return _REGISTRY[name].vjp_bwd(ctx, res, g)


_vjp_call.defvjp(_vjp_call_fwd, _vjp_call_bwd)


def _freeze(statics: Optional[Dict[str, Any]]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted((statics or {}).items(), key=lambda kv: kv[0]))


def call(name: str, *args, statics: Optional[Dict[str, Any]] = None,
         mode: str = "auto", allow_kernels: bool = False,
         tp: Optional[str] = None):
    """Route one op call: the single code path behind every dispatch facade.

    ``mode`` is the fully-resolved policy ("kernels" | "reference" |
    "auto"); ``allow_kernels`` is the facade's combined policy + backend
    gate (``mode != "reference" and (mode == "kernels" or on-TPU)``).
    Eligibility, plan resolution, the level gate, and route counting are
    generic; everything op-specific lives in the OpSpec.

    ``tp`` names one of the op's declared :class:`TPContract` sharding
    contracts ("col" | "row" | "heads" | ...).  Inside a :func:`tp_scope`
    (i.e. while tracing a shard_map'd serving region) the contract's
    completing collective runs HERE, on the op's output — keeping
    registry.call the single routing path inside the mapped region.
    Outside a scope the tag is inert, so tagged model code is
    mesh-agnostic.
    """
    spec = get(name)
    if spec.reference is None:
        raise ValueError(f"op {name!r} has no dispatch surface "
                         "(tune-only registration)")
    st = _freeze(statics)
    st_dict = dict(st)
    use_kernel = (bool(allow_kernels) and spec.kernel is not None
                  and (spec.eligible is None
                       or spec.eligible(st_dict, *args)))
    level = Level.T3_REPLICATED
    plan_kw: Dict[str, Any] = {}
    source: Optional[str] = None
    if use_kernel and spec.plan_shape is not None:
        shape = spec.plan_shape(st_dict, *args)
        key_dtype = (spec.plan_dtype(st_dict, *args)
                     if spec.plan_dtype is not None else args[0].dtype)
        level, kw, source = resolve_plan_source(
            spec.plan_kernel or name, shape, key_dtype, level, "tuned")
        plan_kw = dict(kw or {})
        if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
            # the tuned entry says the reference lowering wins here:
            # honor it under "auto" (and count the reference route,
            # tagged with the lookup source, so stats can't disagree
            # with lookup_stats); an explicit "kernels" policy forces
            # the Pallas lowering, keeping any tuned tile geometry
            if mode != "kernels":
                use_kernel = False
            else:
                level = Level.T3_REPLICATED
    route = "kernel" if use_kernel else "reference"
    count_route(spec.stats_op or name, route, source)
    ctx = OpCtx(op=name, mode=mode, level=int(level),
                plan=tuple(sorted(plan_kw.items())), statics=st)
    if use_kernel:
        if spec.vjp_bwd is not None:
            out = _vjp_call(name, ctx, *args)
        else:
            out = spec.kernel(ctx, *args)
    else:
        out = spec.reference(ctx, *args)
    if tp is not None and _TP_AXIS is not None:
        contract = (spec.tp or {}).get(tp)
        if contract is None:
            raise ValueError(
                f"op {name!r} declares no tp contract {tp!r} "
                f"(has: {sorted(spec.tp or {})}); sharded serving cannot "
                "complete this call inside the mapped region")
        if contract.collective == "psum":
            out = jax.lax.psum(out, _TP_AXIS)
        elif contract.collective == "all_gather":
            out = jax.lax.all_gather(out, _TP_AXIS,
                                     axis=contract.gather_axis, tiled=True)
    return out
