"""jit'd wrappers for flash attention and paged decode attention."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax

from ...core.plan import Level
from ...tune.cache import resolve_plan
from ..common import interpret_default
from . import ref
from .backward import flash_attention_bwd_pallas
from .decode import decode_attention_pallas, heuristic_pages_per_tile
from .flash import flash_attention_pallas


def _fit_blocks(s: int, block_q: int, block_kv: int):
    bq = min(block_q, s)
    bkv = min(block_kv, s)
    while s % bq:
        bq //= 2
    while s % bkv:
        bkv //= 2
    return bq, bkv


@functools.partial(jax.jit, static_argnames=("causal", "window", "level",
                                             "block_q", "block_kv",
                                             "return_residuals",
                                             "interpret"))
def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, window: int, level: Level,
                     block_q: int, block_kv: int, return_residuals: bool,
                     interpret: bool):
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        out = ref.attention_ref(q, k, v, causal=causal, window=window)
        if return_residuals:
            return out, ref.attention_lse_ref(q, k, causal=causal,
                                              window=window)
        return out
    bq, bkv = _fit_blocks(q.shape[2], block_q, block_kv)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=bq, block_kv=bkv,
                                  return_residuals=return_residuals,
                                  interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    level: Level = Level.T3_REPLICATED,
                    block_q: int = 512, block_kv: int = 512,
                    plan: Union[str, dict, None] = "heuristic",
                    return_residuals: bool = False,
                    interpret: Optional[bool] = None):
    """(B, H, S, hd) attention.  T0/T1 materialize (S, S); T2+ run the
    online-softmax Pallas kernel.

    ``plan`` selects the tile geometry: ``"heuristic"`` (the ``block_q``/
    ``block_kv`` arguments), ``"tuned"`` (autotuner cache, heuristic on a
    miss), or a tuned kwargs dict (``block_q``/``block_kv``, optional
    ``level``).  ``return_residuals`` additionally returns the per-row
    logsumexp (B, H, S) f32 — the forward state ``flash_attention_bwd``
    consumes.
    """
    if interpret is None:
        interpret = interpret_default()
    level, kw = resolve_plan("attention", q.shape, q.dtype, level, plan)
    if kw:
        block_q = kw.get("block_q", block_q)
        block_kv = kw.get("block_kv", block_kv)
    return _flash_attention(q, k, v, causal=causal, window=window,
                            level=level, block_q=block_q, block_kv=block_kv,
                            return_residuals=return_residuals,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "level",
                                             "block_q", "block_kv",
                                             "interpret"))
def _flash_attention_bwd(q, k, v, o, lse, do, *, causal: bool, window: int,
                         level: Level, block_q: int, block_kv: int,
                         interpret: bool):
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        # "stash" schedule: the dense-score reference VJP (materializes
        # (S, S) — exactly what it re-derives instead of recomputing
        # tiles); fine when the whole score matrix fits on chip
        _, vjp = jax.vjp(
            lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                                 window=window), q, k, v)
        return vjp(do)
    bq, bkv = _fit_blocks(q.shape[2], block_q, block_kv)
    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, o, lse, do, causal=causal, window=window, block_q=bq,
        block_kv=bkv, interpret=interpret)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def flash_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        o: jax.Array, lse: jax.Array, do: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        level: Level = Level.T3_REPLICATED,
                        block_q: int = 256, block_kv: int = 256,
                        plan: Union[str, dict, None] = "heuristic",
                        interpret: Optional[bool] = None):
    """Gradients (dq, dk, dv) of ``flash_attention`` from the saved
    residuals: ``o``/``do`` (B, H, S, hd) f32 and ``lse`` (B, H, S) f32.

    T0/T1 run the dense reference VJP (the "stash" schedule — the (S, S)
    matrix is re-derived wholesale); T2+ run the fused recompute Pallas
    kernels (``backward.py``), which never materialize (S, S).  ``plan``
    selects the backward tile geometry under kernel key
    ``flash_attention_bwd`` — the tuner's per-shape level pick IS the
    recompute-vs-stash threshold.  Gradients come back in the primal
    dtypes (custom-VJP contract).
    """
    if interpret is None:
        interpret = interpret_default()
    level, kw = resolve_plan("flash_attention_bwd", q.shape, q.dtype, level,
                             plan)
    if kw:
        block_q = kw.get("block_q", block_q)
        block_kv = kw.get("block_kv", block_kv)
    return _flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                window=window, level=level, block_q=block_q,
                                block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "level",
                                             "pages_per_tile", "interpret"))
def _decode_attention(q, k_pages, v_pages, table, lengths, *, window: int,
                      level: Level, pages_per_tile: int,
                      interpret: bool) -> jax.Array:
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.decode_attention_ref(q, k_pages, v_pages, table, lengths,
                                        window=window)
    return decode_attention_pallas(q, k_pages, v_pages, table, lengths,
                                   window=window,
                                   pages_per_tile=pages_per_tile,
                                   interpret=interpret)


def decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     table: jax.Array, lengths: jax.Array, *,
                     window: int = 0,
                     level: Level = Level.T3_REPLICATED,
                     pages_per_tile: Optional[int] = None,
                     plan: Union[str, dict, None] = "heuristic",
                     interpret: Optional[bool] = None) -> jax.Array:
    """Ragged decode attention over a paged KV cache.

    q (B, H, hd) — one query token per slot; k_pages / v_pages (P, page,
    Hkv, hd) shared page pools; table (B, n_pages) int32 logical->physical
    page ids; lengths (B,) int32 valid tokens per slot (0 = inactive slot,
    output 0).  Returns (B, H, hd) f32.  T0/T1 gather pages to a dense
    masked reference; T2+ run the scalar-prefetch Pallas kernel.

    ``plan`` selects the KV-tile geometry: ``"heuristic"`` (the
    ``pages_per_tile`` argument, default ~512-row tiles), ``"tuned"``
    (autotuner cache keyed on (B, H, n_pages, page, hd); heuristic on a
    miss), or a tuned kwargs dict (``pages_per_tile``, optional ``level``;
    ``page_size`` / ``prefetch_depth`` entries are layout / feasibility
    knobs and are ignored at call time).
    """
    if interpret is None:
        interpret = interpret_default()
    b, h, hd = q.shape
    _, page, _, _ = k_pages.shape
    n_pages = table.shape[1]
    shape = (b, h, n_pages, page, hd)
    level, kw = resolve_plan("decode_attention", shape, q.dtype, level, plan)
    if kw:
        pages_per_tile = kw.get("pages_per_tile", pages_per_tile)
    if pages_per_tile is None:
        pages_per_tile = heuristic_pages_per_tile(n_pages, page)
    return _decode_attention(q, k_pages, v_pages, table, lengths,
                             window=window, level=level,
                             pages_per_tile=int(pages_per_tile),
                             interpret=interpret)
