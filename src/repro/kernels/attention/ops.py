"""jit'd wrappers + op registrations for the attention family.

This module is the complete registry story for attention (see
``repro.kernels.registry``): the staged wrappers (``flash_attention``,
``flash_attention_bwd``, ``decode_attention``, ``prefill_attention``), the
dispatch-level reference lowerings the models route against (naive +
blockwise self-attention, paged ragged decode, paged ragged prefill), and
the ``OpSpec`` declarations wiring eligibility, tuned-plan key schemas,
the custom-VJP pair, and tune-space hookups — everything one registration
per op.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ...tune.cache import resolve_plan, resolve_plan_source
from .. import registry
from ..common import interpret_default
from . import ref
from .backward import flash_attention_bwd_pallas
from .decode import decode_attention_pallas, heuristic_pages_per_tile
from .flash import flash_attention_pallas
from .prefill import prefill_attention_pallas


def _fit_blocks(s: int, block_q: int, block_kv: int):
    bq = min(block_q, s)
    bkv = min(block_kv, s)
    while s % bq:
        bq //= 2
    while s % bkv:
        bkv //= 2
    return bq, bkv


@functools.partial(jax.jit, static_argnames=("causal", "window", "level",
                                             "block_q", "block_kv",
                                             "return_residuals",
                                             "interpret"))
def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, window: int, level: Level,
                     block_q: int, block_kv: int, return_residuals: bool,
                     interpret: bool):
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        out = ref.attention_ref(q, k, v, causal=causal, window=window)
        if return_residuals:
            return out, ref.attention_lse_ref(q, k, causal=causal,
                                              window=window)
        return out
    bq, bkv = _fit_blocks(q.shape[2], block_q, block_kv)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=bq, block_kv=bkv,
                                  return_residuals=return_residuals,
                                  interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    level: Level = Level.T3_REPLICATED,
                    block_q: int = 512, block_kv: int = 512,
                    plan: Union[str, dict, None] = "heuristic",
                    return_residuals: bool = False,
                    interpret: Optional[bool] = None):
    """(B, H, S, hd) attention.  T0/T1 materialize (S, S); T2+ run the
    online-softmax Pallas kernel.

    ``plan`` selects the tile geometry: ``"heuristic"`` (the ``block_q``/
    ``block_kv`` arguments), ``"tuned"`` (autotuner cache, heuristic on a
    miss), or a tuned kwargs dict (``block_q``/``block_kv``, optional
    ``level``).  ``return_residuals`` additionally returns the per-row
    logsumexp (B, H, S) f32 — the forward state ``flash_attention_bwd``
    consumes.
    """
    if interpret is None:
        interpret = interpret_default()
    level, kw = resolve_plan("attention", q.shape, q.dtype, level, plan)
    if kw:
        block_q = kw.get("block_q", block_q)
        block_kv = kw.get("block_kv", block_kv)
    return _flash_attention(q, k, v, causal=causal, window=window,
                            level=level, block_q=block_q, block_kv=block_kv,
                            return_residuals=return_residuals,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "level",
                                             "block_q", "block_kv",
                                             "interpret"))
def _flash_attention_bwd(q, k, v, o, lse, do, *, causal: bool, window: int,
                         level: Level, block_q: int, block_kv: int,
                         interpret: bool):
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        # "stash" schedule: the dense-score reference VJP (materializes
        # (S, S) — exactly what it re-derives instead of recomputing
        # tiles); fine when the whole score matrix fits on chip
        _, vjp = jax.vjp(
            lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                                 window=window), q, k, v)
        return vjp(do)
    bq, bkv = _fit_blocks(q.shape[2], block_q, block_kv)
    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, o, lse, do, causal=causal, window=window, block_q=bq,
        block_kv=bkv, interpret=interpret)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def flash_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        o: jax.Array, lse: jax.Array, do: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        level: Level = Level.T3_REPLICATED,
                        block_q: int = 256, block_kv: int = 256,
                        plan: Union[str, dict, None] = "heuristic",
                        interpret: Optional[bool] = None):
    """Gradients (dq, dk, dv) of ``flash_attention`` from the saved
    residuals: ``o``/``do`` (B, H, S, hd) f32 and ``lse`` (B, H, S) f32.

    T0/T1 run the dense reference VJP (the "stash" schedule — the (S, S)
    matrix is re-derived wholesale); T2+ run the fused recompute Pallas
    kernels (``backward.py``), which never materialize (S, S).  ``plan``
    selects the backward tile geometry under kernel key
    ``flash_attention_bwd`` — the tuner's per-shape level pick IS the
    recompute-vs-stash threshold.  Gradients come back in the primal
    dtypes (custom-VJP contract).
    """
    if interpret is None:
        interpret = interpret_default()
    level, kw = resolve_plan("flash_attention_bwd", q.shape, q.dtype, level,
                             plan)
    if kw:
        block_q = kw.get("block_q", block_q)
        block_kv = kw.get("block_kv", block_kv)
    return _flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                window=window, level=level, block_q=block_q,
                                block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "level",
                                             "pages_per_tile", "interpret"))
def _decode_attention(q, k_pages, v_pages, table, lengths, k_scale,
                      v_scale, *, window: int, level: Level,
                      pages_per_tile: int, interpret: bool) -> jax.Array:
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.decode_attention_ref(q, k_pages, v_pages, table, lengths,
                                        k_scale, v_scale, window=window)
    return decode_attention_pallas(q, k_pages, v_pages, table, lengths,
                                   k_scale, v_scale, window=window,
                                   pages_per_tile=pages_per_tile,
                                   interpret=interpret)


def decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     table: jax.Array, lengths: jax.Array,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None, *,
                     window: int = 0,
                     level: Level = Level.T3_REPLICATED,
                     pages_per_tile: Optional[int] = None,
                     plan: Union[str, dict, None] = "heuristic",
                     interpret: Optional[bool] = None) -> jax.Array:
    """Ragged decode attention over a paged KV cache.

    q (B, H, hd) — one query token per slot; k_pages / v_pages (P, page,
    Hkv, hd) shared page pools; table (B, n_pages) int32 logical->physical
    page ids; lengths (B,) int32 valid tokens per slot (0 = inactive slot,
    output 0).  int8 pools additionally take ``k_scale`` / ``v_scale``
    (P, Hkv) f32 per-page per-kv-head scales (in-kernel dequant, §4.4).
    Returns (B, H, hd) f32.  T0/T1 gather pages to a dense masked
    reference; T2+ run the scalar-prefetch Pallas kernel.

    ``plan`` selects the KV-tile geometry: ``"heuristic"`` (the
    ``pages_per_tile`` argument, default ~512-row tiles), ``"tuned"``
    (autotuner cache keyed on (B, H, n_pages, page, hd) and the POOL dtype
    — the dtype axis of the serving-cache design space; heuristic on a
    miss), or a tuned kwargs dict (``pages_per_tile``, optional ``level``;
    ``page_size`` / ``prefetch_depth`` entries are layout / feasibility
    knobs and are ignored at call time).
    """
    if interpret is None:
        interpret = interpret_default()
    b, h, hd = q.shape
    _, page, _, _ = k_pages.shape
    n_pages = table.shape[1]
    shape = (b, h, n_pages, page, hd)
    level, kw = resolve_plan("decode_attention", shape, k_pages.dtype,
                             level, plan)
    if kw:
        pages_per_tile = kw.get("pages_per_tile", pages_per_tile)
    if pages_per_tile is None:
        pages_per_tile = heuristic_pages_per_tile(n_pages, page)
    return _decode_attention(q, k_pages, v_pages, table, lengths,
                             k_scale, v_scale, window=window, level=level,
                             pages_per_tile=int(pages_per_tile),
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "level",
                                             "pages_per_tile", "interpret"))
def _prefill_attention(q, k_pages, v_pages, table, starts, k_scale,
                       v_scale, *, window: int, level: Level,
                       pages_per_tile: int, interpret: bool) -> jax.Array:
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.prefill_attention_ref(q, k_pages, v_pages, table, starts,
                                         k_scale, v_scale, window=window)
    return prefill_attention_pallas(q, k_pages, v_pages, table, starts,
                                    k_scale, v_scale, window=window,
                                    pages_per_tile=pages_per_tile,
                                    interpret=interpret)


def prefill_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      table: jax.Array, starts: jax.Array,
                      k_scale: Optional[jax.Array] = None,
                      v_scale: Optional[jax.Array] = None, *,
                      window: int = 0,
                      level: Level = Level.T3_REPLICATED,
                      pages_per_tile: Optional[int] = None,
                      plan: Union[str, dict, None] = "heuristic",
                      interpret: Optional[bool] = None) -> jax.Array:
    """Ragged multi-token prefill attention over a paged KV cache.

    q (B, C, H, hd) — one chunk of C prompt tokens per slot, already
    written into the pools; k_pages / v_pages (P, page, Hkv, hd) shared
    page pools; table (B, n_pages) int32 page ids; starts (B,) int32
    page-aligned chunk offsets (slot b's queries sit at positions
    ``starts[b] + [0, C)``).  int8 pools additionally take ``k_scale`` /
    ``v_scale`` (P, Hkv) f32 per-page per-kv-head scales (in-kernel
    dequant, §4.4).  Returns (B, C, H, hd) f32.  T0/T1 gather pages to a
    dense causally-masked reference; T2+ run the scalar-prefetch Pallas
    kernel with causal intra-chunk masking.

    ``plan`` selects the KV-tile geometry under kernel key
    ``prefill_attention`` (shape key (B, C, H, n_pages, page, hd) plus the
    pool dtype); same semantics as ``decode_attention``.
    """
    if interpret is None:
        interpret = interpret_default()
    b, c, h, hd = q.shape
    _, page, _, _ = k_pages.shape
    n_pages = table.shape[1]
    shape = (b, c, h, n_pages, page, hd)
    level, kw = resolve_plan("prefill_attention", shape, k_pages.dtype,
                             level, plan)
    if kw:
        pages_per_tile = kw.get("pages_per_tile", pages_per_tile)
    if pages_per_tile is None:
        pages_per_tile = heuristic_pages_per_tile(n_pages, page)
    return _prefill_attention(q, k_pages, v_pages, table, starts,
                              k_scale, v_scale, window=window, level=level,
                              pages_per_tile=int(pages_per_tile),
                              interpret=interpret)


# --------------------------------------------------------------------------
# dispatch-level reference lowerings
# --------------------------------------------------------------------------
# THE reference paths the models route against (the einsum contractions
# that used to live inline in models/layers.py, then in dispatch.py).
# ``models/layers.py`` holds no attention contraction of its own.

def causal_mask(qpos: jax.Array, kpos: jax.Array, window: int,
                causal: bool = True) -> jax.Array:
    """Branch-free causal (+ sliding window) mask — condition flattening
    (paper §2.7).  qpos (Sq,), kpos (Skv,) -> bool (Sq, Skv)."""
    if causal:
        m = kpos[None, :] <= qpos[:, None]
    else:
        m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if window > 0:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


def attention_reference(q, k, v, *, causal, window, softcap, mask,
                        accum_dtype, out_dtype):
    """Naive reference: materializes the (Sq, Skv) score tensor."""
    registry.assert_no_dense_scores("attention_reference",
                                    q.shape[1], k.shape[1])
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(accum_dtype) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is None:
        mask = causal_mask(jnp.arange(q.shape[1]), jnp.arange(k.shape[1]),
                           window, causal)[None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def attention_blockwise_reference(q, k, v, *, causal, window, softcap,
                                  accum_dtype, out_dtype, block_kv,
                                  q_splits, unroll):
    """Blockwise (flash-style) reference in pure XLA — tiled accumulation
    interleaving (§2.1.2) on the softmax reduction; never materializes
    (S, S).  Ported verbatim from the pre-dispatch model layer: q stays
    un-blocked (its sharding passes through), only K/V are tiled and
    scanned, and causality is exploited with ``q_splits`` *static*
    sequence quarters so GSPMD never sees a dynamic q loop.
    ``unroll=True`` (dry-run cost compiles) python-unrolls the KV scans so
    ``cost_analysis`` counts every tile with identical math/FLOPs."""
    b, sq, h, hd = q.shape
    block_kv = min(block_kv, sq)
    while block_kv > 1 and sq % block_kv:
        block_kv //= 2
    nkv = sq // block_kv
    scale = 1.0 / math.sqrt(hd)

    kb = jnp.moveaxis(k.reshape(b, nkv, block_kv, h, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, block_kv, h, hd), 1, 0)

    while q_splits > 1 and sq % q_splits != 0:
        q_splits //= 2
    qlen = sq // q_splits

    def kv_step(carry, kj, q_slice, qpos):
        m, l, acc = carry
        kpos = kj * block_kv + jnp.arange(block_kv)
        sc = jnp.einsum("bqhk,bshk->bhqs", q_slice,
                        jax.lax.dynamic_index_in_dim(kb, kj, 0, False)) \
            .astype(accum_dtype) * scale
        if softcap > 0:
            sc = jnp.tanh(sc / softcap) * softcap
        msk = causal_mask(qpos, kpos, window, causal)[None, None]
        sc = jnp.where(msk, sc, -1e30)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshk->bhqk", pexp.astype(out_dtype),
            jax.lax.dynamic_index_in_dim(vb, kj, 0, False)) \
            .astype(accum_dtype)
        return (m_new, l_new, acc_new)

    outs = []
    for qi in range(q_splits):
        q_lo, q_hi = qi * qlen, (qi + 1) * qlen - 1
        q_slice = jax.lax.slice_in_dim(q, q_lo, q_hi + 1, axis=1)
        qpos = jnp.arange(q_lo, q_hi + 1)
        # static KV range this quarter can see (causal upper bound,
        # window lower bound) — condition flattening at compile time
        kj_hi = min(nkv - 1, q_hi // block_kv) if causal else nkv - 1
        kj_lo = 0
        if window > 0:
            kj_lo = max(0, (q_lo - window + 1) // block_kv)
        m0 = jnp.full((b, h, qlen), -1e30, accum_dtype)
        l0 = jnp.zeros((b, h, qlen), accum_dtype)
        a0 = jnp.zeros((b, h, qlen, hd), accum_dtype)
        if unroll:
            carry = (m0, l0, a0)
            for kj in range(kj_lo, kj_hi + 1):
                carry = kv_step(carry, kj, q_slice, qpos)
            m, l, acc = carry
        else:
            def body(c, kj, _q=q_slice, _p=qpos):
                return kv_step(c, kj, _q, _p), None
            (m, l, acc), _ = jax.lax.scan(
                body, (m0, l0, a0), jnp.arange(kj_lo, kj_hi + 1))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.astype(out_dtype))       # (b, h, qlen, hd)

    out = jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]
    return jnp.moveaxis(out, 1, 2)               # (b, sq, h, hd)


def decode_attention_reference(q, k_pages, v_pages, table, lengths,
                               k_scale=None, v_scale=None, *,
                               window, softcap, accum_dtype, out_dtype):
    """Paged ragged decode reference: gather pages to a dense view
    (dequantizing int8 pools through the per-page scales), mask by
    per-slot length (and window), softmax in ``accum_dtype``.  The einsum
    lowering the paged serve path uses when the kernel route is off."""
    b, h, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    grp = h // hkv
    k = ref._gather_pages(k_pages, table, k_scale)
    v = ref._gather_pages(v_pages, table, v_scale)
    if grp > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             k.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             v.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhd,bshd->bhs", q, k).astype(accum_dtype) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    kpos = jnp.arange(k.shape[1])[None, :]
    valid = kpos < lengths[:, None]
    if window > 0:
        valid &= kpos >= lengths[:, None] - window
    scores = jnp.where(valid[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    out = jnp.einsum("bhs,bshd->bhd", probs, v)
    # inactive slots (length 0): every key masked -> exact zeros, no NaNs
    return jnp.where((lengths > 0)[:, None, None], out,
                     jnp.zeros((), out.dtype))


def prefill_attention_reference(q, k_pages, v_pages, table, starts,
                                k_scale=None, v_scale=None, *,
                                window, softcap, accum_dtype, out_dtype):
    """Paged ragged prefill reference: gather pages to a dense view
    (dequantizing int8 pools through the per-page scales), mask causally
    against each chunk's positions (and the sliding window), softmax in
    ``accum_dtype`` — numerically identical to the gather +
    naive-attention path chunked prefill took before this op existed."""
    b, c, h, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    grp = h // hkv
    registry.assert_no_dense_scores("prefill_attention_reference",
                                    c, table.shape[1] * page)
    k = ref._gather_pages(k_pages, table, k_scale)
    v = ref._gather_pages(v_pages, table, v_scale)
    if grp > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             k.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             v.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(accum_dtype) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = starts[:, None] + jnp.arange(c)[None, :]          # (B, C)
    kpos = jnp.arange(k.shape[1])                            # (S,)
    mask = kpos[None, None, :] <= qpos[:, :, None]           # (B, C, S)
    if window > 0:
        mask &= kpos[None, None, :] > qpos[:, :, None] - window
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


# --------------------------------------------------------------------------
# op registrations (repro.kernels.registry)
# --------------------------------------------------------------------------

_BHS = (0, 2, 1, 3)      # (B, S, H, hd) <-> (B, H, S, hd)


def _attention_eligible(st, q, k, v, mask) -> bool:
    if mask is not None or st["softcap"] > 0:
        return False
    if q.shape != k.shape or k.shape != v.shape:
        return False          # decode / cross-length: no self-attn kernel
    if q.shape[1] < 2:
        return False
    return all(jnp.issubdtype(t.dtype, jnp.floating) for t in (q, k, v))


def _attention_plan_shape(st, q, k, v, mask):
    return (q.shape[0], q.shape[2], q.shape[1], q.shape[3])


def _attention_ref_lowering(ctx, q, k, v, mask):
    kw = ctx.kw
    common = dict(causal=kw["causal"], window=kw["window"],
                  softcap=kw["softcap"], accum_dtype=kw["accum_dtype"],
                  out_dtype=kw["out_dtype"])
    # the blockwise lowering tiles a single self-attention length; any
    # cross-length (decode) call falls back to the naive lowering
    if kw["impl"] == "naive" or mask is not None \
            or q.shape[1] != k.shape[1]:
        return attention_reference(q, k, v, mask=mask, **common)
    return attention_blockwise_reference(
        q, k, v, block_kv=kw["block_kv"], q_splits=kw["q_splits"],
        unroll=kw["unroll"], **common)


def _attention_kernel_lowering(ctx, q, k, v, mask):
    kw = ctx.kw
    qt, kt, vt = (t.transpose(*_BHS) for t in (q, k, v))
    out = flash_attention(qt, kt, vt, causal=kw["causal"],
                          window=kw["window"], plan=ctx.ops_plan())
    return out.transpose(*_BHS).astype(kw["out_dtype"])


def _attention_vjp_fwd(ctx, q, k, v, mask):
    kw = ctx.kw
    qt, kt, vt = (t.transpose(*_BHS) for t in (q, k, v))
    o, lse = flash_attention(qt, kt, vt, causal=kw["causal"],
                             window=kw["window"], plan=ctx.ops_plan(),
                             return_residuals=True)
    out = o.transpose(*_BHS).astype(kw["out_dtype"])
    return out, (qt, kt, vt, o, lse)


def _attention_vjp_bwd(ctx, res, g):
    """Forward/backward are a paired schedule: the forward emitted per-row
    logsumexp residuals, the backward recomputes P tiles from them in the
    fused Pallas kernels (``backward.py``) — neither direction
    materializes (S, S).  The tuned ``flash_attention_bwd`` plan may route
    a shape to the dense reference VJP instead (the stash schedule); an
    explicit ``mode="kernels"`` overrides that, forcing the fused
    backward, exactly as the forward policy promises the differential
    tests."""
    qt, kt, vt, o, lse = res
    kw = ctx.kw
    causal, window = kw["causal"], kw["window"]
    # the forward's astype(out_dtype) + transpose happen inside the VJP
    # boundary, so their cotangent rules are applied by hand here
    gt = g.transpose(*_BHS).astype(jnp.float32)
    level, bkw, source = resolve_plan_source(
        "flash_attention_bwd", qt.shape, qt.dtype, Level.T3_REPLICATED,
        "tuned")
    use_fused = not (level in (Level.T0_NAIVE, Level.T1_PIPELINED)
                     and ctx.mode != "kernels")
    registry.count_route("attention_bwd",
                         "kernel" if use_fused else "reference", source)
    if use_fused:
        bkw = {k_: v_ for k_, v_ in (bkw or {}).items()
               if k_ in ("block_q", "block_kv")}
        dq, dk, dv = flash_attention_bwd(qt, kt, vt, o, lse, gt,
                                         causal=causal, window=window,
                                         plan=None, **bkw)
    else:
        registry.assert_no_dense_scores("attention reference VJP",
                                        qt.shape[2], kt.shape[2])
        _, vjp = jax.vjp(
            lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                                 window=window),
            qt, kt, vt)
        dq, dk, dv = vjp(gt)
    return (dq.transpose(*_BHS), dk.transpose(*_BHS),
            dv.transpose(*_BHS), None)


def _attention_example(dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q, k, v = (jax.random.normal(kk, (2, 8, 4, 16), dtype) for kk in ks)
    return (q, k, v), {}


def _attention_bad_example():
    # cross-length (decode-shaped) q vs k/v: structurally ineligible
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 8, 4, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 8, 4, 16), jnp.float32)
    return (q, k, v), {}


def _paged_pools_ok(q, k_pages, v_pages, k_scale, v_scale) -> bool:
    """Pool dtype contract shared by decode/prefill eligibility: floating
    pools with no scales, or int8 pools with floating (P, Hkv) scales."""
    if not jnp.issubdtype(q.dtype, jnp.floating):
        return False
    if k_scale is None:
        return all(jnp.issubdtype(t.dtype, jnp.floating)
                   for t in (k_pages, v_pages))
    if v_scale is None:
        return False
    expect = (k_pages.shape[0], k_pages.shape[2])
    return (all(t.dtype == jnp.int8 for t in (k_pages, v_pages))
            and all(jnp.issubdtype(s.dtype, jnp.floating)
                    and s.shape == expect for s in (k_scale, v_scale)))


def _decode_eligible(st, q, k_pages, v_pages, table, lengths,
                     k_scale=None, v_scale=None) -> bool:
    if st["softcap"] > 0:
        return False
    if q.shape[1] % k_pages.shape[2]:
        return False              # GQA group must divide evenly
    return _paged_pools_ok(q, k_pages, v_pages, k_scale, v_scale)


def _decode_plan_shape(st, q, k_pages, v_pages, table, lengths,
                       k_scale=None, v_scale=None):
    return (q.shape[0], q.shape[1], table.shape[1], k_pages.shape[1],
            q.shape[2])


def _paged_plan_dtype(st, q, k_pages, *rest):
    # tuned plans key on the POOL dtype (the KV-cache dtype axis): an int8
    # pool's larger feasible tiles must never transplant onto a bf16 pool
    return k_pages.dtype


def _decode_ref_lowering(ctx, q, k_pages, v_pages, table, lengths,
                         k_scale=None, v_scale=None):
    kw = ctx.kw
    return decode_attention_reference(
        q, k_pages, v_pages, table, lengths, k_scale, v_scale,
        window=kw["window"], softcap=kw["softcap"],
        accum_dtype=kw["accum_dtype"], out_dtype=kw["out_dtype"])


def _decode_kernel_lowering(ctx, q, k_pages, v_pages, table, lengths,
                            k_scale=None, v_scale=None):
    kw = ctx.kw
    out = decode_attention(q, k_pages, v_pages, table, lengths,
                           k_scale, v_scale,
                           window=kw["window"], plan=ctx.ops_plan())
    return out.astype(kw["out_dtype"])


def _paged_pool_inputs(dtype, *, slots=3, page=8, n_pages=3, h=4, hkv=2,
                       hd=16, seed=0):
    pool = 1 + slots * n_pages
    ks = jax.random.split(jax.random.key(seed), 3)
    kp = jax.random.normal(ks[1], (pool, page, hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (pool, page, hkv, hd), dtype)
    table = (1 + jax.random.permutation(jax.random.key(seed + 1), pool - 1)
             [:slots * n_pages].reshape(slots, n_pages)).astype(jnp.int32)
    return ks[0], kp, vp, table


def _decode_example(dtype):
    kq, kp, vp, table = _paged_pool_inputs(dtype)
    q = jax.random.normal(kq, (3, 4, 16), dtype)
    lengths = jnp.asarray([0, 5, 20], jnp.int32)
    return (q, kp, vp, table, lengths), {}


def _decode_bad_example():
    # softcap: the reference lowering supports it, the kernel does not —
    # eligibility must route it to the reference, not crash
    kq, kp, vp, table = _paged_pool_inputs(jnp.float32)
    q = jax.random.normal(kq, (3, 4, 16), jnp.float32)
    lengths = jnp.asarray([1, 5, 20], jnp.int32)
    return (q, kp, vp, table, lengths), {"softcap": 5.0}


def _prefill_eligible(st, q, k_pages, v_pages, table, starts,
                      k_scale=None, v_scale=None) -> bool:
    if st["softcap"] > 0:
        return False
    if q.shape[2] % k_pages.shape[2]:
        return False              # GQA group must divide evenly
    return _paged_pools_ok(q, k_pages, v_pages, k_scale, v_scale)


def _prefill_plan_shape(st, q, k_pages, v_pages, table, starts,
                        k_scale=None, v_scale=None):
    return (q.shape[0], q.shape[1], q.shape[2], table.shape[1],
            k_pages.shape[1], q.shape[3])


def _prefill_ref_lowering(ctx, q, k_pages, v_pages, table, starts,
                          k_scale=None, v_scale=None):
    kw = ctx.kw
    return prefill_attention_reference(
        q, k_pages, v_pages, table, starts, k_scale, v_scale,
        window=kw["window"], softcap=kw["softcap"],
        accum_dtype=kw["accum_dtype"], out_dtype=kw["out_dtype"])


def _prefill_kernel_lowering(ctx, q, k_pages, v_pages, table, starts,
                             k_scale=None, v_scale=None):
    kw = ctx.kw
    out = prefill_attention(q, k_pages, v_pages, table, starts,
                            k_scale, v_scale,
                            window=kw["window"], plan=ctx.ops_plan())
    return out.astype(kw["out_dtype"])


def _prefill_example(dtype):
    kq, kp, vp, table = _paged_pool_inputs(dtype, slots=2, page=8,
                                           n_pages=3)
    q = jax.random.normal(kq, (2, 8, 4, 16), dtype)
    starts = jnp.asarray([0, 8], jnp.int32)
    return (q, kp, vp, table, starts), {}


def _prefill_bad_example():
    # softcap routes to the reference lowering (kernel bakes in plain
    # scaled-dot-product only)
    kq, kp, vp, table = _paged_pool_inputs(jnp.float32, slots=2, page=8,
                                           n_pages=3)
    q = jax.random.normal(kq, (2, 8, 4, 16), jnp.float32)
    starts = jnp.asarray([0, 8], jnp.int32)
    return (q, kp, vp, table, starts), {"softcap": 5.0}


# ----------------------------------------------------- tune input builders
def _attention_tune_inputs(shape, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    return tuple(jax.random.normal(kk, shape, dtype) for kk in ks)


def _attention_tune_call(args, plan):
    return flash_attention(*args, plan=plan)


def _flash_bwd_tune_inputs(shape, dtype):
    """Backward cell: run the (reference-level) forward once to build the
    (o, lse) residuals, then time the backward candidates on a fixed
    cotangent — the sweep never times the forward."""
    ks = jax.random.split(jax.random.key(0), 4)
    q, k, v = (jax.random.normal(kk, shape, dtype) for kk in ks[:3])
    o, lse = flash_attention(q, k, v, level=Level.T1_PIPELINED, plan=None,
                             return_residuals=True)
    do = jax.random.normal(ks[3], shape, jnp.float32)
    return (q, k, v, o, lse, do)


def _flash_bwd_tune_call(args, plan):
    return flash_attention_bwd(*args, plan=plan)


def _tune_pool(key, pool, page, hkv, hd, dtype):
    """One tune-cell page pool at ``dtype``; int8 returns (pool, scales)
    through the same abs-max quantizer the serve path writes with."""
    vals = jax.random.normal(key, (pool, page, hkv, hd), jnp.float32)
    if jnp.dtype(dtype) == jnp.int8:
        from ...core.quant import quantize_pages
        return quantize_pages(vals)
    return vals.astype(dtype), None


def _decode_tune_inputs(shape, dtype):
    """Paged ragged-decode cell: a shared pool with page 0 reserved, a
    shuffled (deterministic) page table, and staggered per-slot lengths so
    the sweep times the masked-tail path the serve loop actually runs.
    ``dtype`` is the POOL dtype (the cache's dtype axis): int8 cells build
    quantized pools + scales with bf16 queries."""
    b, h, n_pages, page, hd = shape
    hkv = max(1, h // 2)                       # exercise GQA grouping
    pool = 1 + b * n_pages
    ks = jax.random.split(jax.random.key(0), 3)
    q_dtype = jnp.bfloat16 if jnp.dtype(dtype) == jnp.int8 else dtype
    q = jax.random.normal(ks[0], (b, h, hd), q_dtype)
    k_pages, k_scale = _tune_pool(ks[1], pool, page, hkv, hd, dtype)
    v_pages, v_scale = _tune_pool(ks[2], pool, page, hkv, hd, dtype)
    perm = jax.random.permutation(jax.random.key(3), pool - 1) + 1
    table = perm[:b * n_pages].reshape(b, n_pages).astype(jnp.int32)
    lengths = ((jnp.arange(b) + 1) * (n_pages * page) // b).astype(jnp.int32)
    if k_scale is None:
        return (q, k_pages, v_pages, table, lengths)
    return (q, k_pages, v_pages, table, lengths, k_scale, v_scale)


def _decode_tune_call(args, plan):
    return decode_attention(*args, plan=plan)


def _prefill_tune_inputs(shape, dtype):
    """Paged ragged-prefill cell: staggered page-aligned chunk offsets so
    the sweep times the tile-skip path (early chunks see few live tiles).
    ``dtype`` is the POOL dtype; int8 cells quantize pools + carry scales."""
    b, c, h, n_pages, page, hd = shape
    hkv = max(1, h // 2)                       # exercise GQA grouping
    pool = 1 + b * n_pages
    ks = jax.random.split(jax.random.key(0), 3)
    q_dtype = jnp.bfloat16 if jnp.dtype(dtype) == jnp.int8 else dtype
    q = jax.random.normal(ks[0], (b, c, h, hd), q_dtype)
    k_pages, k_scale = _tune_pool(ks[1], pool, page, hkv, hd, dtype)
    v_pages, v_scale = _tune_pool(ks[2], pool, page, hkv, hd, dtype)
    perm = jax.random.permutation(jax.random.key(3), pool - 1) + 1
    table = perm[:b * n_pages].reshape(b, n_pages).astype(jnp.int32)
    max_start = (n_pages * page - c) // page
    starts = ((jnp.arange(b) * max(max_start, 0)) // max(b - 1, 1)
              * page).astype(jnp.int32)
    if k_scale is None:
        return (q, k_pages, v_pages, table, starts)
    return (q, k_pages, v_pages, table, starts, k_scale, v_scale)


def _prefill_tune_call(args, plan):
    return prefill_attention(*args, plan=plan)


def _tune_specs():
    from ...tune import space
    return {
        "attention": registry.TuneSpec(
            space=space.attention_space,
            make_inputs=_attention_tune_inputs,
            call=_attention_tune_call,
            default_dtype=jnp.bfloat16,
            default_shapes=((1, 2, 128, 64), (1, 4, 256, 64)),
        ),
        "flash_attention_bwd": registry.TuneSpec(
            space=space.flash_attention_bwd_space,
            make_inputs=_flash_bwd_tune_inputs,
            call=_flash_bwd_tune_call,
            default_dtype=jnp.bfloat16,
            default_shapes=((1, 2, 128, 64), (1, 4, 256, 64)),
        ),
        # (slots, heads, n_pages, page_size, head_dim): two page-size
        # layouts so the serve scheduler's page-size pick has entries
        "decode_attention": registry.TuneSpec(
            space=space.decode_attention_space,
            make_inputs=_decode_tune_inputs,
            call=_decode_tune_call,
            default_dtype=jnp.bfloat16,
            default_shapes=((4, 4, 8, 32, 64), (4, 4, 4, 64, 64)),
        ),
        # (slots, chunk, heads, n_pages, page_size, head_dim)
        "prefill_attention": registry.TuneSpec(
            space=space.prefill_attention_space,
            make_inputs=_prefill_tune_inputs,
            call=_prefill_tune_call,
            default_dtype=jnp.bfloat16,
            default_shapes=((2, 8, 4, 4, 8, 64), (2, 16, 4, 3, 16, 64)),
        ),
    }


_TUNE = _tune_specs()

registry.register(registry.OpSpec(
    name="attention",
    reference=_attention_ref_lowering,
    kernel=_attention_kernel_lowering,
    eligible=_attention_eligible,
    plan_shape=_attention_plan_shape,
    vjp_fwd=_attention_vjp_fwd,
    vjp_bwd=_attention_vjp_bwd,
    tune=_TUNE["attention"],
    example=_attention_example,
    bad_example=_attention_bad_example,
))

# the attention backward is not a dispatch surface of its own (it is the
# VJP half of ``attention``), but it IS a tuned kernel: the per-shape
# level pick is the recompute-vs-stash threshold
registry.register(registry.OpSpec(
    name="flash_attention_bwd",
    tune=_TUNE["flash_attention_bwd"],
))

registry.register(registry.OpSpec(
    name="decode_attention",
    reference=_decode_ref_lowering,
    kernel=_decode_kernel_lowering,
    eligible=_decode_eligible,
    plan_shape=_decode_plan_shape,
    plan_dtype=_paged_plan_dtype,
    tune=_TUNE["decode_attention"],
    example=_decode_example,
    bad_example=_decode_bad_example,
    tp={
        # heads are the sharded axis: q (B, H, hd) on dim 1, K/V pools
        # (P, page, Hkv, hd) on dim 2, per-page scales (P, Hkv) on dim 1;
        # table/lengths are host metadata, replicated. Each shard attends
        # its own heads against its own pool slice, then the per-shard
        # (B, H/tp, hd) outputs all-gather back to full heads on dim 1.
        "heads": registry.TPContract(
            in_axes=(1, 2, 2, None, None, 1, 1),
            collective="all_gather",
            gather_axis=1,
        ),
    },
))

registry.register(registry.OpSpec(
    name="prefill_attention",
    reference=_prefill_ref_lowering,
    kernel=_prefill_kernel_lowering,
    eligible=_prefill_eligible,
    plan_shape=_prefill_plan_shape,
    plan_dtype=_paged_plan_dtype,
    tune=_TUNE["prefill_attention"],
    example=_prefill_example,
    bad_example=_prefill_bad_example,
    tp={
        # same layout as decode with a chunk axis: q (B, C, H, hd) sharded
        # on dim 2, pools on dim 2, scales on dim 1; gather restores full
        # heads on dim 2 of the (B, C, H/tp, hd) per-shard output.
        "heads": registry.TPContract(
            in_axes=(2, 2, 2, None, None, 1, 1),
            collective="all_gather",
            gather_axis=2,
        ),
    },
))
