"""jit'd wrapper for flash attention."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax

from ...core.plan import Level
from ...tune.cache import resolve_plan
from ..common import interpret_default
from . import ref
from .flash import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "level",
                                             "block_q", "block_kv",
                                             "interpret"))
def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, window: int, level: Level,
                     block_q: int, block_kv: int,
                     interpret: bool) -> jax.Array:
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    s = q.shape[2]
    bq = min(block_q, s)
    bkv = min(block_kv, s)
    while s % bq:
        bq //= 2
    while s % bkv:
        bkv //= 2
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=bq, block_kv=bkv,
                                  interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    level: Level = Level.T3_REPLICATED,
                    block_q: int = 512, block_kv: int = 512,
                    plan: Union[str, dict, None] = "heuristic",
                    interpret: Optional[bool] = None) -> jax.Array:
    """(B, H, S, hd) attention.  T0/T1 materialize (S, S); T2+ run the
    online-softmax Pallas kernel.

    ``plan`` selects the tile geometry: ``"heuristic"`` (the ``block_q``/
    ``block_kv`` arguments), ``"tuned"`` (autotuner cache, heuristic on a
    miss), or a tuned kwargs dict (``block_q``/``block_kv``, optional
    ``level``).
    """
    if interpret is None:
        interpret = interpret_default()
    level, kw = resolve_plan("attention", q.shape, q.dtype, level, plan)
    if kw:
        block_q = kw.get("block_q", block_q)
        block_kv = kw.get("block_kv", block_kv)
    return _flash_attention(q, k, v, causal=causal, window=window,
                            level=level, block_q=block_q, block_kv=block_kv,
                            interpret=interpret)
