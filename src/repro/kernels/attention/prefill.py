"""Pallas ragged multi-token prefill attention over a paged KV cache.

The prefill half of the serving hot path: a chunk of C query tokens of one
slot (C == the scheduler's page size) attends over that slot's cached
history *plus the chunk itself*, stored as fixed-size pages scattered
through the shared pool.  The decode kernel (``decode.py``) covers one
token per slot per step; this kernel closes the ROADMAP's "prefill chunks
still take the reference attention route" item with the same paper stack:

* memory access extraction (§4.1) — the scalar-prefetched ``table`` is
  resolved in the BlockSpec index maps, so the compute kernel only ever
  sees dense page tiles; ``starts`` rides along as the second prefetched
  scalar and parameterizes the causal window of every chunk;
* on-chip buffering (§4.2) — ``pages_per_tile`` separately pipelined page
  streams per KV tile, page fetches for tile j+1 overlapping the online-
  softmax update for tile j;
* tiled accumulation interleaving (§2.1.2) — the (C*grp, hd) accumulator
  in VMEM is revisited once per page tile with the exp(m_old - m_new)
  correction — the flash recurrence, now with C query rows per slot;
* condition flattening + tile skipping (§2.7) — causal intra-chunk
  masking is a branch-free ``where`` over (qpos, kpos) iotas; tiles wholly
  above the chunk's last position (or wholly behind its sliding window)
  are skipped with ``pl.when`` before any MXU work.

Layout: q (B, C, H, hd) — B chunked slots, GQA-grouped to (B, Hkv, C*grp,
hd) so each grid step feeds one (C*grp, page*ppt) MXU score tile;
k_pages / v_pages (P, page, Hkv, hd); table (B, n_pages) int32 page ids;
starts (B,) int32 page-aligned chunk offsets — slot b's queries sit at
positions ``starts[b] + [0, C)`` and its live KV length is
``starts[b] + C`` (the chunk was just written into its page).  Padded
tail positions inside the final chunk need no extra masking: causality
already hides them from every real query row.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import tpu_compiler_params


def _prefill_kernel(starts_ref, table_ref, *rest, n_tiles: int,
                    page: int, ppt: int, grp: int, chunk: int, window: int,
                    scale: float, quantized: bool):
    if quantized:
        k_scale_ref, v_scale_ref, q_ref, *refs = rest
    else:
        k_scale_ref = v_scale_ref = None
        q_ref, *refs = rest
    k_refs = refs[:ppt]
    v_refs = refs[ppt:2 * ppt]
    o_ref = refs[2 * ppt]
    m_ref, l_ref, acc_ref = refs[2 * ppt + 1:]
    b = pl.program_id(0)
    hh = pl.program_id(1)
    j = pl.program_id(2)

    def load_tile(refs_, scale_ref):
        # int8 pools dequantize per page stream at load time (§4.4): the
        # (page, hd) tile is widened to f32 and multiplied by its page's
        # per-kv-head scale, fetched through the same scalar-prefetch path
        # that resolved the physical page id (§4.1)
        if scale_ref is None:
            return jnp.concatenate([r[0, :, 0] for r in refs_], axis=0)
        tiles = [r[0, :, 0].astype(jnp.float32)
                 * scale_ref[table_ref[b, j * ppt + i], hh]
                 for i, r in enumerate(refs_)]
        return jnp.concatenate(tiles, axis=0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = starts_ref[b]
    kv_len = start + chunk            # history + the chunk itself
    # structural tile skip (§2.7): tile j covers kpos [k_lo, k_hi]; a tile
    # wholly above the last query position (causal) or wholly behind the
    # earliest query's window is dead before any MXU work
    k_lo = j * ppt * page
    live = k_lo < kv_len
    if window > 0:
        k_hi = k_lo + ppt * page - 1
        live = jnp.logical_and(live, k_hi > start - window)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0]                                   # (C*grp, hd)
        k = load_tile(k_refs, k_scale_ref)
        v = load_tile(v_refs, v_scale_ref)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        # row r of the flattened (C*grp) query axis is token r // grp
        qpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // grp
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= qpos               # causal: also hides padded tails
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, -1e30)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_tiles - 1)
    def _flush():
        # every query row sees at least its own position, so l > 0
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def prefill_attention_pallas(q: jax.Array, k_pages: jax.Array,
                             v_pages: jax.Array, table: jax.Array,
                             starts: jax.Array,
                             k_scale: jax.Array = None,
                             v_scale: jax.Array = None, *, window: int = 0,
                             pages_per_tile: int = 1,
                             interpret: bool = False) -> jax.Array:
    """q (B, C, H, hd); k/v_pages (P, page, Hkv, hd); table (B, n_pages);
    starts (B,) page-aligned chunk offsets.  Returns (B, C, H, hd) f32.

    int8 pools additionally take ``k_scale`` / ``v_scale`` (P, Hkv) f32
    per-page per-kv-head scales; they ride the scalar-prefetch path next
    to ``table`` and the page tiles dequantize at load time."""
    quantized = k_scale is not None
    b, c, h, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    n_pages = table.shape[1]
    assert h % hkv == 0, (h, hkv)
    grp = h // hkv
    ppt = max(1, min(pages_per_tile, n_pages))
    if n_pages % ppt:
        # pad the logical page axis with page 0; padded positions sit at
        # kpos >= kv_len for every slot and are therefore always masked
        pad = ppt - n_pages % ppt
        table = jnp.pad(table, ((0, 0), (0, pad)))
        n_pages += pad
    n_tiles = n_pages // ppt
    rows = c * grp
    # (B, C, Hkv, grp, hd) -> (B, Hkv, C*grp, hd): one MXU row block per
    # (slot, kv-head) grid cell, query tokens × GQA group flattened
    qg = q.reshape(b, c, hkv, grp, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(b, hkv, rows, hd)

    kernel = functools.partial(
        _prefill_kernel, n_tiles=n_tiles, page=page, ppt=ppt, grp=grp,
        chunk=c, window=window, scale=1.0 / math.sqrt(hd),
        quantized=quantized)

    # int8 pools prefetch two extra scalar operands (the scale tables), so
    # every index map takes a *prefetch tail of 2 or 4 refs
    def page_spec(i):
        # the i-th page stream of a KV tile: tile j holds logical pages
        # [j*ppt, (j+1)*ppt); the scalar-prefetched table resolves the
        # logical -> physical page id inside the index map (§4.1)
        return pl.BlockSpec(
            (1, page, 1, hd),
            lambda bb, hh, jj, st, tab, *_sc, i=i: (tab[bb, jj * ppt + i],
                                                    0, hh, 0))

    q_spec = pl.BlockSpec((1, 1, rows, hd),
                          lambda bb, hh, jj, st, tab, *_sc: (bb, hh, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4 if quantized else 2,
        grid=(b, hkv, n_tiles),
        in_specs=[
            q_spec,
            *[page_spec(i) for i in range(ppt)],
            *[page_spec(i) for i in range(ppt)],
        ],
        out_specs=pl.BlockSpec((1, 1, rows, hd),
                               lambda bb, hh, jj, st, tab, *_sc:
                               (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),     # running max
            pltpu.VMEM((rows, 1), jnp.float32),     # running denom
            pltpu.VMEM((rows, hd), jnp.float32),    # weighted-V acc
        ],
    )
    prefetch = (starts.astype(jnp.int32), table)
    if quantized:
        prefetch += (k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, hd), jnp.float32),
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*prefetch, qg, *([k_pages] * ppt), *([v_pages] * ppt))
    return out.reshape(b, hkv, c, grp, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(b, c, h, hd)
