from .ops import (decode_attention, flash_attention,  # noqa: F401
                  flash_attention_bwd, prefill_attention)
