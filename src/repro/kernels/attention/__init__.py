from .ops import decode_attention, flash_attention  # noqa: F401
