"""Pallas flash-attention backward — recompute-based training kernels.

The forward stores only the per-row logsumexp (``lse = m + log l``); the
backward recomputes each P tile from (q, k, lse) on the fly and folds the
softmax-gradient correction ``dS = P * (dP - delta)`` (with
``delta = rowsum(dO * O)`` precomputed once, jnp-side) into three output
accumulators — dQ, dK, dV — without ever materializing the (S, S) score
matrix.  This is §2.1 accumulation interleaving applied to the *gradient*
reduction, plus §2.7 masked tails: causal / sliding-window tile skipping is
structural (grid-index arithmetic), so dead tiles issue no MXU work.

Two kernels with independent tile geometry, per the standard TPU
formulation (different iteration orders want different blocks):

* dQ:  grid (B*H, Sq/bq, Skv/bkv), KV sequential inner — the dQ tile is
  the loop-carried accumulator, flushed when the KV sweep ends.
* dKV: grid (B*H, Skv/bkv, Sq/bq), Q sequential inner — dK and dV tiles
  are the carries, sharing one recomputed P tile per grid step, flushed
  when the Q sweep ends.

GQA grouping note: dispatch expands KV heads *before* the custom-VJP
boundary, so the per-group gradient reduction (summing dK/dV over the
query heads of one KV head) happens in the VJP of that broadcast — the
kernels always see matched head counts.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import tpu_compiler_params


def _tile_live(qi, kj, block_q: int, block_kv: int, causal: bool,
               window: int):
    """Structural liveness of the (qi, kj) tile — same §2.7 condition
    flattening the forward uses; dead tiles are skipped branch-free."""
    q_lo = qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = kj * block_kv
    k_hi = k_lo + block_kv - 1
    live = True
    if causal:
        live = k_lo <= q_hi
    if window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)
    return live, q_lo, k_lo


def _p_and_ds(q, k, v, do, lse, di, q_lo, k_lo, *, causal, window, scale):
    """Recompute one P tile from the lse residual and form dS.

    Returns (p, ds), both (bq, bkv) f32: p = exp(scale*qk^T - lse) under
    the causal/window mask, ds = p * (dP - delta) with dP = dO V^T.  The
    shared tile every accumulator update is built from.
    """
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - di[:, None])
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, di_ref, dq_ref,
               acc_ref, *, n_kv: int, block_q: int, block_kv: int,
               causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live, q_lo, k_lo = _tile_live(qi, kj, block_q, block_kv, causal, window)

    @pl.when(live)
    def _step():
        q = q_ref[0]
        k = k_ref[0]
        _, ds = _p_and_ds(q, k, v_ref[0], do_ref[0], lse_ref[0], di_ref[0],
                          q_lo, k_lo, causal=causal, window=window,
                          scale=scale)
        acc_ref[...] += jnp.dot(ds.astype(k.dtype), k,
                                preferred_element_type=jnp.float32)

    @pl.when(kj == n_kv - 1)
    def _flush():
        dq_ref[0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, di_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, n_q: int, block_q: int,
                block_kv: int, causal: bool, window: int, scale: float):
    kj = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    live, q_lo, k_lo = _tile_live(qi, kj, block_q, block_kv, causal, window)

    @pl.when(live)
    def _step():
        q = q_ref[0]
        do = do_ref[0]
        p, ds = _p_and_ds(q, k_ref[0], v_ref[0], do, lse_ref[0], di_ref[0],
                          q_lo, k_lo, causal=causal, window=window,
                          scale=scale)
        dv_acc[...] += jnp.dot(p.T.astype(do.dtype), do,
                               preferred_element_type=jnp.float32)
        dk_acc[...] += jnp.dot(ds.T.astype(q.dtype), q,
                               preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _flush():
        dk_ref[0] = (dk_acc[...] * scale).astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                               o: jax.Array, lse: jax.Array, do: jax.Array,
                               *, causal: bool = True, window: int = 0,
                               block_q: int = 256, block_kv: int = 256,
                               interpret: bool = False):
    """Fused recompute backward.  q,k,v: (B, H, S, hd); o, do: (B, H, S,
    hd) f32; lse: (B, H, S) f32.  Returns (dq, dk, dv) as f32 — callers
    cast back to the primal dtypes."""
    b, h, s, hd = q.shape
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0
    bh = b * h
    n_q = s // block_q
    n_kv = s // block_kv
    scale = 1.0 / math.sqrt(hd)

    qf, kf, vf, dof = (t.reshape(bh, s, hd) for t in (q, k, v, do))
    lsef = lse.reshape(bh, s)
    # delta = rowsum(dO * O): O(S*hd) precompute shared by both kernels
    dif = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32),
                  axis=-1).reshape(bh, s)

    q_spec = pl.BlockSpec((1, block_q, hd), lambda g, i, j: (g, i, 0))
    kv_spec = pl.BlockSpec((1, block_kv, hd), lambda g, i, j: (g, j, 0))
    row_spec = pl.BlockSpec((1, block_q), lambda g, i, j: (g, i))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, n_kv=n_kv, block_q=block_q,
                          block_kv=block_kv, causal=causal, window=window,
                          scale=scale),
        grid=(bh, n_q, n_kv),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, dif)

    # dKV sweeps Q on the inner (sequential) axis: swap the roles of the
    # index-map grid coordinates so i walks Q tiles for a fixed KV tile
    q_spec_i = pl.BlockSpec((1, block_q, hd), lambda g, j, i: (g, i, 0))
    kv_spec_i = pl.BlockSpec((1, block_kv, hd), lambda g, j, i: (g, j, 0))
    row_spec_i = pl.BlockSpec((1, block_q), lambda g, j, i: (g, i))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, n_q=n_q, block_q=block_q,
                          block_kv=block_kv, causal=causal, window=window,
                          scale=scale),
        grid=(bh, n_kv, n_q),
        in_specs=[q_spec_i, kv_spec_i, kv_spec_i, q_spec_i, row_spec_i,
                  row_spec_i],
        out_specs=[kv_spec_i, kv_spec_i],
        out_shape=[jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bh, s, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_kv, hd), jnp.float32),
                        pltpu.VMEM((block_kv, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, dif)

    shape = (b, h, s, hd)
    return dq.reshape(shape), dk.reshape(shape), dv.reshape(shape)
