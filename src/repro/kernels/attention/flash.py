"""Pallas flash attention — accumulation interleaving (§2.1) flagship.

The softmax reduction over keys is a loop-carried dependency (running max,
running denominator, weighted-value accumulator).  The online-softmax
recurrence is exactly the paper's interleaving: the (bq, hd) accumulator
tile in VMEM is revisited once per KV tile, the correction factor
exp(m_old - m_new) playing the role of the delayed write-back.  Causal
tile-skipping is done with a branch-free `when` (condition flattening §2.7):
skipped tiles never issue MXU work.

Grid: (batch*heads, Sq/bq, Skv/bkv) with the KV axis 'arbitrary'
(sequential — it carries the accumulator) and the rest 'parallel'
(replication §3.2).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import tpu_compiler_params


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *rest,
                  n_kv: int, block_q: int, block_kv: int, causal: bool,
                  window: int, scale: float, with_lse: bool):
    if with_lse:
        lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        lse_ref, (m_ref, l_ref, acc_ref) = None, rest
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal / window tile skip (structural, not data-dependent)
    q_lo = qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = kj * block_kv
    k_hi = k_lo + block_kv - 1
    live = True
    if causal:
        live = k_lo <= q_hi
    if window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _step():
        q = q_ref[0]                      # (bq, hd)
        k = k_ref[0]                      # (bkv, hd)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, -1e30)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)
        if with_lse:
            # per-row logsumexp m + log(l): the only residual the fused
            # backward needs to recompute P tiles (ISSUE: store lse, not P)
            lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
            lse_ref[...] = lse.reshape(1, block_q)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 512, block_kv: int = 512,
                           return_residuals: bool = False,
                           interpret: bool = False):
    """q,k,v: (B, H, S, hd) -> (B, H, S, hd) f32.

    With ``return_residuals`` also emits the per-row logsumexp ``lse``
    (B, H, S) f32 — the only forward state the fused recompute backward
    (``backward.py``) needs beyond q/k/v/o.
    """
    b, h, s, hd = q.shape
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0
    bh = b * h
    n_q = s // block_q
    n_kv = s // block_kv
    qf = q.reshape(bh, s, hd)
    kf = k.reshape(bh, s, hd)
    vf = v.reshape(bh, s, hd)

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, block_q=block_q, block_kv=block_kv,
        causal=causal, window=window, scale=1.0 / math.sqrt(hd),
        with_lse=return_residuals)
    out_specs = [pl.BlockSpec((1, block_q, hd), lambda g, i, j: (g, i, 0))]
    out_shape = [jax.ShapeDtypeStruct((bh, s, hd), jnp.float32)]
    if return_residuals:
        out_specs.append(pl.BlockSpec((1, block_q), lambda g, i, j: (g, i)))
        out_shape.append(jax.ShapeDtypeStruct((bh, s), jnp.float32))
    outs = pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=out_specs if return_residuals else out_specs[0],
        out_shape=out_shape if return_residuals else out_shape[0],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),   # weighted-V acc
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    if return_residuals:
        out, lse = outs
        return out.reshape(b, h, s, hd), lse.reshape(b, h, s)
    return outs.reshape(b, h, s, hd)
