"""Oracle for causal flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _gather_pages(pages: jax.Array, table: jax.Array,
                  scale: jax.Array) -> jax.Array:
    """Gather a pool's pages per slot; int8 pools (scale (P, Hkv) f32
    per-page per-kv-head) dequantize to f32 at gather time — the oracle
    twin of the kernels' in-tile dequant."""
    b = table.shape[0]
    hkv, hd = pages.shape[2], pages.shape[3]
    g = pages[table]                       # (B, n_pages, page, Hkv, hd)
    if scale is not None:
        g = g.astype(jnp.float32) * scale[table][:, :, None, :, None]
    return g.reshape(b, -1, hkv, hd)


def decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                         v_pages: jax.Array, table: jax.Array,
                         lengths: jax.Array,
                         k_scale: jax.Array = None,
                         v_scale: jax.Array = None, *,
                         window: int = 0) -> jax.Array:
    """Oracle for paged ragged decode: gather pages to a dense (B, S, Hkv,
    hd) view (dequantizing int8 pools through ``k_scale`` / ``v_scale``),
    mask key positions past each slot's length (and older than its
    window), f32 softmax.  q (B, H, hd) -> (B, H, hd) f32."""
    b, h, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    grp = h // hkv
    k = _gather_pages(k_pages, table, k_scale)       # (B, n_pages*page, ...)
    v = _gather_pages(v_pages, table, v_scale)
    if grp > 1:                                      # GQA group broadcast
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             k.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             v.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
    scores = jnp.einsum("bhd,bshd->bhs", q, k).astype(jnp.float32) \
        / math.sqrt(hd)
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = kpos < lengths[:, None]
    if window > 0:
        mask &= kpos >= lengths[:, None] - window
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs.astype(v.dtype), v)
    # fully-masked rows (inactive slots, lengths == 0) -> exact zeros
    return jnp.where((lengths > 0)[:, None, None],
                     out.astype(jnp.float32), 0.0)


def prefill_attention_ref(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, table: jax.Array,
                          starts: jax.Array,
                          k_scale: jax.Array = None,
                          v_scale: jax.Array = None, *,
                          window: int = 0) -> jax.Array:
    """Oracle for paged ragged multi-token prefill: gather pages to a
    dense (B, S, Hkv, hd) view (dequantizing int8 pools through
    ``k_scale`` / ``v_scale``), mask causally against each chunk's own
    positions (``starts[b] + [0, C)``; the chunk's own keys are already in
    the pool) and by the sliding window, f32 softmax.
    q (B, C, H, hd) -> (B, C, H, hd) f32."""
    b, c, h, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    grp = h // hkv
    k = _gather_pages(k_pages, table, k_scale)       # (B, n_pages*page, ...)
    v = _gather_pages(v_pages, table, v_scale)
    if grp > 1:                                      # GQA group broadcast
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             k.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             v.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) \
        / math.sqrt(hd)
    qpos = starts[:, None] + jnp.arange(c)[None, :]          # (B, C)
    kpos = jnp.arange(k.shape[1])                            # (S,)
    mask = kpos[None, None, :] <= qpos[:, :, None]           # (B, C, S)
    if window > 0:
        mask &= kpos[None, None, :] > qpos[:, :, None] - window
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
    return out.astype(jnp.float32)


def _masked_scores(q: jax.Array, k: jax.Array, causal: bool,
                   window: int) -> jax.Array:
    """Dense (B, H, S, S) f32 scaled scores with the causal/window mask
    applied — the one definition of the mask semantics both the forward
    oracle and the lse residual derive from."""
    s, hd = q.shape[2], q.shape[3]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return jnp.where(mask[None, None], scores, -1e30)


def attention_lse_ref(q: jax.Array, k: jax.Array, *, causal: bool = True,
                      window: int = 0) -> jax.Array:
    """Per-row logsumexp of the masked scaled scores: (B, H, S) f32.

    The residual the fused backward consumes, computed the dense way —
    used only when the forward itself ran a T0/T1 reference lowering
    (which already materialized (S, S))."""
    return jax.scipy.special.logsumexp(
        _masked_scores(q, k, causal, window), axis=-1)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q,k,v: (B, H, S, hd).  f32 softmax; returns (B, H, S, hd) f32."""
    probs = jax.nn.softmax(_masked_scores(q, k, causal, window), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd",
                      probs.astype(v.dtype), v).astype(jnp.float32)
