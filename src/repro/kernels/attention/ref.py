"""Oracle for causal flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q,k,v: (B, H, S, hd).  f32 softmax; returns (B, H, S, hd) f32."""
    b, h, s, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd",
                      probs.astype(v.dtype), v).astype(jnp.float32)
