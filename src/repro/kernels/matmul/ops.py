"""jit'd public wrapper for the staged matmul."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ...core.scaling import TilePlan, TilePlanner
from ...tune.cache import resolve_plan
from ..common import interpret_default
from . import ref
from .matmul import matmul_pallas


@functools.partial(jax.jit, static_argnames=("level", "plan", "interpret"))
def _matmul(a: jax.Array, b: jax.Array, *, level: Level,
            plan: Optional[TilePlan], interpret: bool) -> jax.Array:
    if level == Level.T0_NAIVE:
        return ref.matmul_t0_naive(a, b)
    if level == Level.T1_PIPELINED:
        return ref.matmul_ref(a, b)
    m, k = a.shape
    _, n = b.shape
    if plan is None:
        if level == Level.T2_VECTORIZED:
            plan = TilePlan(128, 128, 128, 0, (m // 128, n // 128, k // 128),
                            0.0, 0.0)
        else:
            plan = TilePlanner().plan_matmul(
                m, n, k, in_bytes=a.dtype.itemsize)
    return matmul_pallas(a, b, plan, interpret=interpret)


def matmul(a: jax.Array, b: jax.Array, *,
           level: Level = Level.T3_REPLICATED,
           plan: Union[str, dict, TilePlan, None] = "heuristic",
           interpret: Optional[bool] = None) -> jax.Array:
    """C = A @ B at a paper-§6.2 optimization stage.

    T0: naive K-loop (loop-carried dependency; measured, never used).
    T1: pipelined — XLA dot with f32 accumulation (dependency resolved by
        reduction recognition, §2.1/Tab. 2).
    T2+: Pallas kernel; BlockSpecs from the TilePlanner (T2 uses minimal
        MXU-aligned 128 blocks = vectorization only; T3 uses the VMEM-
        budget-maximal plan = +replication/tiling).

    ``plan`` selects the tile geometry: ``"heuristic"`` (TilePlanner),
    ``"tuned"`` (autotuner cache, heuristic on a miss), an explicit
    ``TilePlan``, or a tuned kwargs dict (``bm``/``bn``/``bk``, optional
    ``prefetch_depth`` and ``level``).  Resolution happens outside jit so a
    freshly tuned cache takes effect without retracing games.
    """
    if interpret is None:
        interpret = interpret_default()
    m, k = a.shape
    _, n = b.shape
    tile_plan: Optional[TilePlan] = None
    if isinstance(plan, TilePlan):
        tile_plan = plan
    else:
        level, kw = resolve_plan("matmul", (m, k, n), a.dtype, level, plan)
        if kw:
            planner = TilePlanner(
                double_buffer=kw.get("prefetch_depth", 2) >= 2)
            # clamp tiles to the problem dims: a nearest-shape plan may
            # have been tuned on a larger problem (feasibility was checked
            # against the clamped tiles, matching matmul_pallas)
            tile_plan = planner.plan_from_tiles(
                m, n, k, min(kw["bm"], m), min(kw["bn"], n),
                min(kw["bk"], k), in_bytes=a.dtype.itemsize)
    return _matmul(a, b, level=level, plan=tile_plan, interpret=interpret)
