"""jit'd public wrapper for the staged matmul."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ...core.scaling import TilePlan, TilePlanner
from ..common import interpret_default
from . import ref
from .matmul import matmul_pallas


@functools.partial(jax.jit, static_argnames=("level", "plan", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, level: Level = Level.T3_REPLICATED,
           plan: Optional[TilePlan] = None,
           interpret: Optional[bool] = None) -> jax.Array:
    """C = A @ B at a paper-§6.2 optimization stage.

    T0: naive K-loop (loop-carried dependency; measured, never used).
    T1: pipelined — XLA dot with f32 accumulation (dependency resolved by
        reduction recognition, §2.1/Tab. 2).
    T2+: Pallas kernel; BlockSpecs from the TilePlanner (T2 uses minimal
        MXU-aligned 128 blocks = vectorization only; T3 uses the VMEM-
        budget-maximal plan = +replication/tiling).
    """
    if interpret is None:
        interpret = interpret_default()
    if level == Level.T0_NAIVE:
        return ref.matmul_t0_naive(a, b)
    if level == Level.T1_PIPELINED:
        return ref.matmul_ref(a, b)
    n, k = a.shape
    _, m = b.shape
    if plan is None:
        if level == Level.T2_VECTORIZED:
            plan = TilePlan(128, 128, 128, 0, (n // 128, m // 128, k // 128),
                            0.0, 0.0)
        else:
            plan = TilePlanner().plan_matmul(
                n, m, k, in_bytes=a.dtype.itemsize)
    return matmul_pallas(a, b, plan, interpret=interpret)
