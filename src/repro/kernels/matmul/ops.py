"""jit'd public wrapper for the staged matmul + its op registrations.

This module is the complete registry story for the matmul family: the
staged ``matmul`` wrapper, and the ``OpSpec`` declarations for the
``matmul`` and ``grouped_matmul`` dispatch ops — reference lowering,
eligibility, custom-VJP pair, tuned-plan key schema, and tune-space hookup
all in one place (see ``repro.kernels.registry``).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ...core.scaling import TilePlan, TilePlanner
from ...tune.cache import resolve_plan, resolve_plan_source
from .. import registry
from ..common import interpret_default
from . import ref
from .matmul import matmul_pallas, quantized_matmul_pallas


@functools.partial(jax.jit, static_argnames=("level", "plan", "interpret"))
def _matmul(a: jax.Array, b: jax.Array, *, level: Level,
            plan: Optional[TilePlan], interpret: bool) -> jax.Array:
    if level == Level.T0_NAIVE:
        return ref.matmul_t0_naive(a, b)
    if level == Level.T1_PIPELINED:
        return ref.matmul_ref(a, b)
    m, k = a.shape
    _, n = b.shape
    if plan is None:
        if level == Level.T2_VECTORIZED:
            plan = TilePlan(128, 128, 128, 0, (m // 128, n // 128, k // 128),
                            0.0, 0.0)
        else:
            plan = TilePlanner().plan_matmul(
                m, n, k, in_bytes=a.dtype.itemsize)
    return matmul_pallas(a, b, plan, interpret=interpret)


def matmul(a: jax.Array, b: jax.Array, *,
           level: Level = Level.T3_REPLICATED,
           plan: Union[str, dict, TilePlan, None] = "heuristic",
           interpret: Optional[bool] = None) -> jax.Array:
    """C = A @ B at a paper-§6.2 optimization stage.

    T0: naive K-loop (loop-carried dependency; measured, never used).
    T1: pipelined — XLA dot with f32 accumulation (dependency resolved by
        reduction recognition, §2.1/Tab. 2).
    T2+: Pallas kernel; BlockSpecs from the TilePlanner (T2 uses minimal
        MXU-aligned 128 blocks = vectorization only; T3 uses the VMEM-
        budget-maximal plan = +replication/tiling).

    ``plan`` selects the tile geometry: ``"heuristic"`` (TilePlanner),
    ``"tuned"`` (autotuner cache, heuristic on a miss), an explicit
    ``TilePlan``, or a tuned kwargs dict (``bm``/``bn``/``bk``, optional
    ``prefetch_depth`` and ``level``).  Resolution happens outside jit so a
    freshly tuned cache takes effect without retracing games.
    """
    if interpret is None:
        interpret = interpret_default()
    m, k = a.shape
    _, n = b.shape
    tile_plan: Optional[TilePlan] = None
    if isinstance(plan, TilePlan):
        tile_plan = plan
    else:
        level, kw = resolve_plan("matmul", (m, k, n), a.dtype, level, plan)
        if kw:
            planner = TilePlanner(
                double_buffer=kw.get("prefetch_depth", 2) >= 2)
            # clamp tiles to the problem dims: a nearest-shape plan may
            # have been tuned on a larger problem (feasibility was checked
            # against the clamped tiles, matching matmul_pallas)
            tile_plan = planner.plan_from_tiles(
                m, n, k, min(kw["bm"], m), min(kw["bn"], n),
                min(kw["bk"], k), in_bytes=a.dtype.itemsize)
    return _matmul(a, b, level=level, plan=tile_plan, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("level", "plan", "interpret"))
def _quantized_matmul(a: jax.Array, b_q: jax.Array, b_scale: jax.Array, *,
                      level: Level, plan: Optional[TilePlan],
                      interpret: bool) -> jax.Array:
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.quantized_matmul_ref(a, b_q, b_scale)
    m, k = a.shape
    _, n = b_q.shape
    if plan is None:
        if level == Level.T2_VECTORIZED:
            plan = TilePlan(128, 128, 128, 0, (m // 128, n // 128, k // 128),
                            0.0, 0.0)
        else:
            plan = TilePlanner().plan_matmul(
                m, n, k, in_bytes=a.dtype.itemsize)
    return quantized_matmul_pallas(a, b_q, b_scale, plan,
                                   interpret=interpret)


def quantized_matmul(a: jax.Array, b_q: jax.Array, b_scale: jax.Array, *,
                     level: Level = Level.T3_REPLICATED,
                     plan: Union[str, dict, TilePlan, None] = "heuristic",
                     interpret: Optional[bool] = None) -> jax.Array:
    """C = A @ dequant(B) with int8 B and per-column f32 scales (§4.4).

    Same staging/plan contract as :func:`matmul`; plans live in their own
    ``"quantized_matmul"`` namespace (the int8 B tile halves the VMEM cost
    of a given geometry, so matmul entries don't transplant)."""
    if interpret is None:
        interpret = interpret_default()
    m, k = a.shape
    _, n = b_q.shape
    tile_plan: Optional[TilePlan] = None
    if isinstance(plan, TilePlan):
        tile_plan = plan
    else:
        level, kw = resolve_plan("quantized_matmul", (m, k, n), a.dtype,
                                 level, plan)
        if kw:
            planner = TilePlanner(
                double_buffer=kw.get("prefetch_depth", 2) >= 2)
            tile_plan = planner.plan_from_tiles(
                m, n, k, min(kw["bm"], m), min(kw["bn"], n),
                min(kw["bk"], k), in_bytes=a.dtype.itemsize)
    return _quantized_matmul(a, b_q, b_scale, level=level, plan=tile_plan,
                             interpret=interpret)


# --------------------------------------------------------------------------
# op registrations (repro.kernels.registry)
# --------------------------------------------------------------------------
#
# ``dispatch.matmul`` contracts the last axis of x with the first axis of
# w — the generalized form of every projection / dense / head matmul in
# the models (``bsd,dhk->bshk`` is exactly this with w pre-reshaped, so
# the reference lowering is bit-identical to the einsums it replaces).
# ``grouped_matmul`` is the MoE expert contraction: per-group matmuls over
# a static group axis, sharing the ``matmul`` tuned-plan namespace.

def _matmul_eligible(statics, x, w) -> bool:
    if x.ndim < 2 or w.ndim < 2:
        return False
    if x.shape[-1] != w.shape[0]:
        return False
    if not (jnp.issubdtype(x.dtype, jnp.floating)
            and jnp.issubdtype(w.dtype, jnp.floating)):
        return False
    m = math.prod(x.shape[:-1])
    k = x.shape[-1]
    n = math.prod(w.shape[1:])
    if min(m, k, n) < 1:
        return False
    try:          # same heuristic solver the kernel falls back to
        TilePlanner().plan_matmul(m, n, k, in_bytes=x.dtype.itemsize)
    except ValueError:
        return False
    return True


def _matmul_plan_shape(statics, x, w):
    return (math.prod(x.shape[:-1]), x.shape[-1], math.prod(w.shape[1:]))


def _matmul_reference(ctx, x, w):
    k = x.shape[-1]
    out = jnp.einsum("mk,kn->mn", x.reshape(-1, k), w.reshape(k, -1))
    return out.reshape(x.shape[:-1] + w.shape[1:])


def _matmul_kernel_lowering(ctx, x, w):
    k = x.shape[-1]
    out = matmul(x.reshape(-1, k), w.reshape(k, -1), plan=ctx.ops_plan())
    return out.astype(jnp.result_type(x, w)) \
        .reshape(x.shape[:-1] + w.shape[1:])


def _matmul_vjp_fwd(ctx, x, w):
    return _matmul_kernel_lowering(ctx, x, w), (x, w)


def _grad_gemm(a: jax.Array, b: jax.Array, mode: str) -> jax.Array:
    """One projection-grad GEMM routed like a forward matmul: resolve THIS
    shape's own tuned plan (dA and dB are transposed problems, so each
    gets its own cache entry, never the forward's), run the staged Pallas
    kernel, and count the route through the public registry hook — the
    same paired-schedule idiom as the attention backward.  Falls back to
    the f32 einsum reference only when the tuned entry pins the shape to
    T0/T1 under auto mode."""
    m, k = a.shape
    n = b.shape[1]
    level, kw, source = resolve_plan_source(
        "matmul", (m, k, n), a.dtype, Level.T3_REPLICATED, "tuned")
    use_kernel = not (level in (Level.T0_NAIVE, Level.T1_PIPELINED)
                      and mode != "kernels")
    registry.count_route("matmul_bwd",
                         "kernel" if use_kernel else "reference", source)
    if not use_kernel:
        return jnp.einsum("mk,kn->mn", a, b)
    return matmul(a, b, level=Level.T3_REPLICATED,
                  plan=(dict(kw) if kw else "heuristic"))


def _matmul_vjp_bwd(ctx, res, g):
    # backward = two plain GEMMs in f32 (dx = g @ w.T, dw = x.T @ g),
    # each dispatched through the staged tuned kernel at its own shape;
    # grads cast back to the primal dtypes (the kernel forward's f32
    # output was cast to the promoted dtype, so the cotangent casts first)
    x, w = res
    k = x.shape[-1]
    g2 = g.reshape(-1, math.prod(w.shape[1:])).astype(jnp.float32)
    x2 = x.reshape(-1, k).astype(jnp.float32)
    w2 = w.reshape(k, -1).astype(jnp.float32)
    dx = _grad_gemm(g2, w2.T, ctx.mode).astype(x.dtype).reshape(x.shape)
    dw = _grad_gemm(x2.T, g2, ctx.mode).astype(w.dtype).reshape(w.shape)
    return dx, dw


def _matmul_example(dtype):
    a = jax.random.normal(jax.random.key(0), (2, 16, 32), dtype)
    b = jax.random.normal(jax.random.key(1), (32, 24), dtype)
    return (a, b), {}


def _matmul_bad_example():
    # integer contraction: the MXU path wants floats, the einsum reference
    # handles it — eligibility must reject, not crash
    a = jax.random.randint(jax.random.key(0), (8, 16), 0, 3, jnp.int32)
    b = jax.random.randint(jax.random.key(1), (16, 8), 0, 3, jnp.int32)
    return (a, b), {}


def _grouped_eligible(statics, x, w) -> bool:
    return _matmul_eligible(statics, x[0], w[0])


def _grouped_plan_shape(statics, x, w):
    g, c, k = x.shape
    return (c, k, w.shape[2])


def _grouped_reference(ctx, x, w):
    return jnp.einsum("gck,gkn->gcn", x, w)


def _grouped_kernel_lowering(ctx, x, w):
    g = x.shape[0]
    out_dtype = jnp.result_type(x, w)
    plan = ctx.ops_plan()
    # the (static) group axis unrolls into per-expert Pallas matmuls, all
    # sharing the one plan resolved for the per-expert (c, k, n) cell
    outs = [matmul(x[e], w[e], plan=plan).astype(out_dtype)
            for e in range(g)]
    return jnp.stack(outs, axis=0)


def _grouped_vjp_fwd(ctx, x, w):
    return _grouped_kernel_lowering(ctx, x, w), (x, w)


def _grouped_vjp_bwd(ctx, res, g):
    # per-expert grads are the same two plain GEMMs as the dense matmul
    # backward, unrolled over the static group axis like the forward
    x, w = res
    g32 = g.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    n_groups = x.shape[0]
    dx = jnp.stack([_grad_gemm(g32[e], w32[e].T, ctx.mode)
                    for e in range(n_groups)]).astype(x.dtype)
    dw = jnp.stack([_grad_gemm(x32[e].T, g32[e], ctx.mode)
                    for e in range(n_groups)]).astype(w.dtype)
    return dx, dw


def _grouped_example(dtype):
    x = jax.random.normal(jax.random.key(0), (4, 8, 32), dtype)
    w = jax.random.normal(jax.random.key(1), (4, 32, 16), dtype)
    return (x, w), {}


def _grouped_bad_example():
    x = jax.random.randint(jax.random.key(0), (4, 8, 32), 0, 3, jnp.int32)
    w = jax.random.randint(jax.random.key(1), (4, 32, 16), 0, 3, jnp.int32)
    return (x, w), {}


def _quantized_eligible(statics, x, w_q, w_scale) -> bool:
    if x.ndim < 2 or w_q.ndim != 2 or w_scale.ndim != 1:
        return False
    if x.shape[-1] != w_q.shape[0] or w_scale.shape[0] != w_q.shape[1]:
        return False
    if not (jnp.issubdtype(x.dtype, jnp.floating)
            and w_q.dtype == jnp.int8
            and jnp.issubdtype(w_scale.dtype, jnp.floating)):
        return False
    m = math.prod(x.shape[:-1])
    k, n = w_q.shape
    if min(m, k, n) < 1:
        return False
    try:
        TilePlanner().plan_matmul(m, n, k, in_bytes=x.dtype.itemsize)
    except ValueError:
        return False
    return True


def _quantized_plan_shape(statics, x, w_q, w_scale):
    return (math.prod(x.shape[:-1]), x.shape[-1], w_q.shape[1])


def _quantized_reference(ctx, x, w_q, w_scale):
    k = x.shape[-1]
    out = ref.quantized_matmul_ref(x.reshape(-1, k), w_q, w_scale)
    return out.reshape(x.shape[:-1] + (w_q.shape[1],))


def _quantized_kernel_lowering(ctx, x, w_q, w_scale):
    k = x.shape[-1]
    out = quantized_matmul(x.reshape(-1, k), w_q, w_scale,
                           plan=ctx.ops_plan())
    return out.reshape(x.shape[:-1] + (w_q.shape[1],))


def _quantized_example(dtype):
    from ...core.quant import quantize_channelwise
    x = jax.random.normal(jax.random.key(0), (2, 16, 32), dtype)
    w = jax.random.normal(jax.random.key(1), (32, 24), jnp.float32)
    w_q, w_scale = quantize_channelwise(w)
    return (x, w_q, w_scale), {}


def _quantized_bad_example():
    # float weights: the point of this op is the int8 B operand — anything
    # else must route to plain ``matmul``, so eligibility rejects floats
    x = jax.random.normal(jax.random.key(0), (8, 32), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (32, 24), jnp.float32)
    scale = jnp.ones((24,), jnp.float32)
    return (x, w, scale), {}


def _quantized_tune_inputs(shape, dtype):
    from ...core.quant import quantize_channelwise
    m, k, n = shape
    a = jax.random.normal(jax.random.key(0), (m, k), dtype)
    w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
    w_q, w_scale = quantize_channelwise(w)
    return (a, w_q, w_scale)


def _quantized_tune_call(args, plan):
    return quantized_matmul(*args, plan=plan)


def _quantized_tune_spec():
    from ...tune.space import quantized_matmul_space
    return registry.TuneSpec(
        space=quantized_matmul_space,
        make_inputs=_quantized_tune_inputs,
        call=_quantized_tune_call,
        default_dtype=jnp.float32,
        default_shapes=((256, 256, 256), (384, 128, 512)),
    )


def _matmul_tune_inputs(shape, dtype):
    m, k, n = shape
    a = jax.random.normal(jax.random.key(0), (m, k), dtype)
    b = jax.random.normal(jax.random.key(1), (k, n), dtype)
    return (a, b)


def _matmul_tune_call(args, plan):
    return matmul(*args, plan=plan)


def _matmul_tune_spec():
    from ...tune.space import matmul_space
    return registry.TuneSpec(
        space=matmul_space,
        make_inputs=_matmul_tune_inputs,
        call=_matmul_tune_call,
        default_dtype=jnp.float32,
        default_shapes=((256, 256, 256), (384, 128, 512)),
    )


registry.register(registry.OpSpec(
    name="matmul",
    reference=_matmul_reference,
    kernel=_matmul_kernel_lowering,
    eligible=_matmul_eligible,
    plan_shape=_matmul_plan_shape,
    vjp_fwd=_matmul_vjp_fwd,
    vjp_bwd=_matmul_vjp_bwd,
    tune=_matmul_tune_spec(),
    example=_matmul_example,
    bad_example=_matmul_bad_example,
    tp={
        # column-parallel: weight sharded on its output dim, every device
        # computes a disjoint slice of the output features — no collective
        "col": registry.TPContract(in_axes=(None, 1)),
        # row-parallel: activations sharded on the contraction dim, weight
        # on its input dim — partial sums need a psum across the axis
        "row": registry.TPContract(in_axes=(-1, 0), collective="psum"),
    },
))

registry.register(registry.OpSpec(
    name="quantized_matmul",
    reference=_quantized_reference,
    kernel=_quantized_kernel_lowering,
    eligible=_quantized_eligible,
    plan_shape=_quantized_plan_shape,
    tune=_quantized_tune_spec(),
    example=_quantized_example,
    bad_example=_quantized_bad_example,
    # no VJP: the int8 weight operand is not differentiable — training
    # keeps float weights and routes through ``matmul``
    tp={
        # per-output-channel scales shard alongside the weight's output dim
        "col": registry.TPContract(in_axes=(None, 1, 0)),
        # row-parallel shards the contraction dim; scales stay replicated
        # (they are per-output-channel) and partial sums psum-reduce
        "row": registry.TPContract(in_axes=(-1, 0, None), collective="psum"),
    },
))

registry.register(registry.OpSpec(
    name="grouped_matmul",
    reference=_grouped_reference,
    kernel=_grouped_kernel_lowering,
    eligible=_grouped_eligible,
    plan_shape=_grouped_plan_shape,
    plan_kernel="matmul",        # shares the matmul tuned-plan namespace
    vjp_fwd=_grouped_vjp_fwd,
    vjp_bwd=_grouped_vjp_bwd,
    example=_grouped_example,
    bad_example=_grouped_bad_example,
))
