from .ops import matmul  # noqa: F401
