"""Pure-jnp oracle for the staged matmul kernels (paper §6.2).

T0 (naive) is also *expressed* here the way the paper's Lst. 1a is: an
explicit K-inner loop accumulating into one scalar-per-(n,m) register — the
loop-carried dependency the transformations remove.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array,
               acc_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with f32 accumulation — the oracle for all stages."""
    return jnp.dot(a, b, preferred_element_type=acc_dtype) \
        .astype(acc_dtype)


def quantized_matmul_ref(a: jax.Array, b_q: jax.Array,
                         b_scale: jax.Array) -> jax.Array:
    """Oracle for the int8-weight matmul: dequantize B to f32 (per-output-
    channel scales), then the usual f32-accumulated dot."""
    b = b_q.astype(jnp.float32) * b_scale.astype(jnp.float32)[None, :]
    return matmul_ref(a, b)


def matmul_t0_naive(a: jax.Array, b: jax.Array) -> jax.Array:
    """Paper Lst. 1a: K-loop with a loop-carried accumulation dependency.
    On TPU this lowers to a sequential fori_loop of rank-1 updates — the
    initiation-interval disaster the paper's §2.1 removes.  Kept tiny-only
    (benchmarks use small shapes); exists to *measure* the T0 stage."""
    n, k = a.shape
    k2, m = b.shape
    assert k == k2

    def body(i, acc):
        return acc + jnp.outer(a[:, i], b[i, :])

    return jax.lax.fori_loop(
        0, k, body, jnp.zeros((n, m), jnp.float32))
