"""Kernel dispatch: the single entry point models use for hot contractions.

The paper's transformations only pay off when the *whole* dataflow graph
runs through the transformed kernels (FBLAS's module-routing argument): a
tuned Pallas matmul buys nothing while the surrounding projections still
lower through raw einsums.  This module is the routing layer that closes
that gap — ``dispatch.matmul`` / ``dispatch.attention`` /
``dispatch.grouped_matmul`` consult the tuned-plan cache (exact key first,
then nearest-shape, see ``repro.tune.cache``) and route each call to the
Pallas kernel or to the pure-jnp reference lowering based on policy and
shape/dtype/backend eligibility.

Policy (the ``DispatchPolicy`` knob threaded through ``configs/base.py``):

  "kernels"   — force the Pallas path whenever structurally possible
                (interpret mode on CPU); used by the differential tests
  "reference" — force the einsum reference lowering; bitwise-identical to
                the pre-dispatch model code
  "auto"      — kernels on TPU when eligible, reference otherwise (CPU HLO
                interpretation of a Pallas kernel is never a win); the
                ``REPRO_DISPATCH`` env var can override "auto" globally

Eligibility is decided at trace time (shapes are static), so the decision
costs nothing at run time.  Matmul kernel paths carry a ``jax.custom_vjp``
whose backward is the reference contraction; the attention kernel path
pairs the flash forward (which emits per-row logsumexp residuals) with the
fused recompute Pallas backward (``attention/backward.py``) so a
``dispatch="kernels"`` train step never materializes the (S, S) score
matrix in either direction — the tuned ``flash_attention_bwd`` plan can
still route small shapes to the dense reference VJP (the stash schedule)
under "auto".  Per-route counters (``stats()``) let regression tests prove
the serve/train graphs actually flow through dispatch, and the
``forbid_dense_scores()`` scope turns any dense-score lowering into a
trace-time assertion for those tests.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import os
from collections import Counter
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from ..core.scaling import TilePlanner

MODES = ("kernels", "reference", "auto")


@dataclasses.dataclass(frozen=True)
class DispatchPolicy:
    """Routing policy: "kernels" | "reference" | "auto"."""

    mode: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"dispatch mode must be one of {MODES}, got {self.mode!r}")


PolicyLike = Union[DispatchPolicy, str, None]

# module default consulted when a call site passes policy=None/"auto";
# seeded from the environment so launchers can force a path globally.
_default_mode: Optional[str] = None


def default_mode() -> str:
    global _default_mode
    if _default_mode is None:
        env = os.environ.get("REPRO_DISPATCH", "auto")
        _default_mode = env if env in MODES else "auto"
    return _default_mode


def set_default_mode(mode: str) -> None:
    DispatchPolicy(mode)          # validate
    global _default_mode
    _default_mode = mode


@contextlib.contextmanager
def policy_scope(mode: str):
    """Temporarily force the module-default mode (tests, dry-runs)."""
    prev = default_mode()
    set_default_mode(mode)
    try:
        yield
    finally:
        set_default_mode(prev)


def resolve_mode(policy: PolicyLike) -> str:
    """Collapse a call-site policy to "kernels" | "reference" | "auto"."""
    if policy is None:
        mode = "auto"
    elif isinstance(policy, DispatchPolicy):
        mode = policy.mode
    else:
        mode = str(policy)
        DispatchPolicy(mode)      # validate
    if mode == "auto":
        mode = default_mode()
    return mode


def _kernels_by_default() -> bool:
    """auto-mode backend gate: compiled Pallas on TPU is a win; HLO
    interpretation of the same kernel on CPU/GPU is never one."""
    return jax.default_backend() == "tpu"


# ------------------------------------------------------------------- stats
# (op, route) counters, incremented at trace time.  Regression tests reset
# them, run a serve/train step, and assert the kernel routes were taken —
# so a refactor cannot silently drop the models back to raw einsums.
_stats: Counter = Counter()


def reset_stats() -> None:
    _stats.clear()


def stats() -> Dict[Tuple[str, str], int]:
    return dict(_stats)


@contextlib.contextmanager
def stats_scope():
    """Isolated counter scope: zeroed on entry, restored on exit.

    Tests and probes read routes via the yielded ``stats`` accessor without
    leaking counts into (or absorbing counts from) other test modules.
    """
    saved = Counter(_stats)
    _stats.clear()
    try:
        yield stats
    finally:
        _stats.clear()
        _stats.update(saved)


def _count(op: str, route: str) -> None:
    _stats[(op, route)] += 1


# ------------------------------------------------- dense-score tripwire
# Trace-time shape-assertion hook for the reference attention lowerings:
# inside a ``forbid_dense_scores()`` scope, any path that would materialize
# a dense (Sq, Skv) score tensor raises instead of tracing.  Tests wrap a
# ``dispatch="kernels"`` train step in it to PROVE the fused routes carried
# the whole graph — counters say which route ran, the tripwire says no
# other route could have.
_forbid_dense = False


@contextlib.contextmanager
def forbid_dense_scores():
    global _forbid_dense
    prev = _forbid_dense
    _forbid_dense = True
    try:
        yield
    finally:
        _forbid_dense = prev


def _assert_no_dense_scores(where: str, sq: int, skv: int) -> None:
    if _forbid_dense:
        raise AssertionError(
            f"dense ({sq}, {skv}) attention scores would be materialized "
            f"in {where} inside a forbid_dense_scores() scope")


# ------------------------------------------------------------------ matmul
def _matmul_eligible(x: jax.Array, w: jax.Array) -> bool:
    if x.ndim < 2 or w.ndim < 2:
        return False
    if x.shape[-1] != w.shape[0]:
        return False
    if not (jnp.issubdtype(x.dtype, jnp.floating)
            and jnp.issubdtype(w.dtype, jnp.floating)):
        return False
    m = math.prod(x.shape[:-1])
    k = x.shape[-1]
    n = math.prod(w.shape[1:])
    if min(m, k, n) < 1:
        return False
    try:          # same heuristic solver the ops wrapper falls back to
        TilePlanner().plan_matmul(m, n, k, in_bytes=x.dtype.itemsize)
    except ValueError:
        return False
    return True


@jax.custom_vjp
def _matmul_kernel(a: jax.Array, b: jax.Array) -> jax.Array:
    """2-D Pallas matmul with tuned-plan lookup; f32 output."""
    from .matmul.ops import matmul as matmul_op
    return matmul_op(a, b, plan="tuned")


def _matmul_kernel_fwd(a, b):
    return _matmul_kernel(a, b), (a, b)


def _matmul_kernel_bwd(res, g):
    a, b = res
    da = jnp.einsum("mn,kn->mk", g, b).astype(a.dtype)
    db = jnp.einsum("mk,mn->kn", a, g).astype(b.dtype)
    return da, db


_matmul_kernel.defvjp(_matmul_kernel_fwd, _matmul_kernel_bwd)


def matmul(x: jax.Array, w: jax.Array, *,
           policy: PolicyLike = None) -> jax.Array:
    """Contract the last axis of ``x`` with the first axis of ``w``.

    x: (..., K); w: (K, N1[, N2, ...]).  Returns x.shape[:-1] + w.shape[1:]
    in the promoted input dtype — the generalized form of every projection
    / dense / head matmul in the models (``bsd,dhk->bshk`` is exactly this
    with w pre-reshaped, so the reference lowering is bit-identical to the
    einsums it replaces).
    """
    out_shape = x.shape[:-1] + w.shape[1:]
    out_dtype = jnp.result_type(x, w)
    mode = resolve_mode(policy)
    # backend gate first: skip the tile enumeration on reference-bound paths
    use_kernel = (mode != "reference"
                  and (mode == "kernels" or _kernels_by_default())
                  and _matmul_eligible(x, w))
    _count("matmul", "kernel" if use_kernel else "reference")
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    w2 = w.reshape(k, -1)
    if use_kernel:
        out = _matmul_kernel(x2, w2).astype(out_dtype)
    else:
        out = jnp.einsum("mk,kn->mn", x2, w2)
    return out.reshape(out_shape)


def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   policy: PolicyLike = None) -> jax.Array:
    """Per-group matmul: x (G, C, K) x w (G, K, N) -> (G, C, N).

    The MoE expert contraction.  The kernel route unrolls the (static)
    group axis into per-expert Pallas matmuls; the reference route is the
    batched einsum the MoE layer always used.
    """
    g, c, k = x.shape
    _, _, n = w.shape
    mode = resolve_mode(policy)
    use_kernel = (mode != "reference"
                  and (mode == "kernels" or _kernels_by_default())
                  and _matmul_eligible(x[0], w[0]))
    _count("grouped_matmul", "kernel" if use_kernel else "reference")
    if use_kernel:
        out_dtype = jnp.result_type(x, w)
        outs = [_matmul_kernel(x[e], w[e]).astype(out_dtype)
                for e in range(g)]
        return jnp.stack(outs, axis=0)
    return jnp.einsum("gck,gkn->gcn", x, w)


# --------------------------------------------------------------- attention
def causal_mask(qpos: jax.Array, kpos: jax.Array, window: int,
                causal: bool = True) -> jax.Array:
    """Branch-free causal (+ sliding window) mask — condition flattening
    (paper §2.7).  qpos (Sq,), kpos (Skv,) -> bool (Sq, Skv)."""
    if causal:
        m = kpos[None, :] <= qpos[:, None]
    else:
        m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if window > 0:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


def _attention_reference(q, k, v, *, causal, window, softcap, mask,
                         accum_dtype, out_dtype):
    """Naive reference: materializes the (Sq, Skv) score tensor.

    This is THE dispatch reference path for attention — the einsum
    contractions the models used inline now live here (and in the
    blockwise variant below), so ``models/layers.py`` holds no attention
    contraction of its own.
    """
    _assert_no_dense_scores("_attention_reference", q.shape[1], k.shape[1])
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(accum_dtype) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is None:
        mask = causal_mask(jnp.arange(q.shape[1]), jnp.arange(k.shape[1]),
                           window, causal)[None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def _attention_blockwise_reference(q, k, v, *, causal, window, softcap,
                                   accum_dtype, out_dtype, block_kv,
                                   q_splits, unroll):
    """Blockwise (flash-style) reference in pure XLA — tiled accumulation
    interleaving (§2.1.2) on the softmax reduction; never materializes
    (S, S).  Ported verbatim from the pre-dispatch model layer: q stays
    un-blocked (its sharding passes through), only K/V are tiled and
    scanned, and causality is exploited with ``q_splits`` *static*
    sequence quarters so GSPMD never sees a dynamic q loop.
    ``unroll=True`` (dry-run cost compiles) python-unrolls the KV scans so
    ``cost_analysis`` counts every tile with identical math/FLOPs."""
    b, sq, h, hd = q.shape
    block_kv = min(block_kv, sq)
    while block_kv > 1 and sq % block_kv:
        block_kv //= 2
    nkv = sq // block_kv
    scale = 1.0 / math.sqrt(hd)

    kb = jnp.moveaxis(k.reshape(b, nkv, block_kv, h, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, block_kv, h, hd), 1, 0)

    while q_splits > 1 and sq % q_splits != 0:
        q_splits //= 2
    qlen = sq // q_splits

    def kv_step(carry, kj, q_slice, qpos):
        m, l, acc = carry
        kpos = kj * block_kv + jnp.arange(block_kv)
        sc = jnp.einsum("bqhk,bshk->bhqs", q_slice,
                        jax.lax.dynamic_index_in_dim(kb, kj, 0, False)) \
            .astype(accum_dtype) * scale
        if softcap > 0:
            sc = jnp.tanh(sc / softcap) * softcap
        msk = causal_mask(qpos, kpos, window, causal)[None, None]
        sc = jnp.where(msk, sc, -1e30)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshk->bhqk", pexp.astype(out_dtype),
            jax.lax.dynamic_index_in_dim(vb, kj, 0, False)) \
            .astype(accum_dtype)
        return (m_new, l_new, acc_new)

    outs = []
    for qi in range(q_splits):
        q_lo, q_hi = qi * qlen, (qi + 1) * qlen - 1
        q_slice = jax.lax.slice_in_dim(q, q_lo, q_hi + 1, axis=1)
        qpos = jnp.arange(q_lo, q_hi + 1)
        # static KV range this quarter can see (causal upper bound,
        # window lower bound) — condition flattening at compile time
        kj_hi = min(nkv - 1, q_hi // block_kv) if causal else nkv - 1
        kj_lo = 0
        if window > 0:
            kj_lo = max(0, (q_lo - window + 1) // block_kv)
        m0 = jnp.full((b, h, qlen), -1e30, accum_dtype)
        l0 = jnp.zeros((b, h, qlen), accum_dtype)
        a0 = jnp.zeros((b, h, qlen, hd), accum_dtype)
        if unroll:
            carry = (m0, l0, a0)
            for kj in range(kj_lo, kj_hi + 1):
                carry = kv_step(carry, kj, q_slice, qpos)
            m, l, acc = carry
        else:
            def body(c, kj, _q=q_slice, _p=qpos):
                return kv_step(c, kj, _q, _p), None
            (m, l, acc), _ = jax.lax.scan(
                body, (m0, l0, a0), jnp.arange(kj_lo, kj_hi + 1))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.astype(out_dtype))       # (b, h, qlen, hd)

    out = jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]
    return jnp.moveaxis(out, 1, 2)               # (b, sq, h, hd)


def _attention_eligible(q, k, v, *, softcap, mask) -> bool:
    if mask is not None or softcap > 0:
        return False
    if q.shape != k.shape or k.shape != v.shape:
        return False          # decode / cross-length: no self-attn kernel
    if q.shape[1] < 2:
        return False
    return all(jnp.issubdtype(t.dtype, jnp.floating) for t in (q, k, v))


def _flash_ref(q, k, v, causal, window):
    from .attention.ref import attention_ref
    return attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _attn_kernel(causal, window, mode, q, k, v):
    """(B, H, S, hd) flash attention with tuned-plan lookup; f32 output.

    Forward/backward are a paired schedule: the forward emits per-row
    logsumexp residuals, the backward recomputes P tiles from them in the
    fused Pallas kernels (``attention/backward.py``) — neither direction
    materializes (S, S).  The tuned ``flash_attention_bwd`` plan may route
    a shape to the dense reference VJP instead (the stash schedule); an
    explicit ``mode="kernels"`` overrides that, forcing the fused
    backward, exactly as the forward policy promises the differential
    tests."""
    from .attention.ops import flash_attention
    return flash_attention(q, k, v, causal=causal, window=window,
                           plan="tuned")


def _attn_kernel_fwd(causal, window, mode, q, k, v):
    from .attention.ops import flash_attention
    o, lse = flash_attention(q, k, v, causal=causal, window=window,
                             plan="tuned", return_residuals=True)
    return o, (q, k, v, o, lse)


def _attn_kernel_bwd(causal, window, mode, res, g):
    q, k, v, o, lse = res
    from ..core.plan import Level
    from ..tune.cache import resolve_plan
    level, kw = resolve_plan("flash_attention_bwd", q.shape, q.dtype,
                             Level.T3_REPLICATED, "tuned")
    use_fused = not (level in (Level.T0_NAIVE, Level.T1_PIPELINED)
                     and mode != "kernels")
    _count("attention_bwd", "kernel" if use_fused else "reference")
    if use_fused:
        from .attention.ops import flash_attention_bwd
        bkw = {k_: v_ for k_, v_ in (kw or {}).items()
               if k_ in ("block_q", "block_kv")}
        return flash_attention_bwd(q, k, v, o, lse, g, causal=causal,
                                   window=window, plan=None, **bkw)
    _assert_no_dense_scores("_attn_kernel_bwd reference VJP",
                            q.shape[2], k.shape[2])
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _flash_ref(q_, k_, v_, causal, window), q, k, v)
    return vjp(g)


_attn_kernel.defvjp(_attn_kernel_fwd, _attn_kernel_bwd)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0, softcap: float = 0.0,
              mask: Optional[jax.Array] = None,
              accum_dtype: Any = jnp.float32,
              out_dtype: Any = None,
              impl: str = "blockwise",
              block_kv: int = 512, q_splits: int = 4, unroll: bool = False,
              policy: PolicyLike = None) -> jax.Array:
    """Scaled-dot-product attention over model-layout tensors.

    q: (B, Sq, H, hd); k, v: (B, Skv, H, hd), already GQA-expanded.
    Returns (B, Sq, H, hd) in ``out_dtype`` (default: q's dtype).

    ``mask`` (broadcastable to (B, H, Sq, Skv)) overrides the causal/window
    mask — used by the decode path's rolling-cache validity mask, and
    always routed to the reference (the kernel bakes in causal/window
    only).  ``impl`` picks the reference lowering on the reference route:
    "naive" materializes (Sq, Skv); "blockwise" is the tiled XLA
    formulation (with ``block_kv`` / ``q_splits`` / ``unroll``).
    """
    out_dtype = q.dtype if out_dtype is None else out_dtype
    mode = resolve_mode(policy)
    use_kernel = (mode != "reference"
                  and (mode == "kernels" or _kernels_by_default())
                  and _attention_eligible(q, k, v, softcap=softcap,
                                          mask=mask))
    _count("attention", "kernel" if use_kernel else "reference")
    if use_kernel:
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        out = _attn_kernel(bool(causal), int(window), mode, qt, kt, vt)
        return out.transpose(0, 2, 1, 3).astype(out_dtype)
    # the blockwise lowering tiles a single self-attention length; any
    # cross-length (decode) call falls back to the naive lowering
    if impl == "naive" or mask is not None or q.shape[1] != k.shape[1]:
        return _attention_reference(
            q, k, v, causal=causal, window=window, softcap=softcap,
            mask=mask, accum_dtype=accum_dtype, out_dtype=out_dtype)
    return _attention_blockwise_reference(
        q, k, v, causal=causal, window=window, softcap=softcap,
        accum_dtype=accum_dtype, out_dtype=out_dtype, block_kv=block_kv,
        q_splits=q_splits, unroll=unroll)


# --------------------------------------------------------- decode attention
def _decode_attention_reference(q, k_pages, v_pages, table, lengths, *,
                                window, softcap, accum_dtype, out_dtype):
    """Paged ragged decode reference: gather pages to a dense view, mask by
    per-slot length (and window), softmax in ``accum_dtype``.  The einsum
    lowering the paged serve path uses when the kernel route is off."""
    b, h, hd = q.shape
    _, page, hkv, _ = k_pages.shape
    grp = h // hkv
    k = k_pages[table].reshape(b, -1, hkv, hd)
    v = v_pages[table].reshape(b, -1, hkv, hd)
    if grp > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             k.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             v.shape[:3] + (grp, hd)).reshape(b, -1, h, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhd,bshd->bhs", q, k).astype(accum_dtype) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    kpos = jnp.arange(k.shape[1])[None, :]
    valid = kpos < lengths[:, None]
    if window > 0:
        valid &= kpos >= lengths[:, None] - window
    scores = jnp.where(valid[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    out = jnp.einsum("bhs,bshd->bhd", probs, v)
    # inactive slots (length 0): every key masked -> exact zeros, no NaNs
    return jnp.where((lengths > 0)[:, None, None], out,
                     jnp.zeros((), out.dtype))


def _decode_eligible(q, k_pages, v_pages, *, softcap) -> bool:
    if softcap > 0:
        return False
    if q.shape[1] % k_pages.shape[2]:
        return False              # GQA group must divide evenly
    return all(jnp.issubdtype(t.dtype, jnp.floating)
               for t in (q, k_pages, v_pages))


def decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     table: jax.Array, lengths: jax.Array, *,
                     window: int = 0, softcap: float = 0.0,
                     accum_dtype: Any = jnp.float32,
                     out_dtype: Any = None,
                     policy: PolicyLike = None) -> jax.Array:
    """Ragged decode attention over a paged KV cache — the serving hot path.

    q (B, H, hd) one query token per slot; k_pages / v_pages (P, page,
    Hkv, hd) shared pools; table (B, n_pages) logical->physical page ids;
    lengths (B,) valid tokens per slot (0 = inactive -> zero output).
    Returns (B, H, hd) in ``out_dtype`` (default q's dtype).  Inference
    only — no custom VJP; the kernel route consults the tuned-plan cache
    for KV-tile geometry (``plan="tuned"``).
    """
    out_dtype = q.dtype if out_dtype is None else out_dtype
    mode = resolve_mode(policy)
    use_kernel = (mode != "reference"
                  and (mode == "kernels" or _kernels_by_default())
                  and _decode_eligible(q, k_pages, v_pages, softcap=softcap))
    pages_per_tile = None
    if use_kernel:
        # resolve the tuned plan HERE so the route counter stays honest: a
        # tuned entry may say the reference lowering wins on this backend
        # (level <= T1), in which case "auto" honors it and counts the
        # reference route — while an explicit "kernels" override forces
        # the Pallas lowering (keeping any tuned tile geometry), as the
        # policy docstring promises the differential tests
        from ..core.plan import Level
        from ..tune.cache import resolve_plan
        shape = (q.shape[0], q.shape[1], table.shape[1], k_pages.shape[1],
                 q.shape[2])
        level, kw = resolve_plan("decode_attention", shape, q.dtype,
                                 Level.T3_REPLICATED, "tuned")
        pages_per_tile = (kw or {}).get("pages_per_tile")
        if level in (Level.T0_NAIVE, Level.T1_PIPELINED) \
                and mode != "kernels":
            use_kernel = False
    _count("decode_attention", "kernel" if use_kernel else "reference")
    if use_kernel:
        from .attention.ops import decode_attention as decode_op
        out = decode_op(q, k_pages, v_pages, table, lengths, window=window,
                        pages_per_tile=pages_per_tile, plan=None)
        return out.astype(out_dtype)
    return _decode_attention_reference(
        q, k_pages, v_pages, table, lengths, window=window, softcap=softcap,
        accum_dtype=accum_dtype, out_dtype=out_dtype)
