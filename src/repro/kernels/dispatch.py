"""Kernel dispatch: the single entry point models use for hot contractions.

The paper's transformations only pay off when the *whole* dataflow graph
runs through the transformed kernels (FBLAS's module-routing argument): a
tuned Pallas matmul buys nothing while the surrounding projections still
lower through raw einsums.  This module is the routing layer that closes
that gap — ``dispatch.matmul`` / ``dispatch.attention`` /
``dispatch.grouped_matmul`` / ``dispatch.decode_attention`` /
``dispatch.prefill_attention`` route each call to the Pallas kernel or to
the pure-jnp reference lowering based on policy and shape/dtype/backend
eligibility.

Since the registry redesign this module is a *thin facade*: every op is a
declarative :class:`repro.kernels.registry.OpSpec` (reference lowering,
kernel lowering, eligibility predicate, tuned-plan key schema, optional
custom-VJP pair, tune-space hookup — one registration in the op family's
``ops.py``), and every facade below collapses its policy argument and
delegates to ``registry.call`` — the ONE generic code path holding the
exact → nearest → heuristic tuned-plan lookup, the level gate, and the
``(op, route)`` counters that used to be five hand-wired copies.

Policy (the ``DispatchPolicy`` knob threaded through ``configs/base.py``):

  "kernels"   — force the Pallas path whenever structurally possible
                (interpret mode on CPU); used by the differential tests.
                A tuned plan that says "the reference lowering wins at
                this shape" (level <= T1) is overridden: the Pallas
                lowering runs with the tuned tile geometry.
  "reference" — force the einsum reference lowering; bitwise-identical to
                the pre-dispatch model code
  "auto"      — kernels on TPU when eligible, reference otherwise (CPU HLO
                interpretation of a Pallas kernel is never a win); a tuned
                level <= T1 plan is honored as the reference route; the
                ``REPRO_DISPATCH`` env var can override "auto" globally

Eligibility is decided at trace time (shapes are static), so the decision
costs nothing at run time.  Matmul kernel paths carry a ``jax.custom_vjp``
whose backward is the reference contraction; the attention kernel path
pairs the flash forward (which emits per-row logsumexp residuals) with the
fused recompute Pallas backward (``attention/backward.py``) so a
``dispatch="kernels"`` train step never materializes the (S, S) score
matrix in either direction.  Per-route counters (``stats()``, plus
``plan_source_stats()`` tagging each decision with the tuned-plan lookup
route that produced it) let regression tests prove the serve/train graphs
actually flow through dispatch, and the ``forbid_dense_scores()`` scope
turns any dense-score lowering into a trace-time assertion for those
tests.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from . import registry
from .registry import (forbid_dense_scores, plan_source_stats,  # noqa: F401
                       reset_stats, stats, stats_scope)

MODES = ("kernels", "reference", "auto")


@dataclasses.dataclass(frozen=True)
class DispatchPolicy:
    """Routing policy: "kernels" | "reference" | "auto"."""

    mode: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"dispatch mode must be one of {MODES}, got {self.mode!r}")


PolicyLike = Union[DispatchPolicy, str, None]

# module default consulted when a call site passes policy=None/"auto";
# seeded from the environment so launchers can force a path globally.
_default_mode: Optional[str] = None


def default_mode() -> str:
    global _default_mode
    if _default_mode is None:
        env = os.environ.get("REPRO_DISPATCH", "auto")
        _default_mode = env if env in MODES else "auto"
    return _default_mode


def set_default_mode(mode: str) -> None:
    DispatchPolicy(mode)          # validate
    global _default_mode
    _default_mode = mode


@contextlib.contextmanager
def policy_scope(mode: str):
    """Temporarily force the module-default mode (tests, dry-runs)."""
    prev = default_mode()
    set_default_mode(mode)
    try:
        yield
    finally:
        set_default_mode(prev)


def resolve_mode(policy: PolicyLike) -> str:
    """Collapse a call-site policy to "kernels" | "reference" | "auto"."""
    if policy is None:
        mode = "auto"
    elif isinstance(policy, DispatchPolicy):
        mode = policy.mode
    else:
        mode = str(policy)
        DispatchPolicy(mode)      # validate
    if mode == "auto":
        mode = default_mode()
    return mode


def _kernels_by_default() -> bool:
    """auto-mode backend gate: compiled Pallas on TPU is a win; HLO
    interpretation of the same kernel on CPU/GPU is never one."""
    return jax.default_backend() == "tpu"


def _call(name: str, *args, statics=None, policy: PolicyLike = None,
          tp: Optional[str] = None):
    """Collapse the policy knob and hand off to the registry's one path.

    ``tp`` names the op's declared sharding contract for this call site
    (see ``registry.TPContract``); it only acts inside a
    ``registry.tp_scope`` (the shard_map'd serving region), where the
    registry completes the op with the contract's collective."""
    mode = resolve_mode(policy)
    allow = mode != "reference" and (mode == "kernels"
                                     or _kernels_by_default())
    return registry.call(name, *args, statics=statics, mode=mode,
                         allow_kernels=allow, tp=tp)


def causal_mask(qpos: jax.Array, kpos: jax.Array, window: int,
                causal: bool = True) -> jax.Array:
    """Re-export of the attention family's branch-free causal/window mask
    (condition flattening, §2.7)."""
    from .attention.ops import causal_mask as _causal_mask
    return _causal_mask(qpos, kpos, window, causal)


# ------------------------------------------------------------------ facades
def matmul(x: jax.Array, w: jax.Array, *,
           policy: PolicyLike = None, tp: Optional[str] = None) -> jax.Array:
    """Contract the last axis of ``x`` with the first axis of ``w``.

    x: (..., K); w: (K, N1[, N2, ...]).  Returns x.shape[:-1] + w.shape[1:]
    in the promoted input dtype — the generalized form of every projection
    / dense / head matmul in the models (``bsd,dhk->bshk`` is exactly this
    with w pre-reshaped, so the reference lowering is bit-identical to the
    einsums it replaces).

    ``tp`` tags the call site's sharding contract for shard_map'd serving
    ("col" = output channels device-local, no collective; "row" =
    contraction sharded, all-reduce here); inert outside a tp scope.
    """
    return _call("matmul", x, w, policy=policy, tp=tp)


def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   policy: PolicyLike = None) -> jax.Array:
    """Per-group matmul: x (G, C, K) x w (G, K, N) -> (G, C, N).

    The MoE expert contraction.  The kernel route unrolls the (static)
    group axis into per-expert Pallas matmuls (one shared tuned plan,
    resolved on the per-expert cell); the reference route is the batched
    einsum the MoE layer always used.
    """
    return _call("grouped_matmul", x, w, policy=policy)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0, softcap: float = 0.0,
              mask: Optional[jax.Array] = None,
              accum_dtype: Any = jnp.float32,
              out_dtype: Any = None,
              impl: str = "blockwise",
              block_kv: int = 512, q_splits: int = 4, unroll: bool = False,
              policy: PolicyLike = None) -> jax.Array:
    """Scaled-dot-product attention over model-layout tensors.

    q: (B, Sq, H, hd); k, v: (B, Skv, H, hd), already GQA-expanded.
    Returns (B, Sq, H, hd) in ``out_dtype`` (default: q's dtype).

    ``mask`` (broadcastable to (B, H, Sq, Skv)) overrides the causal/window
    mask — used by the decode path's rolling-cache validity mask, and
    always routed to the reference (the kernel bakes in causal/window
    only).  ``impl`` picks the reference lowering on the reference route:
    "naive" materializes (Sq, Skv); "blockwise" is the tiled XLA
    formulation (with ``block_kv`` / ``q_splits`` / ``unroll``).
    """
    out_dtype = q.dtype if out_dtype is None else out_dtype
    return _call(
        "attention", q, k, v, mask,
        statics=dict(causal=bool(causal), window=int(window),
                     softcap=float(softcap), accum_dtype=accum_dtype,
                     out_dtype=out_dtype, impl=impl, block_kv=block_kv,
                     q_splits=q_splits, unroll=bool(unroll)),
        policy=policy)


def decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     table: jax.Array, lengths: jax.Array,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None, *,
                     window: int = 0, softcap: float = 0.0,
                     accum_dtype: Any = jnp.float32,
                     out_dtype: Any = None,
                     policy: PolicyLike = None) -> jax.Array:
    """Ragged decode attention over a paged KV cache — the serving hot path.

    q (B, H, hd) one query token per slot; k_pages / v_pages (P, page,
    Hkv, hd) shared pools; table (B, n_pages) logical->physical page ids;
    lengths (B,) valid tokens per slot (0 = inactive -> zero output).
    int8 pools additionally pass ``k_scale`` / ``v_scale`` (P, Hkv) f32
    per-page per-kv-head scales (both or neither); the kernel dequantizes
    page tiles at load time, the reference at gather time.
    Returns (B, H, hd) in ``out_dtype`` (default q's dtype).  Inference
    only — no custom VJP; the kernel route consults the tuned-plan cache
    for KV-tile geometry (keyed on the POOL dtype).
    """
    out_dtype = q.dtype if out_dtype is None else out_dtype
    args = (q, k_pages, v_pages, table, lengths)
    if k_scale is not None:
        args += (k_scale, v_scale)
    # "heads" is the op's single sharding contract: q heads and KV pools
    # device-local, output all-gathered back to full head width so the
    # (replicated) out-projection sees every head.  Inert unsharded.
    return _call(
        "decode_attention", *args,
        statics=dict(window=int(window), softcap=float(softcap),
                     accum_dtype=accum_dtype, out_dtype=out_dtype),
        policy=policy, tp="heads")


def prefill_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      table: jax.Array, starts: jax.Array,
                      k_scale: Optional[jax.Array] = None,
                      v_scale: Optional[jax.Array] = None, *,
                      window: int = 0, softcap: float = 0.0,
                      accum_dtype: Any = jnp.float32,
                      out_dtype: Any = None,
                      policy: PolicyLike = None) -> jax.Array:
    """Ragged multi-token prefill attention over a paged KV cache.

    q (B, C, H, hd) one chunk of C prompt tokens per slot (already written
    into the pools); table (B, n_pages) page ids; starts (B,) page-aligned
    chunk offsets — slot b's queries sit at positions ``starts[b] +
    [0, C)`` and attend causally over the cached history plus the chunk
    itself (padded tail positions are hidden by causality).  Returns
    (B, C, H, hd) in ``out_dtype`` (default q's dtype).  int8 pools pass
    ``k_scale`` / ``v_scale`` (P, Hkv) f32 scales like ``decode_attention``.
    Inference only — no custom VJP; the first op registered end-to-end
    through the registry (kernel, oracle, tune space, plan key: one
    ``OpSpec``).
    """
    out_dtype = q.dtype if out_dtype is None else out_dtype
    args = (q, k_pages, v_pages, table, starts)
    if k_scale is not None:
        args += (k_scale, v_scale)
    return _call(
        "prefill_attention", *args,
        statics=dict(window=int(window), softcap=float(softcap),
                     accum_dtype=accum_dtype, out_dtype=out_dtype),
        policy=policy, tp="heads")


def quantized_matmul(x: jax.Array, w_q: jax.Array, w_scale: jax.Array, *,
                     policy: PolicyLike = None,
                     tp: Optional[str] = None) -> jax.Array:
    """Int8-weight matmul with per-output-channel dequant (§4.4 demotion).

    x: (..., K) floating activations; w_q: (K, N) int8 weights; w_scale:
    (N,) f32 per-channel scales (``core.quant.quantize_channelwise``
    layout).  The kernel folds the dequant into the MXU loop — int8
    weights widen in-register and the channel scale is applied ONCE at the
    K-flush (it factors out of the K contraction); the reference lowering
    dequantizes then einsums.  Returns x.shape[:-1] + (N,) f32.  Inference
    only — no custom VJP (the int8 weight is not differentiable).
    """
    return _call("quantized_matmul", x, w_q, w_scale, policy=policy, tp=tp)
