from .ops import jacobi4  # noqa: F401
