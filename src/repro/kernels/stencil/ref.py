"""Oracle for the 4-point 2D Jacobi stencil (paper §6.1, Lst. 4).

Boundary convention: boundary cells are copied through unchanged; interior
cells become the mean of their 4 neighbors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def jacobi4_ref(x: jax.Array) -> jax.Array:
    north = x[:-2, 1:-1]
    south = x[2:, 1:-1]
    west = x[1:-1, :-2]
    east = x[1:-1, 2:]
    interior = 0.25 * (north + south + west + east)
    return x.at[1:-1, 1:-1].set(interior.astype(x.dtype))


def jacobi4_iter_ref(x: jax.Array, steps: int) -> jax.Array:
    def body(_, x):
        return jacobi4_ref(x)
    return jax.lax.fori_loop(0, steps, body, x)
