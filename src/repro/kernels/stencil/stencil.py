"""Pallas 4-point 2D Jacobi — the paper's §6.1 stencil, TPU-adapted.

The paper buffers two rows in FIFOs ("north buffer" / "center buffer",
Lst. 4a) so each element is read from memory once.  A TPU has no FIFOs —
the *same transformation* (delay buffering §2.2) becomes three overlapping
row-stripe views of the input, expressed as three BlockSpecs whose index
maps are shifted by one row-block: the north/center/south "taps" of the
delay line.  Each interior row still enters VMEM exactly once per sweep in
steady state (the paper's perfect-reuse claim), because consecutive grid
steps reuse the stripe that was the previous step's south tap via the
pallas_call DMA pipeline.

East/west neighbors come from intra-block lane shifts (vectorization §3.1)
with the true boundary columns exchanged through the halo views.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import tpu_compiler_params


def _jacobi_kernel(north_ref, center_ref, south_ref, o_ref, *,
                   br: int, n_rows: int):
    i = pl.program_id(0)
    c = center_ref[...]
    n_tap = north_ref[...]
    s_tap = south_ref[...]
    # north/south neighbors of each row in the center stripe.  The taps
    # are whole stripes; row-shift within the concatenated (3*br) window:
    up = jnp.concatenate([n_tap[-1:], c[:-1]], axis=0)
    down = jnp.concatenate([c[1:], s_tap[:1]], axis=0)
    # east/west via lane shifts (§3.1); edge columns fixed below
    west = jnp.pad(c[:, :-1], ((0, 0), (1, 0)))
    east = jnp.pad(c[:, 1:], ((0, 0), (0, 1)))
    out = 0.25 * (up + down + west + east)
    # boundary conditions: copy-through on domain edges (branch-free
    # predication — condition flattening §2.7)
    rows = i * br + jax.lax.broadcasted_iota(jnp.int32, c.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, c.shape, 1)
    edge = (rows == 0) | (rows == n_rows - 1) | (cols == 0) \
        | (cols == c.shape[1] - 1)
    o_ref[...] = jnp.where(edge, c, out).astype(o_ref.dtype)


def jacobi4_pallas(x: jax.Array, *, block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    rows, cols = x.shape
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    grid = (rows // br,)
    nb = rows // br

    def clamp(idx):
        return jnp.clip(idx, 0, nb - 1)

    kernel = functools.partial(_jacobi_kernel, br=br, n_rows=rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # the three delay-line taps (§2.2): north, center, south stripes
            pl.BlockSpec((br, cols), lambda i: (clamp(i - 1), 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (clamp(i + 1), 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        compiler_params=tpu_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(x, x, x)
