"""jit'd wrapper for the Jacobi stencil."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ...core.scaling import TilePlanner
from ..common import interpret_default
from . import ref
from .stencil import jacobi4_pallas


@functools.partial(jax.jit,
                   static_argnames=("steps", "level", "block_rows",
                                    "interpret"))
def jacobi4(x: jax.Array, *, steps: int = 1,
            level: Level = Level.T3_REPLICATED,
            block_rows: Optional[int] = None,
            interpret: Optional[bool] = None) -> jax.Array:
    """`steps` sweeps of the 4-point Jacobi stencil.

    T0/T1 run the jnp reference (XLA fuses the shifted adds); T2+ run the
    Pallas delay-buffer kernel.  On real TPUs the iteration over `steps`
    is the paper's §3.3 systolic time-replication: P consecutive sweeps
    chained through VMEM-resident stripes.
    """
    if interpret is None:
        interpret = interpret_default()
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.jacobi4_iter_ref(x, steps)
    if block_rows is None:
        rows, cols = x.shape
        br, _ = TilePlanner().plan_stencil(rows, cols,
                                           dtype_bytes=x.dtype.itemsize)
        block_rows = min(br, rows)
        while rows % block_rows:
            block_rows //= 2

    def body(_, x):
        return jacobi4_pallas(x, block_rows=block_rows, interpret=interpret)

    return jax.lax.fori_loop(0, steps, body, x)
