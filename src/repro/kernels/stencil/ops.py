"""jit'd wrapper for the Jacobi stencil."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ...core.scaling import TilePlanner
from ...tune.cache import resolve_plan
from ..common import interpret_default
from . import ref
from .stencil import jacobi4_pallas


@functools.partial(jax.jit,
                   static_argnames=("steps", "level", "block_rows",
                                    "interpret"))
def _jacobi4(x: jax.Array, *, steps: int, level: Level,
             block_rows: Optional[int], interpret: bool) -> jax.Array:
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.jacobi4_iter_ref(x, steps)
    if block_rows is None:
        rows, cols = x.shape
        br, _ = TilePlanner().plan_stencil(rows, cols,
                                           dtype_bytes=x.dtype.itemsize)
        block_rows = min(br, rows)
        while rows % block_rows:
            block_rows //= 2

    def body(_, x):
        return jacobi4_pallas(x, block_rows=block_rows, interpret=interpret)

    return jax.lax.fori_loop(0, steps, body, x)


def jacobi4(x: jax.Array, *, steps: int = 1,
            level: Level = Level.T3_REPLICATED,
            block_rows: Optional[int] = None,
            plan: Union[str, dict, None] = "heuristic",
            interpret: Optional[bool] = None) -> jax.Array:
    """`steps` sweeps of the 4-point Jacobi stencil.

    T0/T1 run the jnp reference (XLA fuses the shifted adds); T2+ run the
    Pallas delay-buffer kernel.  On real TPUs the iteration over `steps`
    is the paper's §3.3 systolic time-replication: P consecutive sweeps
    chained through VMEM-resident stripes.

    ``plan`` selects the block geometry: ``"heuristic"`` (TilePlanner),
    ``"tuned"`` (autotuner cache, heuristic on a miss), or a tuned kwargs
    dict (``block_rows``, optional ``level``).  An explicit ``block_rows``
    argument wins over any plan.
    """
    if interpret is None:
        interpret = interpret_default()
    level, kw = resolve_plan("stencil", x.shape, x.dtype, level, plan)
    if block_rows is None and kw:
        block_rows = kw.get("block_rows")
    return _jacobi4(x, steps=steps, level=level, block_rows=block_rows,
                    interpret=interpret)


# ------------------------------------------------------------ registration
# Tune-only OpSpec: the stencil has no model dispatch surface, but the
# autotuner sweeps it (repro.kernels.registry drives tune.tuner's tables).
def _stencil_tune_inputs(shape, dtype):
    return (jax.random.normal(jax.random.key(0), shape, dtype),)


def _stencil_tune_call(args, plan):
    return jacobi4(*args, steps=1, plan=plan)


def _register():
    from ...tune.space import stencil_space
    from .. import registry
    registry.register(registry.OpSpec(
        name="stencil",
        tune=registry.TuneSpec(
            space=stencil_space,
            make_inputs=_stencil_tune_inputs,
            call=_stencil_tune_call,
            default_dtype=jnp.float32,
            default_shapes=((128, 256), (256, 512)),
        ),
    ))


_register()
