"""jit'd wrapper for the N-body acceleration kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ..common import interpret_default
from . import ref
from .nbody import nbody_pallas


@functools.partial(jax.jit, static_argnames=("level", "block_targets",
                                             "block_sources", "interpret"))
def nbody_accel(pos: jax.Array, mass: jax.Array, *,
                level: Level = Level.T3_REPLICATED,
                block_targets: int = 512, block_sources: int = 512,
                interpret: Optional[bool] = None) -> jax.Array:
    """Gravitational accelerations, staged per paper §6.3.

    T0/T1: jnp reference (materializes the full (N, N) interaction tensor —
    the naive memory pattern).  T2+: Pallas kernel with VMEM-resident target
    blocks and streamed source blocks (tiled accumulation interleaving)."""
    if interpret is None:
        interpret = interpret_default()
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.nbody_accel_ref(pos, mass)
    n = pos.shape[1]
    bt = min(block_targets, n)
    bs = min(block_sources, n)
    while n % bt:
        bt //= 2
    while n % bs:
        bs //= 2
    return nbody_pallas(pos, mass, block_targets=bt, block_sources=bs,
                        interpret=interpret)
