"""jit'd wrapper for the N-body acceleration kernel."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ...core.plan import Level
from ...tune.cache import resolve_plan
from ..common import interpret_default
from . import ref
from .nbody import nbody_pallas


@functools.partial(jax.jit, static_argnames=("level", "block_targets",
                                             "block_sources", "interpret"))
def _nbody_accel(pos: jax.Array, mass: jax.Array, *, level: Level,
                 block_targets: int, block_sources: int,
                 interpret: bool) -> jax.Array:
    if level in (Level.T0_NAIVE, Level.T1_PIPELINED):
        return ref.nbody_accel_ref(pos, mass)
    n = pos.shape[1]
    bt = min(block_targets, n)
    bs = min(block_sources, n)
    while n % bt:
        bt //= 2
    while n % bs:
        bs //= 2
    return nbody_pallas(pos, mass, block_targets=bt, block_sources=bs,
                        interpret=interpret)


def nbody_accel(pos: jax.Array, mass: jax.Array, *,
                level: Level = Level.T3_REPLICATED,
                block_targets: int = 512, block_sources: int = 512,
                plan: Union[str, dict, None] = "heuristic",
                interpret: Optional[bool] = None) -> jax.Array:
    """Gravitational accelerations, staged per paper §6.3.

    T0/T1: jnp reference (materializes the full (N, N) interaction tensor —
    the naive memory pattern).  T2+: Pallas kernel with VMEM-resident target
    blocks and streamed source blocks (tiled accumulation interleaving).

    ``plan`` selects the block geometry: ``"heuristic"`` (the
    ``block_targets``/``block_sources`` arguments), ``"tuned"`` (autotuner
    cache, heuristic on a miss), or a tuned kwargs dict (``block_targets``/
    ``block_sources``, optional ``level``).
    """
    if interpret is None:
        interpret = interpret_default()
    level, kw = resolve_plan("nbody", (pos.shape[1],), pos.dtype, level,
                             plan)
    if kw:
        block_targets = kw.get("block_targets", block_targets)
        block_sources = kw.get("block_sources", block_sources)
    return _nbody_accel(pos, mass, level=level, block_targets=block_targets,
                        block_sources=block_sources, interpret=interpret)


# ------------------------------------------------------------ registration
# Tune-only OpSpec: no model dispatch surface, swept by the autotuner.
def _nbody_tune_inputs(shape, dtype):
    (n,) = shape
    pos = jax.random.normal(jax.random.key(0), (3, n), dtype)
    mass = jax.random.uniform(jax.random.key(1), (n,), dtype) + 0.1
    return (pos, mass)


def _nbody_tune_call(args, plan):
    return nbody_accel(*args, plan=plan)


def _register():
    from ...tune.space import nbody_space
    from .. import registry
    registry.register(registry.OpSpec(
        name="nbody",
        tune=registry.TuneSpec(
            space=nbody_space,
            make_inputs=_nbody_tune_inputs,
            call=_nbody_tune_call,
            default_dtype=jnp.float32,
            default_shapes=((256,), (512,)),
        ),
    ))


_register()
