from .ops import nbody_accel  # noqa: F401
