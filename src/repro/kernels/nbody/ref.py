"""Oracle for gravitational N-body acceleration (paper §6.3).

SoA layout (pos (3, N), mass (N,)) — the lane dimension is the particle
index, the TPU-native form of the paper's 512-bit vector extraction.
Plummer-softened gravity: a_i = sum_j m_j (r_j - r_i) / (|r|^2 + eps^2)^1.5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SOFTENING = 1e-3


def nbody_accel_ref(pos: jax.Array, mass: jax.Array,
                    eps: float = SOFTENING) -> jax.Array:
    """pos: (3, N) f32; mass: (N,) f32 -> accel (3, N) f32."""
    diff = pos[:, None, :] - pos[:, :, None]          # (3, i, j): r_j - r_i
    r2 = jnp.sum(jnp.square(diff), axis=0) + eps * eps
    inv_r3 = jax.lax.rsqrt(r2) / r2                   # (i, j)
    w = inv_r3 * mass[None, :]
    return jnp.einsum("cij,ij->ci", diff, w)
