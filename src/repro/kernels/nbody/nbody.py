"""Pallas N-body kernel — the paper's §6.3 design, TPU-adapted.

Paper version: L resident particles held in registers per PE, interacting
particles streamed through a systolic chain; the loop-carried dependency on
the acceleration accumulator is broken by interleaving across the L
residents (§2.1.2).

TPU version: a (3, bt) block of *target* particles is the "resident" set —
it stays pinned in VMEM across the source grid axis while (3, bs) source
blocks stream through (the pallas_call DMA pipeline is the systolic data
stream, §3.3/§4.1).  The accumulator scratch (3, bt) is revisited once per
source block: the same tiled accumulation interleaving, with the VPU lane
dimension (targets) playing the role of the FPGA's parallel PEs (§3.2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import tpu_compiler_params

from .ref import SOFTENING


def _nbody_kernel(tp_ref, sp_ref, sm_ref, o_ref, acc_ref, *,
                  n_src: int, eps: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tp = tp_ref[...]                     # (3, bt) resident targets
    sp = sp_ref[...]                     # (3, bs) streamed sources
    sm = sm_ref[...]                     # (1, bs)
    # pairwise (bt, bs) interaction tile — all VPU work
    diff = sp[:, None, :] - tp[:, :, None]          # (3, bt, bs)
    r2 = jnp.sum(jnp.square(diff), axis=0) + eps * eps
    inv_r = jax.lax.rsqrt(r2)
    w = (inv_r / r2) * sm                           # (bt, bs) masses folded
    acc_ref[...] += jnp.einsum("cts,ts->ct", diff, w,
                               preferred_element_type=jnp.float32)

    @pl.when(j == n_src - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def nbody_pallas(pos: jax.Array, mass: jax.Array, *, block_targets: int = 512,
                 block_sources: int = 512, eps: float = SOFTENING,
                 interpret: bool = False) -> jax.Array:
    _, n = pos.shape
    bt = min(block_targets, n)
    bs = min(block_sources, n)
    assert n % bt == 0 and n % bs == 0, (n, bt, bs)
    n_src = n // bs
    grid = (n // bt, n_src)
    mass2d = mass[None, :]               # (1, N) — sublane-friendly

    kernel = functools.partial(_nbody_kernel, n_src=n_src, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, bt), lambda i, j: (0, i)),   # resident targets
            pl.BlockSpec((3, bs), lambda i, j: (0, j)),   # streamed sources
            pl.BlockSpec((1, bs), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((3, bt), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((3, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((3, bt), jnp.float32)],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(pos, pos, mass2d)
