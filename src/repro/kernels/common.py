"""Shared kernel utilities: interpret-mode dispatch, grid helpers."""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

# JAX renamed TPUCompilerParams -> CompilerParams across 0.4 -> 0.5; support
# both so the kernels run on whichever JAX the container ships.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(dimension_semantics):
    """Version-tolerant ``pltpu.CompilerParams(dimension_semantics=...)``."""
    return _COMPILER_PARAMS_CLS(
        dimension_semantics=tuple(dimension_semantics))


def on_cpu() -> bool:
    """Kernels run interpret=True on CPU (the container) and compiled on
    real TPUs — same source, per the assignment's validation scheme."""
    return jax.default_backend() == "cpu"


def interpret_default() -> bool:
    return on_cpu()
