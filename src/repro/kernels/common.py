"""Shared kernel utilities: interpret-mode dispatch, grid helpers."""
from __future__ import annotations

import jax


def on_cpu() -> bool:
    """Kernels run interpret=True on CPU (the container) and compiled on
    real TPUs — same source, per the assignment's validation scheme."""
    return jax.default_backend() == "cpu"


def interpret_default() -> bool:
    return on_cpu()
