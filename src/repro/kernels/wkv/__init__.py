from .ops import wkv  # noqa: F401
