"""Oracle for the WKV kernel: the validated chunked implementation from
repro.models.rwkv (itself tested against a per-timestep recurrence)."""
from __future__ import annotations

import jax

from ...models.rwkv import wkv_chunked


def wkv_ref(r, k, v, lw, u, *, chunk: int = 64):
    """r,k,v,lw: (B, S, H, hd); u: (H, hd) -> (B, S, H, hd) f32."""
    out, _ = wkv_chunked(r, k, v, lw, u, chunk=chunk, intra="direct")
    return out
