"""jit'd public wrapper for the Pallas WKV kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import interpret_default
from .wkv import wkv_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "subchunk",
                                             "interpret"))
def wkv(r: jax.Array, k: jax.Array, v: jax.Array, lw: jax.Array,
        u: jax.Array, *, chunk: int = 64, subchunk: int = 16,
        interpret: Optional[bool] = None) -> jax.Array:
    """RWKV6 WKV recurrence on the MXU.

    r,k,v: (B, S, H, hd); lw: (B, S, H, hd) log-decays (<= 0, f32);
    u: (H, hd) bonus.  Returns (B, S, H, hd) f32.
    """
    if interpret is None:
        interpret = interpret_default()
    b, s, h, hd = r.shape

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * h, s, hd)

    u_bh = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, 1, hd)
    out = wkv_pallas(fold(r), fold(k), fold(v), fold(lw.astype(jnp.float32)),
                     u_bh.astype(jnp.float32), chunk=chunk,
                     subchunk=subchunk, interpret=interpret)
    return jnp.moveaxis(out.reshape(b, h, s, hd), 1, 2)
