"""Pallas WKV kernel — the §Perf-1 blueprint as hardware.

The XLA lowering of the chunked WKV recurrence spills its (c, c, hd) decay
tensor to HBM every chunk (the measured memory-dominant term of rwkv6-7b
training).  This kernel is the paper's prescription executed at the kernel
level:

* the chunk loop is the sequential grid axis; the (hd, hd) state matrix is
  a VMEM scratch accumulator revisited once per chunk — tiled accumulation
  interleaving (§2.1.2);
* within a chunk, the intra-chunk attention uses the sub-chunked
  *matmul form* (§2.1.1 transposition): off-diagonal sub-blocks are
  boundary-normalized (sc, hd) x (hd, sc) MXU matmuls, diagonal blocks a
  small (sc, sc, hd) direct product — everything VMEM-resident
  (c=64, hd=64: the largest temporary is 1 MiB);
* the batch*heads grid axis is 'parallel' — replication (§3.2).

VMEM working set per grid step (c=64, hd=64, f32): 4 inputs x 16 KiB +
state 16 KiB + diag temp 1 MiB + out 16 KiB << 16 MiB budget — the
TilePlanner-style claim the roofline napkin math uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import tpu_compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                n_chunks: int, c: int, sc: int, hd: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    f32 = jnp.float32
    r = r_ref[0].astype(f32)          # (c, hd)
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)
    lw = lw_ref[0].astype(f32)
    u = u_ref[0].astype(f32)          # (1, hd)
    S = s_ref[...]                    # (hd, hd)

    cum = jnp.cumsum(lw, axis=0)      # inclusive, decreasing (lw <= 0)
    ecum = cum - lw                   # exclusive

    # inter-chunk contribution (exponents <= 0)
    r_in = r * jnp.exp(ecum)
    o_inter = jnp.dot(r_in, S, preferred_element_type=f32)

    # intra-chunk: sub-chunked matmul form (§2.1.1)
    nsc = c // sc
    rows = []
    for a in range(nsc):
        ra = r[a * sc:(a + 1) * sc]
        ecum_a = ecum[a * sc:(a + 1) * sc]
        m_prev_a = cum[a * sc - 1] if a > 0 else jnp.zeros((hd,), f32)
        ra_s = ra * jnp.exp(ecum_a - m_prev_a[None, :])
        acc_a = jnp.zeros((sc, hd), f32)
        for b in range(a):
            kb = k[b * sc:(b + 1) * sc]
            cum_b = cum[b * sc:(b + 1) * sc]
            m_b = cum[(b + 1) * sc - 1]
            # fold the (b, a-1] boundary-gap decay into kb (exponent <= 0)
            kb_s = kb * jnp.exp(m_b[None, :] - cum_b) \
                * jnp.exp(m_prev_a - m_b)[None, :]
            att = jnp.dot(ra_s, kb_s.T, preferred_element_type=f32)
            acc_a += jnp.dot(att, v[b * sc:(b + 1) * sc],
                             preferred_element_type=f32)
        # diagonal block: direct masked product at (sc, sc, hd)
        ka = k[a * sc:(a + 1) * sc]
        va = v[a * sc:(a + 1) * sc]
        cum_a = cum[a * sc:(a + 1) * sc]
        expo = ecum_a[:, None, :] - cum_a[None, :, :]
        tri = jax.lax.broadcasted_iota(jnp.int32, (sc, sc), 0) \
            > jax.lax.broadcasted_iota(jnp.int32, (sc, sc), 1)
        w = jnp.where(tri[:, :, None], jnp.exp(jnp.maximum(expo, -60.0)),
                      0.0)
        att_d = jnp.sum(ra[:, None, :] * ka[None, :, :] * w, axis=-1)
        acc_a += jnp.dot(att_d, va, preferred_element_type=f32)
        rows.append(acc_a)
    out = o_inter + jnp.concatenate(rows, axis=0)

    # bonus diagonal term
    bonus = jnp.sum(r * (u * k), axis=-1, keepdims=True)
    out = out + bonus * v

    # state update (exponents <= 0)
    total = cum[-1]
    k_dec = k * jnp.exp(total[None, :] - cum)
    s_ref[...] = jnp.exp(total)[:, None] * S \
        + jnp.dot(k_dec.T, v, preferred_element_type=f32)
    o_ref[0] = out.astype(o_ref.dtype)


def wkv_pallas(r, k, v, lw, u, *, chunk: int = 64, subchunk: int = 16,
               interpret: bool = False):
    """r,k,v,lw: (BH, S, hd); u: (BH, 1, hd) -> out (BH, S, hd) f32."""
    bh, s, hd = r.shape
    c = min(chunk, s)
    while c > 1 and s % c:
        c //= 2
    sc = min(subchunk, c)
    while sc > 1 and c % sc:
        sc //= 2
    n_chunks = s // c

    kernel = functools.partial(_wkv_kernel, n_chunks=n_chunks, c=c, sc=sc,
                               hd=hd)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, c, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, j: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, hd), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, lw, u)
