"""Pipeline-enabling transformations (paper §2), adapted to JAX/TPU.

The FPGA problem: a loop-carried dependency through an ``L_acc``-cycle
operation forces initiation interval ``I = L_acc``.  The TPU analogue is a
*sequential* reduction (``lax.scan``/``fori_loop`` carrying a scalar) that
serializes what the VPU/MXU could do in parallel, or an XLA reduction whose
shape defeats lane parallelism.  The cures are the paper's cures:

* §2.1.1/2.1.2  transpose / tile the iteration space so each accumulator is
  revisited only every M >= L_acc steps  -> ``interleaved_accumulate``
* §2.1.4        interleave independent problem instances -> ``cross_input_interleave``
* §2.4          fuse sequential pipelined phases          -> ``fuse_phases``
* §2.5          flatten nested iteration spaces           -> ``flatten_grid``

These helpers are used by the Pallas kernels, the RWKV6 chunked scan, and the
optimizer, and are unit/property tested against naive references.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp


def interleaved_accumulate(
    xs: jax.Array,
    *,
    lanes: int = 8,
    axis: int = 0,
    op: Callable[[jax.Array, jax.Array], jax.Array] = jnp.add,
    init: float = 0.0,
) -> jax.Array:
    """Single-loop accumulation interleaving (paper §2.1.3, Lst. 2).

    Splits a length-N sequential reduction into ``lanes`` independent partial
    accumulators (stage 0: the pipelined loop with the dependency broken) and
    collapses them in a short second stage (stage 1).  On TPU the "lanes" are
    literal vector lanes: the partial accumulators live in one VREG row, so
    stage 0 runs at I=1 independent of the op latency.

    Works for any associative+commutative ``op``; matches the naive fold
    bit-for-bit for integer types, and up to reassociation error for floats
    (which is exactly the trade the paper makes).
    """
    xs = jnp.moveaxis(xs, axis, 0)
    n = xs.shape[0]
    pad = (-n) % lanes
    if pad:
        fill = jnp.full((pad,) + xs.shape[1:], init, dtype=xs.dtype)
        xs = jnp.concatenate([xs, fill], axis=0)
    # stage 0: lane-strided partials.  shape (n/lanes, lanes, ...) reduced
    # over the *sequential* axis; every lane is an independent accumulator.
    xs = xs.reshape((-1, lanes) + xs.shape[1:])

    def body(acc, row):
        return op(acc, row), None

    acc0 = jnp.full((lanes,) + xs.shape[2:], init, dtype=xs.dtype)
    partials, _ = jax.lax.scan(body, acc0, xs)
    # stage 1: collapse the lane partials (short, not throughput-critical).
    return _fold(partials, op, init, axis=0)


def _fold(xs: jax.Array, op, init, axis: int) -> jax.Array:
    """Tree-fold along ``axis`` (log-depth collapse; paper's stage 1)."""
    xs = jnp.moveaxis(xs, axis, 0)
    n = xs.shape[0]
    while n > 1:
        half = n // 2
        lo, hi, rest = xs[:half], xs[half:2 * half], xs[2 * half:]
        xs = jnp.concatenate([op(lo, hi), rest], axis=0)
        n = xs.shape[0]
    return xs[0]


def tiled_accumulate(
    terms_fn: Callable[[jax.Array], jax.Array],
    n: int,
    tile: int,
    out_shape: Tuple[int, ...],
    dtype=jnp.float32,
) -> jax.Array:
    """Tiled accumulation interleaving (paper §2.1.2, Lst. 1c).

    Evaluates ``sum_{i<n} terms_fn(i)`` where ``terms_fn`` maps a vector of
    indices to a (tile,) + out_shape block of terms.  A buffer of ``tile``
    partial accumulators is carried through a scan over n/tile chunks — each
    accumulator is touched once per chunk, breaking the dependency chain as
    long as ``tile >= L_acc``.
    """
    assert n % tile == 0, (n, tile)

    def body(acc, chunk):
        idx = chunk * tile + jnp.arange(tile)
        return acc + terms_fn(idx), None

    acc0 = jnp.zeros((tile,) + out_shape, dtype)
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(n // tile))
    return acc.sum(axis=0)


def cross_input_interleave(
    step: Callable[[jax.Array], jax.Array],
    states: jax.Array,
    n_steps: int,
) -> jax.Array:
    """Cross-input accumulation interleaving (paper §2.1.4, Lst. 3).

    An iterative solver with a true dependency on its own state cannot be
    pipelined — but throughput across *independent problem instances* can.
    The FPGA version rotates N >= L_step states through one pipeline; the TPU
    version vmaps the step across the leading axis (instances fill the VPU/
    MXU instead of pipeline stages) and scans over time.
    """
    vstep = jax.vmap(step)

    def body(s, _):
        return vstep(s), None

    out, _ = jax.lax.scan(body, states, None, length=n_steps)
    return out


def fuse_phases(
    phases: Sequence[Callable[[jax.Array], jax.Array]],
) -> Callable[[jax.Array], jax.Array]:
    """Pipelined loop fusion (paper §2.4): run consecutive elementwise phases
    as one fused pass.  Under jit, composing the callables in one trace is
    sufficient — XLA fuses them into a single loop over the data with a
    single "fill/drain", exactly the paper's Lst. 5c.  The helper exists so
    call sites document the transformation and tests can compare fused vs.
    phase-at-a-time execution.
    """

    def fused(x: jax.Array) -> jax.Array:
        for p in phases:
            x = p(x)
        return x

    return fused


def flatten_grid(shape: Sequence[int]) -> Tuple[int, Callable[[jax.Array], Tuple[jax.Array, ...]]]:
    """Pipelined loop flattening (paper §2.5, Lst. 7 + §2.7 Lst. 8).

    Returns the collapsed trip count and an index-reconstruction function
    mapping the flat index to per-dimension indices using the paper's
    condition-flattened update (compare-then-increment, branch-free).
    Used to collapse multi-dimensional Pallas grids so the inner "pipeline"
    (the grid's DMA double-buffer) never drains between outer iterations.
    """
    total = 1
    for s in shape:
        total *= int(s)

    def unflatten(flat: jax.Array) -> Tuple[jax.Array, ...]:
        idx = []
        rem = flat
        for s in reversed(shape):
            idx.append(rem % s)
            rem = rem // s
        return tuple(reversed(idx))

    return total, unflatten
