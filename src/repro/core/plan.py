"""TransformConfig: the staged optimization levels from the paper's §6.

Each application example in the paper is optimized in stages; we encode the
same ladder so kernels/models can be built "at" a level and the benchmark
harness can sweep it (reproducing Fig. 7's progression structure):

  T0 naive        — straight loop nest, no transformations
  T1 pipelined    — pipeline-enabling transforms applied (§2): accumulation
                    interleaving, delay buffering, fusion/flattening
  T2 vectorized   — + vectorization / lane alignment (§3.1) and memory
                    access extraction/oversubscription (§4.1/4.2)
  T3 replicated   — + replication/streaming/tiling (§3.2-3.4) and striping
                    (§4.3): the full spatial design

plus orthogonal memory knobs (type demotion §4.4, striping ways §4.3).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Iterator, Optional, Sequence

from .memory import BF16_POLICY, DtypePolicy


class Level(enum.IntEnum):
    T0_NAIVE = 0
    T1_PIPELINED = 1
    T2_VECTORIZED = 2
    T3_REPLICATED = 3


@dataclasses.dataclass(frozen=True)
class TransformConfig:
    level: Level = Level.T3_REPLICATED
    # §2.1 accumulation interleaving: number of concurrent accumulators
    accum_lanes: int = 8
    # §3.1 vectorization width (elements per cycle target)
    vector_width: int = 128
    # §3.2 replication factor (compute units / resident rows / TP ways)
    replication: int = 1
    # §3.3 streaming dataflow stages (pipeline-parallel stages)
    stream_stages: int = 1
    # §3.4 tiling: VMEM budget fraction the TilePlanner may use
    vmem_fraction: float = 0.75
    # §4.2 oversubscription: prefetch depth (data pipeline / DMA buffers)
    prefetch_depth: int = 2
    # §4.3 striping ways (FSDP shards for weights/moments)
    stripe_ways: int = 1
    # §4.4 type demotion
    dtypes: DtypePolicy = BF16_POLICY
    int8_moments: bool = False
    int8_grad_wire: bool = False

    def at_level(self, level: Level) -> "TransformConfig":
        return dataclasses.replace(self, level=level)


# Default per-knob candidate sets for the autotuner (repro.tune).  These are
# the paper's transformation parameters as *enumerable axes* rather than the
# single point each kernel hard-codes: the sweep is what turns parameterized
# kernels into peak-rate ones (FBLAS; Rong's programmatic-control argument).
TUNE_LEVELS: Sequence[Level] = (
    Level.T1_PIPELINED, Level.T2_VECTORIZED, Level.T3_REPLICATED)
TUNE_VECTOR_WIDTHS: Sequence[int] = (128, 256, 512)
TUNE_ACCUM_LANES: Sequence[int] = (4, 8, 16)
TUNE_PREFETCH_DEPTHS: Sequence[int] = (1, 2)
TUNE_VMEM_FRACTIONS: Sequence[float] = (0.5, 0.75, 0.9)


def enumerate_configs(
        base: Optional[TransformConfig] = None, *,
        levels: Sequence[Level] = TUNE_LEVELS,
        vector_widths: Sequence[int] = (None,),
        accum_lanes: Sequence[int] = (None,),
        prefetch_depths: Sequence[int] = (None,),
        vmem_fractions: Sequence[float] = (None,),
        max_configs: Optional[int] = None) -> Iterator[TransformConfig]:
    """Cartesian sweep over the transformation knobs, anchored at ``base``.

    ``None`` in a candidate list means "keep the base value", so callers
    sweep only the axes they name.  Deterministic order (itertools.product
    over the given sequences) so a seeded tuner re-visits candidates
    identically run-to-run.
    """
    base = base or TransformConfig()
    n = 0
    for lvl, vw, al, pf, vf in itertools.product(
            levels, vector_widths, accum_lanes, prefetch_depths,
            vmem_fractions):
        cfg = dataclasses.replace(
            base,
            level=lvl,
            vector_width=base.vector_width if vw is None else vw,
            accum_lanes=base.accum_lanes if al is None else al,
            prefetch_depth=base.prefetch_depth if pf is None else pf,
            vmem_fraction=base.vmem_fraction if vf is None else vf)
        yield cfg
        n += 1
        if max_configs is not None and n >= max_configs:
            return


PAPER_STAGES = {
    Level.T0_NAIVE: "naive loop nest",
    Level.T1_PIPELINED: "pipeline-enabled (§2)",
    Level.T2_VECTORIZED: "+ vectorized & memory-extracted (§3.1, §4.1-4.2)",
    Level.T3_REPLICATED: "+ replicated/streamed/tiled (§3.2-3.4, §4.3)",
}
