"""repro.core — the paper's contribution: an HLS-transformation toolbox
re-targeted at TPU/JAX.  See DESIGN.md §2 for the full mapping."""

from .model import (  # noqa: F401
    TPU_V5E,
    HardwareSpec,
    PipelineModel,
    Roofline,
    arithmetic_intensity,
    dense_model_flops,
    machine_balance,
)
from .memory import (  # noqa: F401
    BF16_POLICY,
    F32_POLICY,
    DtypePolicy,
    QuantizedBlock,
    dequantize_block,
    quantize_block,
    quantized_bytes,
    striped_bytes_per_chip,
)
from .pipelining import (  # noqa: F401
    cross_input_interleave,
    flatten_grid,
    fuse_phases,
    interleaved_accumulate,
    tiled_accumulate,
)
from .plan import Level, TransformConfig, PAPER_STAGES  # noqa: F401
from .scaling import (  # noqa: F401
    TilePlan,
    TilePlanner,
    lane_utilization,
    replication_factor,
    round_up,
    vector_pad,
)
from .taxonomy import (  # noqa: F401
    TABLE1,
    TABLE2,
    Characteristic,
    Objective,
    Relevance,
    Transformation,
    TransformClass,
    by_class,
    recommend,
)
