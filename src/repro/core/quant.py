"""Symmetric int8 quantization helpers for KV pages and weights (§4.4).

The paper's type-demotion transformation applied to the two dominant
serving residencies:

* **KV pages** — pools quantize per (page, kv-head): one f32 scale per
  (physical page, Hkv) cell, so a page's scale rides the same
  scalar-prefetch path as the page table and the ragged kernels dequantize
  tile loads in-register (``kernels/attention/decode.py`` / ``prefill.py``).
  Prefill writes whole pages (clean abs-max scales); decode appends one
  token at a time with a *running-max rescale*: the page's scale only ever
  grows, existing int8 values are rescaled by ``old_scale / new_scale``
  (a freed page's scale is reset to 0, so the first append into it wipes
  any stale payload — ratio 0 zeroes the ints).
* **Weights** — per-output-channel scales (one f32 per N column), the
  layout ``quantized_matmul`` folds into its MXU loop at the K-flush.

Everything here is pure jnp (models/ may not import kernel families); the
in-kernel dequant lives with the kernels.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# Symmetric int8: x ~= q * scale with q in [-127, 127], scale = amax / 127.
INT8_MAX = 127.0


def _quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Round-to-nearest symmetric quantize at a given (broadcast) scale.
    A zero scale means "this block is all zeros" — guard the divide."""
    safe = jnp.where(scale > 0, scale, 1.0)
    return jnp.clip(jnp.round(x.astype(jnp.float32) / safe),
                    -INT8_MAX, INT8_MAX).astype(jnp.int8)


def quantize_pages(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Whole-page quantize: x (..., page, Hkv, hd) float ->
    (int8 same-shape, f32 scales (..., Hkv)) with one scale per
    (page, kv-head) — abs-max over the (page, hd) axes."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-3, -1))
    scale = amax / INT8_MAX                       # (..., Hkv)
    q = _quantize(x, scale[..., None, :, None])
    return q, scale


def append_token_quantized(page_q: jax.Array, page_scale: jax.Array,
                           token: jax.Array, off: jax.Array
                           ) -> Tuple[jax.Array, jax.Array]:
    """Decode append: write one token into slot ``off`` of each gathered
    page with a running-max rescale.

    page_q (B, page, Hkv, hd) int8 — the gathered per-slot pages;
    page_scale (B, Hkv) f32; token (B, Hkv, hd) float; off (B,) int32.
    The scale only grows (new = max(old, token_amax/127)); existing ints
    are rescaled by old/new, so a freshly reset page (scale 0) starts
    clean regardless of its stale payload."""
    b = page_q.shape[0]
    tok_amax = jnp.max(jnp.abs(token.astype(jnp.float32)), axis=-1)
    new_scale = jnp.maximum(page_scale, tok_amax / INT8_MAX)   # (B, Hkv)
    ratio = jnp.where(new_scale > 0, page_scale / jnp.where(
        new_scale > 0, new_scale, 1.0), 0.0)
    page_q = jnp.clip(jnp.round(page_q.astype(jnp.float32)
                                * ratio[:, None, :, None]),
                      -INT8_MAX, INT8_MAX).astype(jnp.int8)
    tok_q = _quantize(token, new_scale[..., None])             # (B, Hkv, hd)
    page_q = page_q.at[jnp.arange(b), off].set(tok_q)
    return page_q, new_scale


def quantize_channelwise(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Weight quantize: w (K, N) float -> (int8 (K, N), f32 scales (N,))
    with one scale per output channel — the layout ``quantized_matmul``
    applies once per output column at its K-flush."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)
    scale = amax / INT8_MAX                       # (N,)
    return _quantize(w, scale[None, :]), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Reference dequant: broadcast-multiply back to f32."""
    return q.astype(jnp.float32) * scale


def kv_dtype_of(name: str, compute_dtype) -> jnp.dtype:
    """Resolve an ``ArchConfig.kv_dtype`` string ("" = model compute
    dtype) to a concrete jnp dtype."""
    if not name:
        return jnp.dtype(compute_dtype)
    aliases = {"fp32": "float32", "bf16": "bfloat16"}
    return jnp.dtype(aliases.get(name, name))
