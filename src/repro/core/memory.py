"""Memory transformations (paper §4), adapted to TPU/JAX.

* §4.1 access extraction: on TPU, `pallas_call`'s grid pipeline issues the
  HBM<->VMEM DMAs so compute never touches HBM (the kernels get it for free);
  at host level the data pipeline prefetches on a background thread
  (``repro.data.pipeline``).
* §4.2 oversubscription: prefetch depth > 1; wider blocks than the consumer
  needs ("gearboxing" = reshaping the staged block).
* §4.3 striping: sharding IS striping — every chip's HBM is a DRAM bank.
  Implemented by the sharding rules in ``repro.runtime.sharding``; here we
  provide the byte-accounting used for napkin math.
* §4.4 type demotion: dtype *policies* plus a block-scaled int8 container
  (``QuantizedBlock``) used for gradient compression and int8 Adam moments.
  This is the transformation that makes the 1T-param assigned arch
  (kimi-k2) trainable on 512 x 16 GiB chips — see EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# §4.4 Type demotion: dtype policy
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """Which dtype each class of tensor uses (the demotion decisions)."""

    param: jnp.dtype = jnp.float32       # master weights
    compute: jnp.dtype = jnp.bfloat16    # matmul inputs
    accum: jnp.dtype = jnp.float32       # matmul accumulators / softmax
    moment: jnp.dtype = jnp.float32      # optimizer moments (int8 variant below)
    grad_wire: Optional[jnp.dtype] = None  # dtype on the all-reduce wire

    def bytes_per_param(self, *, adam: bool = True,
                        int8_moments: bool = False) -> float:
        """Napkin math for HBM residency per parameter."""
        b = jnp.dtype(self.param).itemsize + jnp.dtype(self.compute).itemsize
        if adam:
            per_moment = 1 + 4 / 128 if int8_moments \
                else jnp.dtype(self.moment).itemsize
            b += 2 * per_moment
        return float(b)


BF16_POLICY = DtypePolicy()
F32_POLICY = DtypePolicy(compute=jnp.float32)


# --------------------------------------------------------------------------
# §4.4 Type demotion: block-scaled int8 container
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
class QuantizedBlock:
    """Block-scaled int8: values in [-127,127] with one f32 scale per block
    of ``block`` flattened elements.  Symmetric, round-to-nearest.

    Used by (a) gradient all-reduce compression (§4.4 applied to the wire,
    with error feedback in ``repro.optim.compress``) and (b) int8 Adam
    moments (§4.4 applied to optimizer state).  Registered as a pytree with
    the block size as static aux data so jit/sharding treat (q, scale) as
    ordinary leaves."""

    __slots__ = ("q", "scale", "block")

    def __init__(self, q: jax.Array, scale: jax.Array, block: int = 128):
        self.q = q            # int8, original shape
        self.scale = scale    # f32, (n_blocks,)
        self.block = block

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ((ga("q"), self.q), (ga("scale"), self.scale)), self.block

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    def __repr__(self):
        return f"QuantizedBlock(q={self.q!r}, scale={self.scale!r}, " \
               f"block={self.block})"


def quantize_block(x: jax.Array, block: int = 128) -> QuantizedBlock:
    """Blocks run along the LAST axis only: the (..., n) -> (..., nb, block)
    reshape preserves the sharding of every leading axis, so quantized
    optimizer moments stay striped (§4.3) — a flat reshape would force
    GSPMD to gather the full tensor (measured: 64 GiB/device temps on the
    67B arch)."""
    if x.ndim == 0:
        x = x[None]
        squeeze = True
    else:
        squeeze = False
    last = x.shape[-1]
    pad = (-last) % block
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)])
    blocks = xf.reshape(xf.shape[:-1] + (-1, block))
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    q = q.reshape(xf.shape)[..., :last]
    if squeeze:
        q = q[0]
    return QuantizedBlock(q, scale[..., 0], block)


def dequantize_block(qb: QuantizedBlock) -> jax.Array:
    q = qb.q[None] if qb.q.ndim == 0 else qb.q
    last = q.shape[-1]
    pad = (-last) % qb.block
    qf = q.astype(jnp.float32)
    if pad:
        qf = jnp.pad(qf, [(0, 0)] * (qf.ndim - 1) + [(0, pad)])
    blocks = qf.reshape(qf.shape[:-1] + (-1, qb.block))
    out = (blocks * qb.scale[..., None]).reshape(qf.shape)[..., :last]
    return out[0] if qb.q.ndim == 0 else out


def quantized_bytes(n_elems: int, block: int = 128) -> float:
    """Wire/HBM bytes for a block-int8 tensor (napkin math)."""
    return n_elems * (1 + 4.0 / block)


# --------------------------------------------------------------------------
# §4.3 Striping: byte accounting for sharded residency
# --------------------------------------------------------------------------

def striped_bytes_per_chip(total_bytes: float, stripe_ways: int) -> float:
    """RAID-0 over the mesh: each chip holds 1/stripe_ways of the array."""
    return total_bytes / max(stripe_ways, 1)


# --------------------------------------------------------------------------
# §4.1/4.2 Access extraction + oversubscription: double-buffer scan pattern
# --------------------------------------------------------------------------

def prefetched_scan(body, init, xs, *, prefetch: int = 1):
    """Scan whose step t sees element t while XLA overlaps the "load" of
    t+1..t+prefetch — expressed by rolling the consumed sequence so the
    gather of the next element is independent of the current body.  On real
    TPUs `pallas_call` and the data pipeline provide this; the helper exists
    for CPU-verifiable semantics tests and to document the pattern.
    """
    del prefetch  # semantic no-op on CPU; XLA already software-pipelines
    return jax.lax.scan(body, init, xs)
