"""Machine-readable encoding of the paper's Table 1 and Table 2.

The paper's primary contribution is a *taxonomy* of HLS transformations: three
classes (pipelining / scaling / memory), each transformation annotated with

* characteristics — effects on the code and the generated hardware, and
* objectives — the bottlenecks a performance engineer can target with it.

This module encodes that cheat sheet so tooling (the benchmark harness, the
perf-iteration loop in EXPERIMENTS.md, and users of the library) can *query*
it: ``recommend(Objective.LOOP_CARRIED_DEPENDENCY)`` returns the
transformations the paper prescribes, together with the TPU-native mechanism
this repo implements for each (see ``tpu_mechanism``).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Tuple


class TransformClass(enum.Enum):
    PIPELINING = "pipelining"
    SCALING = "scaling"
    MEMORY = "memory"


class Characteristic(enum.Enum):
    """Center column group of Table 1."""

    ENABLES_PIPELINING = "PL"      # enables pipelining
    INCREASES_REUSE = "RE"         # increases arithmetic intensity
    INCREASES_PARALLELISM = "PR"   # exposes more parallelism
    OPTIMIZES_MEMORY = "ME"        # optimizes memory accesses
    RESOURCE_NEUTRAL = "RS"        # does not significantly increase resources
    ROUTING_NEUTRAL = "RT"         # does not impair routing / frequency
    SCHEDULE_NEUTRAL = "SC"        # does not change loop-nest schedule
    CODE_NEUTRAL = "CC"            # does not increase code complexity


class Objective(enum.Enum):
    """Right column group of Table 1 — what the engineer wants to fix."""

    LOOP_CARRIED_DEPENDENCY = "LD"   # resolve loop-carried dependencies
    INTERFACE_CONTENTION = "IC"      # resolve interface contention
    DATA_REUSE = "RE"                # increase data reuse
    PARALLELISM = "CU"               # increase parallelism (compute units)
    MEMORY_BANDWIDTH = "BW"          # increase usable memory bandwidth
    PIPELINING_OVERHEAD = "PL"       # reduce pipeline fill/drain overhead
    ROUTING = "RT"                   # improve routing results
    RESOURCES = "RS"                 # reduce resource utilization


@dataclass(frozen=True)
class Transformation:
    name: str
    cls: TransformClass
    section: str                      # paper section
    characteristics: Tuple[Characteristic, ...]
    objectives: Tuple[Objective, ...]
    fpga_mechanism: str               # what the paper does on FPGA
    tpu_mechanism: str                # what this repo does on TPU
    repo_entrypoints: Tuple[str, ...] = field(default_factory=tuple)


_T = Transformation
_C = Characteristic
_O = Objective
_K = TransformClass

TABLE1: Dict[str, Transformation] = {
    t.name: t
    for t in [
        _T(
            "accumulation_interleaving", _K.PIPELINING, "2.1",
            (_C.ENABLES_PIPELINING, _C.SCHEDULE_NEUTRAL),
            (_O.LOOP_CARRIED_DEPENDENCY,),
            "interleave independent accumulations across an M-deep buffer so "
            "each partial sum is revisited only every M >= L_acc cycles",
            "multi-accumulator reductions: K-blocked VMEM accumulators in the "
            "Pallas matmul; lane-parallel partial sums + tree collapse for "
            "float reductions; online-softmax running stats in flash attention",
            ("repro.core.pipelining.interleaved_accumulate",
             "repro.core.pipelining.cross_input_interleave",
             "repro.kernels.matmul", "repro.kernels.attention"),
        ),
        _T(
            "delay_buffering", _K.PIPELINING, "2.2",
            (_C.ENABLES_PIPELINING, _C.INCREASES_REUSE, _C.OPTIMIZES_MEMORY),
            (_O.INTERFACE_CONTENTION, _O.DATA_REUSE),
            "FIFO line buffers / shift registers hold each loaded element "
            "until its last use (sliding-window stencils)",
            "overlapping BlockSpec halo windows stage each HBM row into VMEM "
            "exactly once per block; sliding-window KV caches; conv ring "
            "buffers in RG-LRU blocks",
            ("repro.kernels.stencil", "repro.models.griffin"),
        ),
        _T(
            "random_access_buffering", _K.PIPELINING, "2.3",
            (_C.ENABLES_PIPELINING, _C.OPTIMIZES_MEMORY),
            (_O.INTERFACE_CONTENTION, _O.MEMORY_BANDWIDTH),
            "stage tiles into on-chip RAM and do random accesses there",
            "gather/scatter have no fast TPU analogue; histogram becomes a "
            "one-hot matmul on the MXU over VMEM-resident tiles (the MXU is "
            "the bank array), with banked partial histograms",
            ("repro.kernels.histogram",),
        ),
        _T(
            "pipelined_loop_fusion", _K.PIPELINING, "2.4",
            (_C.ENABLES_PIPELINING, _C.RESOURCE_NEUTRAL),
            (_O.PIPELINING_OVERHEAD,),
            "fuse sequential pipelined loops under loop guards to share one "
            "fill/drain",
            "XLA op fusion inside one jit; fused layer bodies in a single "
            "scan; fused optimizer update (no per-phase kernel launches)",
            ("repro.core.pipelining.fuse_phases", "repro.optim.adamw"),
        ),
        _T(
            "loop_flattening", _K.PIPELINING, "2.5",
            (_C.ENABLES_PIPELINING, _C.RESOURCE_NEUTRAL),
            (_O.PIPELINING_OVERHEAD,),
            "coalesce nested loops so the inner pipeline never drains",
            "collapsed Pallas grids (1-D grid over (M/bm)*(N/bn)); "
            "scan-over-layers keeps one loop, not L jit calls",
            ("repro.core.pipelining.flatten_grid", "repro.models.transformer"),
        ),
        _T(
            "inlining", _K.PIPELINING, "2.6",
            (_C.ENABLES_PIPELINING, _C.CODE_NEUTRAL),
            (_O.LOOP_CARRIED_DEPENDENCY, _O.PIPELINING_OVERHEAD),
            "instantiate called functions as dedicated hardware",
            "JAX tracing inlines everything by construction; jit boundaries "
            "exist only at step level (train_step / serve_step)",
            ("repro.train.steps",),
        ),
        _T(
            "condition_flattening", _K.PIPELINING, "2.7",
            (_C.RESOURCE_NEUTRAL, _C.SCHEDULE_NEUTRAL),
            (_O.ROUTING,),
            "balance conditional logic depth to shorten the critical path",
            "predication: branch-free jnp.where masks (causal / sliding "
            "window / MoE capacity) instead of lax.cond in hot loops",
            ("repro.models.layers.attention_mask",),
        ),
        _T(
            "vectorization", _K.SCALING, "3.1",
            (_C.INCREASES_PARALLELISM, _C.OPTIMIZES_MEMORY, _C.CODE_NEUTRAL),
            (_O.PARALLELISM, _O.MEMORY_BANDWIDTH),
            "widen the datapath by W via unrolling / vector types; bounded by "
            "W_max = B/(f*S)",
            "lane alignment: trailing dims padded to (8,128) VREG tiles; "
            "bf16 doubles elements/lane; TilePlanner enforces MXU-aligned "
            "block shapes",
            ("repro.core.scaling.vector_pad", "repro.core.scaling.TilePlanner"),
        ),
        _T(
            "replication", _K.SCALING, "3.2",
            (_C.INCREASES_PARALLELISM, _C.INCREASES_REUSE),
            (_O.PARALLELISM,),
            "replicate compute units fed from on-chip reuse; scales with "
            "silicon, not memory bandwidth",
            "within-chip: more MXU passes per loaded operand (K-blocking, "
            "P-resident rows); across chips: tensor/expert parallelism via "
            "sharding over the `model` mesh axis",
            ("repro.runtime.sharding", "repro.kernels.matmul",
             "repro.kernels.nbody"),
        ),
        _T(
            "streaming_dataflow", _K.SCALING, "3.3",
            (_C.INCREASES_PARALLELISM, _C.ROUTING_NEUTRAL),
            (_O.PARALLELISM, _O.ROUTING),
            "partition into PEs connected by FIFOs; systolic arrays",
            "pipeline parallelism over a mesh axis with jax.lax.ppermute "
            "(GPipe microbatch streaming); Pallas's per-grid-step DMA "
            "pipeline is the intra-chip FIFO",
            ("repro.runtime.pipeline_parallel",),
        ),
        _T(
            "tiling", _K.SCALING, "3.4",
            (_C.OPTIMIZES_MEMORY, _C.RESOURCE_NEUTRAL),
            (_O.DATA_REUSE, _O.RESOURCES),
            "fold large problems into chunks that fit on-chip memory",
            "BlockSpec tiling solved by TilePlanner against the 16 MiB VMEM "
            "budget; sequence chunking in RWKV6; microbatching",
            ("repro.core.scaling.TilePlanner",),
        ),
        _T(
            "memory_access_extraction", _K.MEMORY, "4.1",
            (_C.ENABLES_PIPELINING, _C.OPTIMIZES_MEMORY),
            (_O.INTERFACE_CONTENTION, _O.MEMORY_BANDWIDTH),
            "move memory accesses into separate modules; long bursts + "
            "streams decouple memory from compute schedules",
            "pallas_call's emitted DMA pipeline: kernels only touch VMEM Refs "
            "while the grid prefetches the next blocks; host data pipeline "
            "prefetches batches on a background thread",
            ("repro.data.pipeline", "repro.kernels"),
        ),
        _T(
            "memory_oversubscription", _K.MEMORY, "4.2",
            (_C.OPTIMIZES_MEMORY,),
            (_O.MEMORY_BANDWIDTH,),
            "read ahead aggressively into deep buffers; gearbox bus widths",
            "multi-batch prefetch depth in the data loader; double/multiple "
            "buffering of VMEM blocks across grid steps",
            ("repro.data.pipeline",),
        ),
        _T(
            "memory_striping", _K.MEMORY, "4.3",
            (_C.OPTIMIZES_MEMORY,),
            (_O.MEMORY_BANDWIDTH,),
            "stripe arrays across DRAM banks (RAID-0)",
            "FSDP/ZeRO: weights and optimizer moments striped over the mesh "
            "(every chip's HBM is a bank); expert striping (EP); KV-cache "
            "head striping",
            ("repro.runtime.sharding",),
        ),
        _T(
            "type_demotion", _K.MEMORY, "4.4",
            (_C.OPTIMIZES_MEMORY, _C.RESOURCE_NEUTRAL, _C.CODE_NEUTRAL),
            (_O.MEMORY_BANDWIDTH, _O.RESOURCES),
            "demote to cheaper types that still meet precision needs",
            "bf16 compute policy; block-scaled int8 gradient compression and "
            "int8 Adam moments (makes the 1T-param arch fit 512 chips)",
            ("repro.core.memory.QuantizedBlock", "repro.optim.adamw",
             "repro.optim.compress"),
        ),
    ]
}


def recommend(objective: Objective) -> List[Transformation]:
    """The paper's cheat-sheet lookup: objective -> candidate transformations."""
    return [t for t in TABLE1.values() if objective in t.objectives]


def by_class(cls: TransformClass) -> List[Transformation]:
    return [t for t in TABLE1.values() if t.cls is cls]


# --------------------------------------------------------------------------
# Table 2: classic software transformations and their HLS/TPU relevance.
# --------------------------------------------------------------------------

class Relevance(enum.Enum):
    CORE = "core component of an HLS transformation"
    DIRECT = "applies directly, as in software"
    NONE = "little or no relevance to HLS/TPU"


TABLE2: Dict[str, Tuple[Relevance, str]] = {
    "loop_interchange": (Relevance.CORE, "resolves loop-carried deps (§2.1.1)"),
    "strip_mining": (Relevance.CORE, "backbone of tiling/vectorization"),
    "loop_tiling": (Relevance.CORE, "fit fast memory (§3.4 / BlockSpec)"),
    "loop_distribution": (Relevance.CORE, "separate schedules (§3.3)"),
    "loop_unrolling": (Relevance.CORE, "generates parallel hardware (§3.1/3.2)"),
    "software_pipelining": (Relevance.CORE, "what the scheduler does (§1.2)"),
    "loop_coalescing": (Relevance.CORE, "saves pipeline drains (§2.5)"),
    "reduction_recognition": (Relevance.CORE, "prevents accumulation deps (§2.1)"),
    "loop_idiom_recognition": (Relevance.CORE, "shift-buffer detection (§2.2)"),
    "procedure_inlining": (Relevance.CORE, "required for pipelining (§2.6)"),
    "loop_peeling": (Relevance.DIRECT, "opposite often better: coalesce (§2.5)"),
    "simd_transforms": (Relevance.CORE, "via unrolling (§3.1)"),
    "licm_hoisting": (Relevance.DIRECT, "saves memory operations"),
    "loop_normalization": (Relevance.DIRECT, "useful intermediate step"),
    "loop_reversal": (Relevance.DIRECT, "as in software"),
    "array_padding": (Relevance.DIRECT, "lane alignment is exactly this"),
    "scalar_replacement": (Relevance.DIRECT, "registers instead of buffers"),
    "function_memoization": (Relevance.DIRECT, "explicit fast-memory tables"),
    "tail_recursion_elimination": (Relevance.DIRECT, "enables hardware mapping"),
    "regular_array_decomposition": (Relevance.DIRECT, "on/off-chip partitioning"),
    "short_circuiting": (Relevance.NONE, "all logic is instantiated anyway"),
    "code_colocation": (Relevance.NONE, "no runtime function calls"),
    "vliw_transforms": (Relevance.NONE, "no instruction stream"),
    "cache_alignment": (Relevance.NONE, "no implicit cache coherence"),
    "supercompiling": (Relevance.NONE, "synthesis times prohibitive"),
}
