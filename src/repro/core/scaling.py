"""Scaling transformations (paper §3): vectorization, replication, tiling.

On the FPGA, scaling = folding pipelined loops into unrolled hardware.  On
the TPU the "unrolled hardware" already exists (8x128 VPU lanes, 128x128 MXU,
N chips) — the transformation becomes *choosing shapes and shardings that
keep it fed*:

* vectorization §3.1  -> pad/align trailing dims to (sublane, lane) tiles,
* replication  §3.2   -> reuse-fed parallelism: K-blocking in kernels,
                         TP/EP sharding across chips,
* tiling       §3.4   -> ``TilePlanner``: solve BlockSpec shapes against the
                         VMEM budget, the paper's "fit fast memory" objective.

``TilePlanner`` is used by every Pallas kernel in ``repro.kernels`` to derive
its BlockSpecs, so the kernels' VMEM claims are *planned*, not guessed — the
roofline napkin math in EXPERIMENTS.md §Perf reads straight off it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .model import TPU_V5E, HardwareSpec


def round_up(x: int, to: int) -> int:
    return ((x + to - 1) // to) * to


def vector_pad(shape: Sequence[int], dtype_bytes: int = 4,
               hw: HardwareSpec = TPU_V5E) -> Tuple[int, ...]:
    """Vectorization §3.1: the lane-aligned shape the VPU actually processes.

    Trailing dim pads to the 128-lane width; the second-to-last pads to the
    sublane count scaled by the packing factor of the dtype (bf16 packs 2x,
    int8 4x) — narrower types widen W, the paper's W_max = B/(f*S).
    """
    if not shape:
        return tuple(shape)
    packing = max(1, 4 // dtype_bytes)
    out = list(shape)
    out[-1] = round_up(out[-1], hw.lane)
    if len(out) >= 2:
        out[-2] = round_up(out[-2], hw.sublane * packing)
    return tuple(out)


def lane_utilization(shape: Sequence[int], dtype_bytes: int = 4,
                     hw: HardwareSpec = TPU_V5E) -> float:
    """Fraction of VPU lanes doing useful work for this (unpadded) shape."""
    padded = vector_pad(shape, dtype_bytes, hw)
    used = math.prod(shape) if shape else 1
    total = math.prod(padded) if padded else 1
    return used / total


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """A solved tiling for a matmul-like kernel (bm, bn, bk blocks)."""

    bm: int
    bn: int
    bk: int
    vmem_bytes: int          # working set incl. double buffering
    grid: Tuple[int, ...]    # (m/bm, n/bn, k/bk)
    flops_per_step: float
    hbm_bytes_per_step: float

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_step / max(self.hbm_bytes_per_step, 1)


class TilePlanner:
    """Tiling §3.4 as a solver: pick MXU-aligned (bm, bn, bk) maximizing
    arithmetic intensity subject to the VMEM budget.

    Working set per grid step for C[bm,bn] += A[bm,bk] @ B[bk,bn]:
        A-block + B-block (double-buffered: x2 for DMA overlap, the paper's
        memory oversubscription §4.2) + C-accumulator (single, revisited).
    Larger bm*bn raises reuse of each loaded A/B element — the §3.2
    "replication fed by reuse" argument in shape form.
    """

    def __init__(self, hw: HardwareSpec = TPU_V5E, *,
                 vmem_fraction: float = 0.75,
                 double_buffer: bool = True):
        self.hw = hw
        self.budget = int(hw.vmem_bytes * vmem_fraction)
        self.double_buffer = double_buffer

    def plan_matmul(self, m: int, n: int, k: int, *,
                    in_bytes: int = 2, acc_bytes: int = 4,
                    candidates: Optional[Sequence[int]] = None) -> TilePlan:
        cands = list(candidates or (128, 256, 512, 1024, 2048))
        best: Optional[TilePlan] = None
        mxu = self.hw.mxu_dim
        for bm in cands:
            if bm > round_up(m, mxu):
                continue
            for bn in cands:
                if bn > round_up(n, mxu):
                    continue
                for bk in cands:
                    if bk > round_up(k, mxu):
                        continue
                    buf = 2 if self.double_buffer else 1
                    vmem = (bm * bk + bk * bn) * in_bytes * buf \
                        + bm * bn * acc_bytes
                    if vmem > self.budget:
                        continue
                    grid = (math.ceil(m / bm), math.ceil(n / bn),
                            math.ceil(k / bk))
                    flops = 2.0 * bm * bn * bk
                    hbm = (bm * bk + bk * bn) * in_bytes
                    plan = TilePlan(bm, bn, bk, vmem, grid, flops, hbm)
                    if best is None or _better(plan, best):
                        best = plan
        if best is None:
            raise ValueError(
                f"no MXU-aligned tiling of ({m},{n},{k}) fits "
                f"{self.budget} bytes of VMEM")
        return best

    def plan_stencil(self, rows: int, cols: int, halo: int = 1, *,
                     dtype_bytes: int = 4,
                     candidates: Optional[Sequence[int]] = None
                     ) -> Tuple[int, int]:
        """Block shape for a 2-D stencil: (brows+2*halo, bcols+2*halo) input
        window + (brows, bcols) output, double-buffered.  The halo overlap is
        the TPU form of the paper's delay buffer — each interior row is
        DMA'd once per block instead of once per use."""
        cands = list(candidates or (128, 256, 512, 1024, 2048, 4096))
        best = None
        for br in cands:
            if br > round_up(rows, self.hw.sublane):
                continue
            for bc in cands:
                if bc > round_up(cols, self.hw.lane):
                    continue
                vmem = ((br + 2 * halo) * (bc + 2 * halo) + br * bc) \
                    * dtype_bytes * 2
                if vmem > self.budget:
                    continue
                waste = ((br + 2 * halo) * (bc + 2 * halo)) / (br * bc)
                key = (waste, -br * bc)
                if best is None or key < best[0]:
                    best = (key, (br, bc))
        if best is None:
            raise ValueError("no stencil tiling fits VMEM")
        return best[1]


def _better(a: TilePlan, b: TilePlan) -> bool:
    """Prefer higher arithmetic intensity; tie-break on fewer grid steps."""
    ka = (a.arithmetic_intensity, -math.prod(a.grid))
    kb = (b.arithmetic_intensity, -math.prod(b.grid))
    return ka > kb


def replication_factor(reuse: int, unit_flops: float,
                       hw: HardwareSpec = TPU_V5E) -> int:
    """§3.2 napkin math: with `reuse` uses per loaded element, how many
    parallel units can one HBM stream feed before compute saturates?
        P_max = reuse * machine_balance / (flops per element per unit)
    """
    balance = hw.peak_flops / hw.hbm_bw
    return max(1, int(reuse * balance / max(unit_flops, 1e-9)))
