"""Scaling transformations (paper §3): vectorization, replication, tiling.

On the FPGA, scaling = folding pipelined loops into unrolled hardware.  On
the TPU the "unrolled hardware" already exists (8x128 VPU lanes, 128x128 MXU,
N chips) — the transformation becomes *choosing shapes and shardings that
keep it fed*:

* vectorization §3.1  -> pad/align trailing dims to (sublane, lane) tiles,
* replication  §3.2   -> reuse-fed parallelism: K-blocking in kernels,
                         TP/EP sharding across chips,
* tiling       §3.4   -> ``TilePlanner``: solve BlockSpec shapes against the
                         VMEM budget, the paper's "fit fast memory" objective.

``TilePlanner`` is used by every Pallas kernel in ``repro.kernels`` to derive
its BlockSpecs, so the kernels' VMEM claims are *planned*, not guessed — the
roofline napkin math in EXPERIMENTS.md §Perf reads straight off it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .model import TPU_V5E, HardwareSpec


def round_up(x: int, to: int) -> int:
    return ((x + to - 1) // to) * to


def vector_pad(shape: Sequence[int], dtype_bytes: int = 4,
               hw: HardwareSpec = TPU_V5E) -> Tuple[int, ...]:
    """Vectorization §3.1: the lane-aligned shape the VPU actually processes.

    Trailing dim pads to the 128-lane width; the second-to-last pads to the
    sublane count scaled by the packing factor of the dtype (bf16 packs 2x,
    int8 4x) — narrower types widen W, the paper's W_max = B/(f*S).
    """
    if not shape:
        return tuple(shape)
    packing = max(1, 4 // dtype_bytes)
    out = list(shape)
    out[-1] = round_up(out[-1], hw.lane)
    if len(out) >= 2:
        out[-2] = round_up(out[-2], hw.sublane * packing)
    return tuple(out)


def lane_utilization(shape: Sequence[int], dtype_bytes: int = 4,
                     hw: HardwareSpec = TPU_V5E) -> float:
    """Fraction of VPU lanes doing useful work for this (unpadded) shape."""
    padded = vector_pad(shape, dtype_bytes, hw)
    used = math.prod(shape) if shape else 1
    total = math.prod(padded) if padded else 1
    return used / total


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """A solved tiling for a matmul-like kernel (bm, bn, bk blocks)."""

    bm: int
    bn: int
    bk: int
    vmem_bytes: int          # working set incl. double buffering
    grid: Tuple[int, ...]    # (m/bm, n/bn, k/bk)
    flops_per_step: float
    hbm_bytes_per_step: float

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_step / max(self.hbm_bytes_per_step, 1)


class TilePlanner:
    """Tiling §3.4 as a solver: pick MXU-aligned (bm, bn, bk) maximizing
    arithmetic intensity subject to the VMEM budget.

    Working set per grid step for C[bm,bn] += A[bm,bk] @ B[bk,bn]:
        A-block + B-block (double-buffered: x2 for DMA overlap, the paper's
        memory oversubscription §4.2) + C-accumulator (single, revisited).
    Larger bm*bn raises reuse of each loaded A/B element — the §3.2
    "replication fed by reuse" argument in shape form.
    """

    def __init__(self, hw: HardwareSpec = TPU_V5E, *,
                 vmem_fraction: float = 0.75,
                 double_buffer: bool = True):
        self.hw = hw
        self.budget = int(hw.vmem_bytes * vmem_fraction)
        self.double_buffer = double_buffer

    def plan_from_tiles(self, m: int, n: int, k: int,
                        bm: int, bn: int, bk: int, *,
                        in_bytes: int = 2, acc_bytes: int = 4) -> TilePlan:
        """Materialize the TilePlan for explicit (bm, bn, bk) tiles, or raise
        if the working set exceeds the VMEM budget.  This is the single
        feasibility check shared by the heuristic solver, the autotuner's
        space enumeration, and cache-deserialized plans."""
        buf = 2 if self.double_buffer else 1
        vmem = (bm * bk + bk * bn) * in_bytes * buf + bm * bn * acc_bytes
        if vmem > self.budget:
            raise ValueError(
                f"tiles ({bm},{bn},{bk}) need {vmem} bytes of VMEM, "
                f"budget is {self.budget}")
        grid = (math.ceil(m / bm), math.ceil(n / bn), math.ceil(k / bk))
        flops = 2.0 * bm * bn * bk
        hbm = (bm * bk + bk * bn) * in_bytes
        return TilePlan(bm, bn, bk, vmem, grid, flops, hbm)

    def enumerate_matmul(self, m: int, n: int, k: int, *,
                         in_bytes: int = 2, acc_bytes: int = 4,
                         candidates: Optional[Sequence[int]] = None
                         ) -> List[TilePlan]:
        """All feasible MXU-aligned tilings within the VMEM budget — the
        autotuner's matmul design space (§3.4 as an enumerable set rather
        than a point solution).  Sorted best-first by the heuristic order
        so `[0]`, when non-empty, is what ``plan_matmul`` returns."""
        cands = list(candidates or (128, 256, 512, 1024, 2048))
        mxu = self.hw.mxu_dim
        plans: List[TilePlan] = []
        for bm in cands:
            # tiles must divide the (clamped) problem dim: matmul_pallas
            # shrinks b to min(b, dim) and rejects ragged grids
            if bm > round_up(m, mxu) or m % min(bm, m):
                continue
            for bn in cands:
                if bn > round_up(n, mxu) or n % min(bn, n):
                    continue
                for bk in cands:
                    if bk > round_up(k, mxu) or k % min(bk, k):
                        continue
                    try:
                        plans.append(self.plan_from_tiles(
                            m, n, k, bm, bn, bk,
                            in_bytes=in_bytes, acc_bytes=acc_bytes))
                    except ValueError:
                        continue
        plans.sort(key=_plan_order_key, reverse=True)
        return plans

    def plan_matmul(self, m: int, n: int, k: int, *,
                    in_bytes: int = 2, acc_bytes: int = 4,
                    candidates: Optional[Sequence[int]] = None) -> TilePlan:
        plans = self.enumerate_matmul(m, n, k, in_bytes=in_bytes,
                                      acc_bytes=acc_bytes,
                                      candidates=candidates)
        if not plans:
            raise ValueError(
                f"no MXU-aligned tiling of ({m},{n},{k}) fits "
                f"{self.budget} bytes of VMEM")
        return plans[0]

    def enumerate_stencil(self, rows: int, cols: int, halo: int = 1, *,
                          dtype_bytes: int = 4,
                          candidates: Optional[Sequence[int]] = None
                          ) -> List[Tuple[int, int]]:
        """All feasible (brows, bcols) stencil blocks within the VMEM budget,
        sorted best-first by halo waste (then larger blocks) — the
        autotuner's stencil design space."""
        cands = list(candidates or (128, 256, 512, 1024, 2048, 4096))
        feasible = []
        for br in cands:
            if br > round_up(rows, self.hw.sublane):
                continue
            for bc in cands:
                if bc > round_up(cols, self.hw.lane):
                    continue
                vmem = ((br + 2 * halo) * (bc + 2 * halo) + br * bc) \
                    * dtype_bytes * 2
                if vmem > self.budget:
                    continue
                waste = ((br + 2 * halo) * (bc + 2 * halo)) / (br * bc)
                feasible.append(((waste, -br * bc), (br, bc)))
        feasible.sort(key=lambda kv: kv[0])
        return [blk for _, blk in feasible]

    def plan_stencil(self, rows: int, cols: int, halo: int = 1, *,
                     dtype_bytes: int = 4,
                     candidates: Optional[Sequence[int]] = None
                     ) -> Tuple[int, int]:
        """Block shape for a 2-D stencil: (brows+2*halo, bcols+2*halo) input
        window + (brows, bcols) output, double-buffered.  The halo overlap is
        the TPU form of the paper's delay buffer — each interior row is
        DMA'd once per block instead of once per use."""
        blocks = self.enumerate_stencil(rows, cols, halo,
                                        dtype_bytes=dtype_bytes,
                                        candidates=candidates)
        if not blocks:
            raise ValueError("no stencil tiling fits VMEM")
        return blocks[0]


def _plan_order_key(p: TilePlan):
    """Heuristic rank: higher arithmetic intensity, then fewer grid steps."""
    return (p.arithmetic_intensity, -math.prod(p.grid))


def replication_factor(reuse: int, unit_flops: float,
                       hw: HardwareSpec = TPU_V5E) -> int:
    """§3.2 napkin math: with `reuse` uses per loaded element, how many
    parallel units can one HBM stream feed before compute saturates?
        P_max = reuse * machine_balance / (flops per element per unit)
    """
    balance = hw.peak_flops / hw.hbm_bw
    return max(1, int(reuse * balance / max(unit_flops, 1e-9)))
