"""Performance models: the paper's pipeline model (§1.2) and a TPU roofline.

The paper quantifies pipelines with two numbers — latency ``L`` (depth in
cycles) and initiation interval ``I`` (cycles between accepted inputs) — and
the total cycle count

    C = L + I * (N - 1)                                              (Eq. 1)

for N inputs.  Sequential pipelines compose as ``L = L0 + L1`` with
``I = max(I0, I1)``.  We reuse this model verbatim for TPU reasoning:

* a Pallas grid is a pipeline whose N is the number of grid steps and whose I
  is ``max(compute_cycles, dma_cycles)`` per step (double buffering makes the
  DMA a pipeline stage exactly like the paper's "memory extraction"),
* a scan-over-layers is a pipeline over layers,
* fill/drain overhead (the paper's §2.5 motivation) is ``L / C``.

``Roofline`` holds the three dry-run-derived terms used in EXPERIMENTS.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass(frozen=True)
class PipelineModel:
    """The paper's Eq. 1: C = L + I * (N - 1)."""

    latency: float          # L [cycles]
    initiation_interval: float  # I [cycles]
    n: float                # N [inputs]

    def cycles(self) -> float:
        return self.latency + self.initiation_interval * (self.n - 1)

    def seconds(self, clock_hz: float) -> float:
        return self.cycles() / clock_hz

    def fill_drain_overhead(self) -> float:
        """Fraction of cycles lost to fill/drain (what §2.5 eliminates)."""
        c = self.cycles()
        return self.latency / c if c else 0.0

    def then(self, other: "PipelineModel") -> "PipelineModel":
        """Sequential composition (paper: L adds, I is max)."""
        if self.n != other.n:
            raise ValueError("sequential pipelines must agree on N")
        return PipelineModel(
            latency=self.latency + other.latency,
            initiation_interval=max(self.initiation_interval,
                                    other.initiation_interval),
            n=self.n,
        )

    def folded(self, factor: float) -> "PipelineModel":
        """Scaling transformations (§3) fold the iteration space by `factor`."""
        return PipelineModel(self.latency, self.initiation_interval,
                             math.ceil(self.n / factor))


# --------------------------------------------------------------------------
# TPU v5e hardware constants (the assignment's numbers).
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float         # FLOP/s per chip (bf16)
    hbm_bw: float             # B/s per chip
    ici_bw: float             # B/s per link
    hbm_bytes: float          # HBM capacity per chip
    vmem_bytes: float         # VMEM per core
    clock_hz: float
    mxu_dim: int = 128        # systolic array edge
    lane: int = 128           # VPU lane count
    sublane: int = 8


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=16 * 1024**2,
    clock_hz=940e6,
)


@dataclass
class Roofline:
    """Three-term roofline for one (arch x shape x mesh) dry-run cell."""

    name: str
    chips: int
    hlo_flops: float               # total, all chips
    hlo_bytes: float               # HBM traffic, all chips
    collective_bytes: float        # total bytes crossing ICI, all chips
    model_flops: float             # 6*N*D analytic "useful" FLOPs
    hw: HardwareSpec = field(default_factory=lambda: TPU_V5E)

    # ---- the three terms, in seconds ----
    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * self.hw.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * self.hw.hbm_bw)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * self.hw.ici_bw)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Optimistic overlap model: bound by the slowest roofline term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modeled step
        time: MODEL_FLOPS / (step_s * chips * peak)."""
        denom = self.step_s * self.chips * self.hw.peak_flops
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict[str, float]:
        return {
            "name": self.name,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def dense_model_flops(n_params: int, n_tokens: int) -> float:
    """MODEL_FLOPS = 6 * N * D for a dense decoder train step."""
    return 6.0 * n_params * n_tokens


def arithmetic_intensity(flops: float, bytes_: float) -> float:
    return flops / bytes_ if bytes_ else float("inf")


def machine_balance(hw: HardwareSpec = TPU_V5E) -> float:
    """FLOP/B at which a kernel transitions memory- to compute-bound."""
    return hw.peak_flops / hw.hbm_bw
