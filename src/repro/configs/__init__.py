from .base import (  # noqa: F401
    ArchConfig,
    ShapeSpec,
    SHAPES,
    input_specs,
    shape_applicable,
)
from .archs import ARCHS  # noqa: F401


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
