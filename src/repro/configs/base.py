"""ArchConfig: one declarative description drives model build, sharding,
dry-run input specs, smoke reduction, and MODEL_FLOPS accounting."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# layer descriptor: (mixer, ffn)
#   mixer in {"attn", "swa", "rwkv", "rglru"}
#   ffn   in {"mlp", "moe", "rwkv_cm"}
LayerKind = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern: `prefix` explicit layers, then `pattern` repeated.
    pattern: Tuple[LayerKind, ...] = (("attn", "mlp"),)
    prefix: Tuple[LayerKind, ...] = ()
    window: int = 0               # sliding-window size for "swa" mixers
    activation: str = "swiglu"
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, ...] = ()
    qkv_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma-style sqrt(d) embedding scaling
    input_mode: str = "tokens"    # tokens | embeddings (audio/vlm stubs)
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    shared_d_expert: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 64
    rwkv_intra: str = "direct"    # "matmul" = §Perf-1 optimized WKV
    lru_width: int = 0
    conv_width: int = 4
    # long-context capability (sub-quadratic): gates long_500k
    subquadratic: bool = False
    # kernel routing for every hot matmul/attention (repro.kernels.dispatch):
    # "kernels" forces the Pallas path, "reference" forces the einsum
    # lowering (tests / dry-runs force either), "auto" picks per backend
    dispatch: str = "auto"
    # serving KV-cache layout: "dense" = rectangular (slots, max_len)
    # rolling caches; "paged" = fixed-size pages + per-slot page tables
    # (--cache on launch/serve.py; decode routes through
    # dispatch.decode_attention)
    kv_cache: str = "dense"
    # page size for the paged layout; 0 = pick from tuned decode plans
    # (falls back to 64 when no tuned entry matches)
    kv_page_size: int = 0
    # paged KV-cache storage dtype: "" = model compute dtype; "int8"
    # stores pages as symmetric int8 with per-(page, kv-head) f32 scales
    # (quantize-on-write; the ragged kernels dequantize at tile load) —
    # --kv-dtype on launch/serve.py
    kv_dtype: str = ""
    # projection/MLP weight GEMMs: "" = float weights through
    # dispatch.matmul; "int8" = per-channel quantized weights through
    # dispatch.quantized_matmul (inference only)
    weights_dtype: str = ""
    notes: str = ""

    # ------------------------------------------------------------------
    def layer_kinds(self) -> Tuple[LayerKind, ...]:
        """The full, ordered list of (mixer, ffn) for all n_layers."""
        kinds = list(self.prefix)
        while len(kinds) < self.n_layers:
            kinds.extend(self.pattern)
        return tuple(kinds[: self.n_layers])

    def distinct_kinds(self) -> Tuple[LayerKind, ...]:
        seen, out = set(), []
        for k in self.layer_kinds():
            if k not in seen:
                seen.add(k)
                out.append(k)
        return tuple(out)

    def kind_counts(self) -> Dict[LayerKind, int]:
        counts: Dict[LayerKind, int] = {}
        for k in self.layer_kinds():
            counts[k] = counts.get(k, 0) + 1
        return counts

    # ------------------------------------------------------------------
    def with_layers(self, kinds: Tuple[LayerKind, ...]) -> "ArchConfig":
        """Override to an explicit (small) layer list — used by dry-run cost
        compiles and smoke tests."""
        return dataclasses.replace(
            self, n_layers=len(kinds), prefix=tuple(kinds), pattern=())

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        reduce = {
            "d_model": 128, "n_heads": 4, "n_kv_heads": min(self.n_kv_heads, 4)
            if self.n_kv_heads else 0, "head_dim": 32,
            "d_ff": 256, "vocab_size": 512,
        }
        kinds = self.layer_kinds()
        small_kinds = tuple(dict.fromkeys(kinds))[:3]  # one of each kind
        cfg = dataclasses.replace(
            self,
            name=self.name + "-smoke",
            **reduce,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            d_expert=64 if self.n_experts else 0,
            shared_d_expert=64 if self.n_shared_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            lru_width=128 if self.lru_width else 0,
            rwkv_head_dim=32,
            rwkv_chunk=16,
            window=min(self.window, 16) if self.window else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
        )
        return cfg.with_layers(small_kinds + small_kinds[:1])  # >=2 layers

    # ------------------------------------------------------------------
    # parameter accounting (exact; validated against the real param tree)
    # ------------------------------------------------------------------
    def param_counts(self) -> Dict[str, float]:
        from ..models import transformer as tfm  # lazy, avoids cycle
        return tfm.param_counts(self)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Spec-mandated skips: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k-token KV footprint is "
                       "quadratic-history; skipped per assignment "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                compute_dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill -> token (or stub-embedding) batch + labels;
    decode        -> one new token per sequence (cache specs come from the
                     model, see Model.cache_specs).
    """
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "embeddings":
            batch = {"embeddings": sds((b, s, cfg.d_model), compute_dtype)}
        else:
            batch = {"tokens": sds((b, s), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = sds((b, s), jnp.int32)
        if cfg.mrope_sections:
            batch["positions"] = sds((b, s, len(cfg.mrope_sections)),
                                     jnp.int32)
        return batch
    # decode: one token per sequence
    if cfg.input_mode == "embeddings":
        batch = {"embeddings": sds((b, 1, cfg.d_model), compute_dtype)}
    else:
        batch = {"tokens": sds((b, 1), jnp.int32)}
    if cfg.mrope_sections:
        batch["positions"] = sds((b, 1, len(cfg.mrope_sections)), jnp.int32)
    return batch
