"""The 10 assigned architectures, exactly as specified in the assignment.

Sources are in brackets in the assignment; structural details beyond the
one-line spec (patterns, shared experts, head dims) follow the cited public
configs and are noted inline.  Every config here is validated by a smoke
test (tests/test_archs.py) and exercised full-size by the dry-run.
"""
from __future__ import annotations

from .base import ArchConfig

# ---- MoE --------------------------------------------------------------

QWEN2_MOE_A2_7B = ArchConfig(
    # [hf:Qwen/Qwen1.5-MoE-A2.7B] 24L d=2048 16H (kv=16) d_ff(expert)=1408
    # vocab=151936, 60 routed top-4 + 4 shared (fused 5632-wide shared MLP)
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=5632, vocab_size=151936,
    pattern=(("attn", "moe"),),
    n_experts=60, top_k=4, d_expert=1408,
    n_shared_experts=4, shared_d_expert=5632,
    activation="swiglu", qkv_bias=True, rope_theta=1e6,
    notes="shared experts fused into one 5632-wide MLP; norm_topk routing",
)

KIMI_K2_1T_A32B = ArchConfig(
    # [arXiv:2501.kimi2] 61L d=7168 64H (kv=8) moe_ff=2048 vocab=163840,
    # 384 experts top-8 (+1 shared, DeepSeek-V3 lineage; first layer dense
    # with ff=18432 per the DS-V3 recipe)
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=18432, vocab_size=163840,
    prefix=(("attn", "mlp"),),
    pattern=(("attn", "moe"),),
    n_experts=384, top_k=8, d_expert=2048,
    n_shared_experts=1, shared_d_expert=2048,
    activation="swiglu", rope_theta=5e4,
    notes="assignment mandates GQA kv=8 (real K2 uses MLA); 1 dense first "
          "layer; type demotion (§4.4 int8 moments) required to fit 512 "
          "chips — see EXPERIMENTS.md",
)

# ---- audio ------------------------------------------------------------

MUSICGEN_LARGE = ArchConfig(
    # [arXiv:2306.05284] 48L d=2048 32H d_ff=8192 vocab=2048 (EnCodec
    # codebook). Frontend (EnCodec + codebook delay interleave + text
    # conditioning) is a STUB: input_specs feeds precomputed frame
    # embeddings per the assignment.
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    pattern=(("attn", "mlp"),),
    activation="gelu", rope_theta=1e4, input_mode="embeddings",
    notes="decoder-only over EnCodec tokens; cross-attn conditioning "
          "stubbed (frame embeddings already conditioned)",
)

# ---- dense ------------------------------------------------------------

GEMMA3_4B = ArchConfig(
    # [hf:google/gemma-3-*] 34L d=2560 8H (kv=4) d_ff=10240 vocab=262144,
    # 5 local (sliding 1024) : 1 global, head_dim 256, GeGLU, tied embed
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    pattern=(("swa", "mlp"),) * 5 + (("attn", "mlp"),),
    window=1024, activation="geglu", rope_theta=1e6,
    tie_embeddings=True, embed_scale=True,
    subquadratic=True,
    notes="hybrid local:global 5:1 -> long_500k runs (global layers are "
          "decode-linear; local layers keep a 1024-slot rolling cache)",
)

GEMMA_2B = ArchConfig(
    # [arXiv:2403.08295] 18L d=2048 8H MQA(kv=1) d_ff=16384 vocab=256000,
    # GeGLU, head_dim=256, tied embeddings
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    pattern=(("attn", "mlp"),),
    activation="geglu", rope_theta=1e4,
    tie_embeddings=True, embed_scale=True,
)

DEEPSEEK_67B = ArchConfig(
    # [arXiv:2401.02954] 95L d=8192 64H (kv=8) d_ff=22016 vocab=102400,
    # llama-arch (SwiGLU, RMSNorm, RoPE)
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400,
    pattern=(("attn", "mlp"),),
    activation="swiglu", rope_theta=1e4,
)

CODEQWEN15_7B = ArchConfig(
    # [hf:Qwen/CodeQwen1.5-7B] 32L d=4096 32H (kv=32... spec says kv=32;
    # hf config uses GQA kv=4 for codeqwen — we follow the assignment)
    # d_ff=13440 vocab=92416, qwen1.5 arch (QKV bias)
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    pattern=(("attn", "mlp"),),
    activation="swiglu", qkv_bias=True, rope_theta=1e6,
)

# ---- SSM / hybrid -----------------------------------------------------

RWKV6_7B = ArchConfig(
    # [arXiv:2404.05892] Finch 32L d=4096 attn-free d_ff=14336 vocab=65536,
    # data-dependent decay, head_dim 64
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=0, head_dim=64,
    d_ff=14336, vocab_size=65536,
    pattern=(("rwkv", "rwkv_cm"),),
    rwkv_head_dim=64, rwkv_chunk=64,
    subquadratic=True,
    notes="attention transformations inapplicable (attn-free); chunked scan "
          "= tiled accumulation interleaving §2.1.2 on the matrix-state "
          "recurrence",
)

RECURRENTGEMMA_9B = ArchConfig(
    # [arXiv:2402.19427] Griffin: 38L d=4096 16H (kv=1, MQA) d_ff=12288,
    # vocab=256000, pattern 2 recurrent : 1 local-attn (window 2048),
    # lru_width=4096, GeGLU
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("swa", "mlp")),
    window=2048, lru_width=4096, conv_width=4,
    activation="geglu", tie_embeddings=True, embed_scale=True,
    subquadratic=True,
    notes="RG-LRU via associative_scan (log-depth); local attn keeps a "
          "2048-slot rolling cache",
)

# ---- VLM --------------------------------------------------------------

QWEN2_VL_2B = ArchConfig(
    # [arXiv:2409.12191] 28L d=1536 12H (kv=2) d_ff=8960 vocab=151936,
    # M-RoPE (sections 16/24/24 over head_dim/2), vision tower STUBBED:
    # input_specs feeds precomputed patch embeddings + 3-axis positions.
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    pattern=(("attn", "mlp"),),
    activation="swiglu", qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), tie_embeddings=True,
    input_mode="embeddings",
    notes="backbone only per assignment; M-RoPE positions provided by the "
          "(stub) frontend",
)


ARCHS = {
    c.name: c
    for c in [
        QWEN2_MOE_A2_7B, KIMI_K2_1T_A32B, MUSICGEN_LARGE, GEMMA3_4B,
        GEMMA_2B, DEEPSEEK_67B, CODEQWEN15_7B, RWKV6_7B, RECURRENTGEMMA_9B,
        QWEN2_VL_2B,
    ]
}
