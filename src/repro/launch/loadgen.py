"""Arrival-clock load generation for the serving engine.

The continuous-batching engine (``launch/engine.py``) consumes a stream
of timed :class:`Request` s instead of a static list: every request
carries an ``arrival`` timestamp on a virtual clock, and the engine only
sees a request once its clock has reached that time.  Two generators:

* :func:`poisson_stream` — seeded open-loop Poisson arrivals
  (inter-arrival ~ Exp(1/rate)); ``rate == 0`` collapses to a burst at
  t = 0 (every request in-queue before the first iteration — the
  deterministic shape benchmarks prefer).
* :func:`trace_stream` — trace-driven arrivals from explicit
  ``{"t", "prompt_len" | "tokens", "max_new"}`` events (replayed
  production traces, adversarial test workloads).

Both are fully determined by their seed: same seed, same arrival times,
same prompt tokens — the property the engine's determinism tests pin.
:class:`ArrivalQueue` orders a stream by arrival (stable on ties, so
FCFS follows stream order) and pops the ready prefix each iteration.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, List, Mapping, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    arrival: float = 0.0
    truncated: bool = False    # finished by the context wall, not max_new


def poisson_stream(n: int, *, rate: float, vocab_size: int,
                   prompt_len: int, max_new: int, seed: int = 0,
                   prompt_jitter: int = 0, start_rid: int = 0,
                   shared_prefix_len: int = 0, shared_frac: float = 0.0
                   ) -> List[Request]:
    """``n`` seeded Poisson arrivals at ``rate`` requests per clock unit.

    ``prompt_jitter`` adds a uniform 0..jitter extension to each prompt
    length (ragged traffic); ``rate == 0`` puts every arrival at t = 0.

    ``shared_prefix_len`` > 0 models system/tool-prompt reuse: one common
    prefix of that length is drawn once per stream, and each request
    independently carries it with probability ``shared_frac`` (its unique
    tokens fill the remaining ``prompt_len - shared_prefix_len``
    positions).  The default (0, 0.0) draws exactly the same streams as
    before — the extra rng calls only happen when a prefix is configured.
    """
    if shared_prefix_len > prompt_len:
        raise ValueError(
            f"shared_prefix_len {shared_prefix_len} > prompt_len "
            f"{prompt_len}")
    rng = np.random.default_rng(seed)
    prefix = (rng.integers(0, vocab_size, shared_prefix_len)
              if shared_prefix_len > 0 else None)
    t = 0.0
    reqs: List[Request] = []
    for i in range(n):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        ln = prompt_len + (int(rng.integers(0, prompt_jitter + 1))
                           if prompt_jitter else 0)
        if prefix is not None and float(rng.random()) < shared_frac:
            tail = rng.integers(0, vocab_size, ln - shared_prefix_len)
            prompt = np.concatenate([prefix, tail])
        else:
            prompt = rng.integers(0, vocab_size, ln)
        reqs.append(Request(start_rid + i, prompt, max_new, arrival=t))
    return reqs


def trace_stream(trace: Iterable[Mapping], *, vocab_size: int,
                 seed: int = 0, start_rid: int = 0) -> List[Request]:
    """Trace-driven arrivals: one event per request.

    Each event is a mapping with ``t`` (arrival time, default 0.0),
    ``max_new``, and either explicit ``tokens`` or a ``prompt_len`` whose
    tokens are drawn from the seeded rng.  ``start_rid`` offsets the
    assigned rids so several streams can be mixed without collisions
    (``ServeMetrics.timelines`` and :class:`ArrivalQueue` key on rid).
    """
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for i, ev in enumerate(trace):
        if "tokens" in ev:
            prompt = np.asarray(ev["tokens"], np.int64)
        else:
            prompt = rng.integers(0, vocab_size, int(ev["prompt_len"]))
        reqs.append(Request(start_rid + i, prompt, int(ev["max_new"]),
                            arrival=float(ev.get("t", 0.0))))
    return reqs


class ArrivalQueue:
    """A request stream ordered by arrival time on the virtual clock.

    The sort is stable, so requests arriving at the same instant keep
    their stream order (FCFS).  ``pop_ready(now)`` hands the engine every
    request whose arrival has passed; ``next_arrival()`` lets an idle
    engine jump its clock forward instead of spinning.
    """

    def __init__(self, requests: Iterable[Request]):
        self._pending: List[Request] = sorted(requests,
                                              key=lambda r: r.arrival)
        rids = [r.rid for r in self._pending]
        if len(set(rids)) != len(rids):
            dups = sorted({r for r in rids if rids.count(r) > 1})
            raise ValueError(
                f"duplicate request rids in stream: {dups} "
                "(mixing streams? pass start_rid to the generators)")
        self._i = 0

    def __len__(self) -> int:
        return len(self._pending) - self._i

    def next_arrival(self) -> Optional[float]:
        if self._i >= len(self._pending):
            return None
        return self._pending[self._i].arrival

    def pop_ready(self, now: float) -> List[Request]:
        out: List[Request] = []
        while (self._i < len(self._pending)
               and self._pending[self._i].arrival <= now):
            out.append(self._pending[self._i])
            self._i += 1
        return out
