import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell this driver:

1. builds the production mesh — (data=16, model=16) and, unless skipped,
   (pod=2, data=16, model=16);
2. compiles the full-depth scanned train_step / serve_step with real
   in/out shardings (`.lower().compile()`), records
   ``compiled.memory_analysis()`` (fits?) and the collective schedule;
3. runs the *cost* compiles — python-unrolled 0-layer and 1-layer-per-kind
   variants — and affine-extrapolates exact per-step FLOPs / HBM bytes /
   collective bytes to full depth (XLA counts scan bodies once; DESIGN.md
   §6 explains the method and its validation);
4. emits one JSON per cell under results/dryrun/ used by the roofline
   report generator.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_arch, input_specs, shape_applicable
from ..configs.base import ArchConfig, ShapeSpec
from ..core.memory import DtypePolicy
from ..core.model import TPU_V5E, Roofline
from ..models.transformer import ExecOptions, Model, param_counts
from ..optim.adamw import AdamWConfig
from ..roofline.analysis import analyze_compiled
from ..runtime.sharding import MeshRules, make_rules, tree_shardings
from ..train.steps import (TrainStepConfig, abstract_train_state,
                           make_train_step, make_serve_step)
from .mesh import make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

BIG_PARAM_THRESHOLD = 30e9      # archs above this get bf16 params + int8 Adam


def policy_for(cfg: ArchConfig, kind: str) -> Tuple[DtypePolicy, bool]:
    """(dtype policy, int8_moments) — type demotion §4.4 decisions."""
    big = param_counts(cfg)["total"] >= BIG_PARAM_THRESHOLD
    if kind in ("decode", "prefill_serve"):
        return DtypePolicy(param=jnp.bfloat16), False
    if big:
        return DtypePolicy(param=jnp.bfloat16), True
    return DtypePolicy(param=jnp.float32), False


def block_sizes(seq: int) -> Tuple[int, int]:
    b = min(max(512, seq // 8), 4096)
    b = min(b, seq)
    return b, b


def make_constrain(rules: MeshRules):
    def con(x):
        if x.ndim != 3:
            return x
        spec = rules.activation_spec(x.shape)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, spec))
    return con


def attn_hook(rules: MeshRules):
    """q/k/v sharding at attention entry (Megatron SP->TP transition):
    heads over `model` when divisible; otherwise q falls back to sequence
    sharding (its rows are independent) and k/v replicate over model."""
    model = rules.model_axis
    msz = rules.axis_size(model)

    def hook(t, role):
        if t.ndim != 4:
            return t
        b, sq, h, _hd = t.shape
        dp = rules.dp_axes if b % rules.axis_size(rules.dp_axes) == 0 \
            else ("data" if b % rules.axis_size("data") == 0 else None)
        seq_ok = sq > 1 and sq % msz == 0
        if rules.attn_prefer_seq and seq_ok:
            # §Perf-2: sequence-parallel attention — q/k/v stay seq-sharded,
            # all heads local; no residual-stream resharding at all
            spec = P(dp, model, None, None) if role == "q" \
                else P(dp, None, None, None)
        elif h % msz == 0:
            spec = P(dp, None, model, None)
        elif role == "q" and seq_ok:
            spec = P(dp, model, None, None)
        else:
            spec = P(dp, None, None, None)
        return jax.lax.with_sharding_constraint(
            t, NamedSharding(rules.mesh, spec))

    return hook


def build_model(cfg: ArchConfig, shape: ShapeSpec, mode: str,
                rules: MeshRules, dt: DtypePolicy) -> Model:
    bq, bkv = block_sizes(shape.seq_len)
    opts = ExecOptions(mode=mode, block_q=bq, block_kv=bkv, remat=True,
                       constrain=make_constrain(rules),
                       attn_constrain=attn_hook(rules),
                       moe_mesh=rules.mesh,
                       moe_dp_axes=rules.dp_axes,
                       moe_ep_axes=rules.ep_axes,
                       expert_pad=rules.axis_size(rules.ep_axes))
    return Model(cfg, dt=dt, opts=opts)


# --------------------------------------------------------------------------
# compiles
# --------------------------------------------------------------------------

def compile_train(cfg: ArchConfig, shape: ShapeSpec, rules: MeshRules,
                  mode: str, seq_override: Optional[int] = None
                  ) -> Tuple[object, int]:
    seq = seq_override or shape.seq_len
    shape_eff = dataclasses.replace(shape, seq_len=seq)
    dt, int8 = policy_for(cfg, "train")
    model = build_model(cfg, shape_eff, mode, rules, dt)
    # big archs train with microbatched gradient accumulation (saved-
    # activation stacks shrink by the microbatch count); cost compiles use
    # one full-size batch — FLOPs/bytes are batch-linear, so the affine
    # totals are unchanged and scan-body once-counting is avoided.
    # deep big-vocab archs (gemma3/recurrentgemma: >=30 layers x >=200k
    # vocab) also microbatch: their saved-carry stacks + f32-dup'd xent
    # chunks are the measured capacity misses.
    big = param_counts(cfg)["total"] >= BIG_PARAM_THRESHOLD
    deep_vocab = cfg.n_layers >= 30 and cfg.vocab_size >= 200_000
    mb = 4 if ((big or deep_vocab) and mode == "mem") else 1
    ts_cfg = TrainStepConfig(opt=AdamWConfig(int8_moments=int8),
                             microbatches=mb)
    params_s0, _ = abstract_train_state(model, ts_cfg)
    grad_sh = tree_shardings(rules, params_s0)
    ts_cfg = dataclasses.replace(ts_cfg, grad_shardings=grad_sh)
    step = make_train_step(model, ts_cfg)
    params_s, opt_s = abstract_train_state(model, ts_cfg)
    batch_s = input_specs(cfg, shape_eff)
    p_sh = tree_shardings(rules, params_s)
    o_sh = tree_shardings(rules, opt_s)
    b_sh = tree_shardings(rules, batch_s, kind="batch")
    jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
    with rules.mesh:
        lowered = jitted.lower(params_s, opt_s, batch_s)
        compiled = lowered.compile()
    return compiled, rules.mesh.size


def compile_prefill(cfg: ArchConfig, shape: ShapeSpec, rules: MeshRules,
                    mode: str) -> Tuple[object, int]:
    """Inference prefill: forward-only, last-token logits out."""
    dt, _ = policy_for(cfg, "decode")
    model = build_model(cfg, shape, mode, rules, dt)
    params_s = model.param_specs()
    batch_s = input_specs(cfg, shape)
    p_sh = tree_shardings(rules, params_s)
    b_sh = tree_shardings(rules, batch_s, kind="batch")
    jitted = jax.jit(model.prefill, in_shardings=(p_sh, b_sh))
    with rules.mesh:
        lowered = jitted.lower(params_s, batch_s)
        compiled = lowered.compile()
    return compiled, rules.mesh.size


def compile_serve(cfg: ArchConfig, shape: ShapeSpec, rules: MeshRules,
                  mode: str) -> Tuple[object, int]:
    dt, _ = policy_for(cfg, "decode")
    model = build_model(cfg, shape, mode, rules, dt)
    step = make_serve_step(model)
    params_s = model.param_specs()
    cache_s = model.cache_specs(shape.global_batch, shape.seq_len)
    batch_s = input_specs(cfg, shape)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    p_sh = tree_shardings(rules, params_s)
    c_sh = tree_shardings(rules, cache_s, kind="cache")
    b_sh = tree_shardings(rules, batch_s, kind="batch")
    pos_sh = NamedSharding(rules.mesh, P())
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh, pos_sh),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
    with rules.mesh:
        lowered = jitted.lower(params_s, cache_s, batch_s, pos_s)
        compiled = lowered.compile()
    return compiled, rules.mesh.size


def compile_cell(cfg, shape, rules, mode, seq_override=None):
    if shape.kind == "decode":
        return compile_serve(cfg, shape, rules, mode)
    if shape.kind == "prefill":
        if seq_override:
            shape = dataclasses.replace(shape, seq_len=seq_override)
        return compile_prefill(cfg, shape, rules, mode)
    return compile_train(cfg, shape, rules, mode, seq_override)


# --------------------------------------------------------------------------
# affine cost extraction
# --------------------------------------------------------------------------

COST_KEYS = ("flops_per_device", "hbm_bytes_per_device",
             "collective_bytes_per_chip")


def _needs_seq_split(cfg: ArchConfig, kind, shape: ShapeSpec) -> bool:
    """rwkv chunk loops are python-unrolled in cost mode; cap the compiled
    sequence and extrapolate (layer cost is affine in S — no quadratic
    terms in an SSM)."""
    return (kind[0] == "rwkv" and shape.kind != "decode"
            and shape.seq_len > 4096)


def cost_terms(cfg: ArchConfig, shape: ShapeSpec, rules: MeshRules,
               log=print) -> Dict:
    chips = rules.mesh.size
    counts = cfg.kind_counts()
    cache: Dict[Tuple, Dict] = {}

    def compiled_cost(kinds: Tuple, seq: Optional[int] = None) -> Dict:
        key = (kinds, seq)
        if key not in cache:
            sub = cfg.with_layers(kinds)
            t0 = time.time()
            comp, _ = compile_cell(sub, shape, rules, "cost", seq)
            res = analyze_compiled(comp, chips)
            log(f"    cost[{'+'.join('/'.join(k) for k in kinds) or 'base'}"
                f"{f'@S={seq}' if seq else ''}] "
                f"{time.time()-t0:.1f}s flops/dev={res['flops_per_device']:.3g}")
            cache[key] = res
        return cache[key]

    base = compiled_cost(())
    totals = {k: base.get(k, 0.0) for k in COST_KEYS}
    per_kind = {}
    for kind, n in counts.items():
        if _needs_seq_split(cfg, kind, shape):
            s1, s2 = 2048, 4096
            b1, b2 = compiled_cost((), s1), compiled_cost((), s2)
            k1, k2 = compiled_cost((kind,), s1), compiled_cost((kind,), s2)
            delta = {}
            for key in COST_KEYS:
                d1 = k1.get(key, 0.0) - b1.get(key, 0.0)
                d2 = k2.get(key, 0.0) - b2.get(key, 0.0)
                slope = (d2 - d1) / (s2 - s1)
                delta[key] = d2 + slope * (shape.seq_len - s2)
        else:
            kc = compiled_cost((kind,))
            delta = {key: kc.get(key, 0.0) - base.get(key, 0.0)
                     for key in COST_KEYS}
        per_kind["/".join(kind)] = delta
        for key in COST_KEYS:
            totals[key] += n * delta[key]

    return {"base": {k: base.get(k, 0.0) for k in COST_KEYS},
            "per_kind": per_kind,
            "kind_counts": {"/".join(k): v for k, v in counts.items()},
            "totals": totals}


# --------------------------------------------------------------------------
# cell driver
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multipod: bool = True,
             cost: bool = True, out_dir: Path = RESULTS_DIR,
             log=print) -> Dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}--{shape_name}.json"
    result: Dict = {"arch": arch, "shape": shape_name,
                    "shape_detail": dataclasses.asdict(shape)}

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        result["skipped"] = reason
        out_path.write_text(json.dumps(result, indent=2, default=str))
        log(f"[{arch} x {shape_name}] SKIP: {reason}")
        return result

    pc = param_counts(cfg)
    result["params"] = pc
    n = pc["n_active"]
    d_tokens = shape.tokens_per_step
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n * d_tokens
    result["model_flops"] = model_flops

    meshes = {"pod": make_production_mesh(multi_pod=False)}
    if multipod:
        meshes["multipod"] = make_production_mesh(multi_pod=True)

    big = pc["total"] >= BIG_PARAM_THRESHOLD
    result["mesh"] = {}
    for mesh_name, mesh in meshes.items():
        fsdp_axes = ("pod", "data") if (big and mesh_name == "multipod") \
            else ("data",)
        ep_axes = ("pod", "model") if (big and mesh_name == "multipod") \
            else ("model",)
        rules = make_rules(mesh, fsdp=True, fsdp_axes=fsdp_axes,
                           ep_axes=ep_axes)
        t0 = time.time()
        comp, chips = compile_cell(cfg, shape, rules, "mem")
        res = analyze_compiled(comp, chips)
        res["compile_seconds"] = round(time.time() - t0, 1)
        hbm = TPU_V5E.hbm_bytes
        res["fits_hbm"] = bool(res.get("peak_bytes_per_device", 0) <= hbm)
        result["mesh"][mesh_name] = res
        log(f"[{arch} x {shape_name}] {mesh_name}: compiled in "
            f"{res['compile_seconds']}s; peak/dev="
            f"{res.get('peak_bytes_per_device', 0)/2**30:.2f} GiB "
            f"fits={res['fits_hbm']} collectives={res['collective_count']}")

    if cost:
        rules = make_rules(meshes["pod"], fsdp=True)
        ct = cost_terms(cfg, shape, rules, log=log)
        result["cost"] = ct
        chips = meshes["pod"].size
        rl = Roofline(
            name=f"{arch}--{shape_name}", chips=chips,
            hlo_flops=ct["totals"]["flops_per_device"] * chips,
            hlo_bytes=ct["totals"]["hbm_bytes_per_device"] * chips,
            collective_bytes=ct["totals"]["collective_bytes_per_chip"]
            * chips,
            model_flops=model_flops)
        result["roofline"] = rl.to_dict()
        log(f"[{arch} x {shape_name}] roofline: compute={rl.compute_s:.4f}s "
            f"mem={rl.memory_s:.4f}s coll={rl.collective_s:.4f}s "
            f"dominant={rl.dominant} frac={rl.roofline_fraction:.3f}")

    out_path.write_text(json.dumps(result, indent=2, default=str))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-multipod", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--out", type=Path, default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        out_path = args.out / f"{arch}--{shape}.json"
        if args.skip_existing and out_path.exists():
            data = json.loads(out_path.read_text())
            if "error" not in data:
                print(f"[{arch} x {shape}] exists, skipping")
                continue
        try:
            run_cell(arch, shape, multipod=not args.no_multipod,
                     cost=not args.no_cost, out_dir=args.out)
        except Exception as e:  # noqa: BLE001 — record, keep sweeping
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
            (args.out / f"{arch}--{shape}.json").write_text(json.dumps(
                {"arch": arch, "shape": shape, "error": repr(e)}, indent=2))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\ndry-run OK")


if __name__ == "__main__":
    main()
