"""Speculative decoding: draft -> verify -> accept/rollback.

The ragged multi-token ``prefill_attention`` op is *exactly* the
verify-K-draft-tokens shape (ROADMAP item 5): a chunk of C candidate
tokens per slot scored causally against that slot's paged KV history.
This module supplies the pieces AROUND that op — zero kernel changes:

* **drafters** propose up to ``max_draft`` candidate continuations per
  slot from its emitted token history:

  - :class:`NgramDrafter` — model-free suffix matching: replay whatever
    followed the most recent earlier occurrence of the current n-token
    suffix.  Deterministic by construction (pure function of the
    history), zero extra FLOPs — the drafter production systems reach
    for when no small model is at hand.
  - :class:`ModelDrafter` — greedy autoregressive drafting with a small
    model sharing the target's token space.  The default draft config
    (:func:`make_draft_config`) is a truncated sibling of the target
    arch: same dims, leading subset of the layer stack.  Initialized
    from the SAME rng key, its layers are bit-identical to the target's
    leading layers (``Model.init`` folds the key per layer index), so
    drafting is early-exit self-speculation — real agreement without a
    separately trained model.

* **acceptance** (:func:`accept_longest_prefix`) — the verify forward
  returns greedy predictions at every window position; draft ``d_j`` is
  accepted iff it equals the prediction at the row BEFORE it, and the
  longest correct prefix plus the bonus token from the first
  disagreeing row is emitted.  Every verify step therefore emits at
  least one token — exactly the token a plain decode step would have —
  which is what makes greedy speculative streams bit-identical to the
  non-speculative baseline.

* **rollback** is the scheduler's business and is cheap by paging
  design: the host simply advances ``lengths`` by the emitted count
  (never past the accepted prefix) and keeps the pages — rejected
  drafts' stale K/V stays in the pool masked off by every later
  ``kpos < length`` read (see ``PagedScheduler.draft_for`` /
  ``verify_step`` and ``layers.attention_verify_paged``).

The pipelining story is the paper's (§2.1.4 cross-input interleaving):
a decode step streams one query token through the full weight pipeline;
a verify step streams W tokens through the SAME pipeline for near-equal
weight traffic, so every accepted draft is a token generated from idle
pipeline headroom.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig


def accept_longest_prefix(drafts: Sequence[int],
                          predictions: np.ndarray) -> List[int]:
    """Longest-correct-prefix acceptance for one slot.

    ``drafts``: the K candidate tokens fed at window rows 1..K.
    ``predictions``: (W,) greedy argmax at every verify row — row t is
    the model's prediction for the token AFTER window position t, so
    draft j (at row j+1) is correct iff it equals ``predictions[j]``.
    Returns the emitted tokens: the accepted drafts plus the bonus token
    from the first disagreeing row (always at least one token; with no
    drafts this is exactly a decode step's argmax).
    """
    a = 0
    while a < len(drafts) and int(drafts[a]) == int(predictions[a]):
        a += 1
    return [int(d) for d in drafts[:a]] + [int(predictions[a])]


class NgramDrafter:
    """Suffix-match drafting over each slot's prompt + emitted tokens.

    For the current ``n``-token suffix (falling back to shorter orders
    down to ``min_n``), find its most recent earlier occurrence in the
    history and propose the tokens that followed it.  Greedy decode
    loves repetition, so this fires often exactly when drafting is
    cheapest to verify.
    """

    name = "ngram"

    def __init__(self, *, max_draft: int = 3, n: int = 3, min_n: int = 1):
        if max_draft < 0:
            raise ValueError(f"max_draft must be >= 0, got {max_draft}")
        self.max_draft = int(max_draft)
        self.n = int(n)
        self.min_n = max(1, int(min_n))

    def _one(self, h: List[int]) -> List[int]:
        ln = len(h)
        for n in range(min(self.n, ln - 1), self.min_n - 1, -1):
            sfx = h[ln - n:]
            for j in range(ln - n - 1, -1, -1):
                if h[j:j + n] == sfx:
                    return h[j + n:j + n + self.max_draft]
        return []

    def propose(self, histories: Sequence[Sequence[int]]) -> List[List[int]]:
        return [self._one([int(t) for t in h]) for h in histories]


def make_draft_config(cfg: ArchConfig, n_layers: int = 0) -> ArchConfig:
    """A truncated sibling of ``cfg`` for drafting: same dims and token
    space, leading ``n_layers`` of the layer stack (default: half, at
    least one).  Because ``Model.init`` derives each layer's key from
    its stack index, initializing this config from the target's rng key
    reproduces the target's leading layers exactly — the drafter is an
    early-exit view of the target, not an unrelated random net."""
    kinds = cfg.layer_kinds()
    n = n_layers or max(1, len(kinds) // 2)
    return dataclasses.replace(
        cfg.with_layers(kinds[:n]), name=cfg.name + "-draft")


class ModelDrafter:
    """Greedy autoregressive drafting with a small model.

    The draft model must share the target's token space
    (``vocab_size``); nothing else about it matters to correctness —
    every proposal is verified by the target.  Drafting is stateless:
    each call right-pads the histories into a fixed (B, pad_to) buffer
    and runs ``max_draft`` full forwards, reading the logits row at
    each history's cursor (causal masking makes the right-padding
    inert).  Stateless costs FLOPs but needs no draft-side KV cache,
    no draft-side rollback, and exactly one compiled shape per padded
    batch size.
    """

    name = "model"

    def __init__(self, model, params, *, max_draft: int = 3,
                 pad_to: int = 128, batch_pad: int = 0):
        if max_draft < 0:
            raise ValueError(f"max_draft must be >= 0, got {max_draft}")
        self.model = model
        self.params = params
        self.max_draft = int(max_draft)
        self.pad_to = int(pad_to)
        self.batch_pad = int(batch_pad)

        def next_tokens(params, toks, last_idx):
            logits = model.forward(params, {"tokens": toks})
            row = jnp.take_along_axis(
                logits, last_idx[:, None, None], axis=1)[:, 0]
            return jnp.argmax(row, axis=-1)

        self._next = jax.jit(next_tokens)

    def _padded_batch(self, b: int) -> int:
        if self.batch_pad:
            return max(self.batch_pad, b)
        n = 1
        while n < b:
            n *= 2
        return n

    def propose(self, histories: Sequence[Sequence[int]]) -> List[List[int]]:
        b = len(histories)
        if b == 0 or self.max_draft == 0:
            return [[] for _ in range(b)]
        bp = self._padded_batch(b)
        toks = np.zeros((bp, self.pad_to), np.int32)
        cursor = np.ones((bp,), np.int32)     # padded rows: 1-token history
        for j, h in enumerate(histories):
            h = [int(t) for t in h][-self.pad_to:]   # keep the suffix
            toks[j, :len(h)] = h
            cursor[j] = len(h)
        out: List[List[int]] = [[] for _ in range(b)]
        for _ in range(self.max_draft):
            if int(cursor.max()) >= self.pad_to:
                break
            nxt = np.asarray(self._next(self.params, jnp.asarray(toks),
                                        jnp.asarray(cursor - 1)))
            for j in range(b):
                t = int(nxt[j])
                out[j].append(t)
                toks[j, cursor[j]] = t
            cursor += 1
        return out


def make_drafter(kind: str, cfg: ArchConfig, *, max_draft: int = 3,
                 dt=None, rng_key=None, draft_layers: int = 0,
                 pad_to: int = 128, batch_pad: int = 0,
                 model: Optional[object] = None, params=None):
    """Build a drafter by name ("ngram" | "model") for a target arch.

    For ``"model"``, pass the draft ``model``/``params`` explicitly or
    let this build the truncated sibling (:func:`make_draft_config`)
    initialized from ``rng_key`` — use the SAME key the target's params
    came from to get the early-exit weight sharing."""
    if kind == "ngram":
        return NgramDrafter(max_draft=max_draft)
    if kind == "model":
        if model is None:
            from ..core.memory import DtypePolicy
            from ..models.transformer import ExecOptions, Model
            dcfg = make_draft_config(cfg, draft_layers)
            model = Model(dcfg, dt=dt or DtypePolicy(param=jnp.bfloat16),
                          opts=ExecOptions(mode="run"))
            params = model.init(rng_key if rng_key is not None
                                else jax.random.key(0))
        if model.cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft arch {model.cfg.name} vocab "
                f"{model.cfg.vocab_size} != target vocab {cfg.vocab_size} "
                "(drafter and target must share the token space)")
        return ModelDrafter(model, params, max_draft=max_draft,
                            pad_to=pad_to, batch_pad=batch_pad)
    raise ValueError(f"unknown drafter {kind!r} (want ngram|model)")
