"""End-to-end training driver.

Runs REAL training (any arch at its smoke or a custom reduced size on CPU;
full size on a TPU cluster) with the production stack: sharded step,
AdamW (+optional int8 moments / gradient compression), deterministic data
pipeline, atomic checkpoints, supervised restart, straggler watch.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \\
      --steps 100 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \\
      --smoke --steps 50 --inject-failures 17,31   # proves restore path
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_arch
from ..core.memory import DtypePolicy
from ..data.pipeline import DataConfig, SyntheticLM
from ..checkpoint.checkpoint import CheckpointManager
from ..models.transformer import ExecOptions, Model
from ..optim.adamw import AdamWConfig
from ..optim.compress import CompressorConfig
from ..runtime.fault_tolerance import FailureInjector, Supervisor
from ..runtime.sharding import make_rules, tree_shardings
from ..train.steps import TrainStepConfig, init_train_state, make_train_step
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated steps to fail at (tests restore)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (with --smoke)")
    ap.add_argument("--dispatch", default="auto",
                    choices=("auto", "kernels", "reference"),
                    help="kernel routing for every hot matmul/attention "
                         "(repro.kernels.dispatch)")
    args = ap.parse_args(argv)

    from ..tune.cache import preload as preload_tuned
    preload_tuned(log=print)
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        if args.d_model:
            cfg = dataclasses.replace(
                cfg, d_model=args.d_model, d_ff=4 * args.d_model)
    cfg = dataclasses.replace(cfg, dispatch=args.dispatch)
    print(f"[dispatch] policy={args.dispatch}")
    mesh = make_host_mesh()
    rules = make_rules(mesh, fsdp=True)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"({cfg.param_counts()['total']/1e6:.1f}M params)")

    opts = ExecOptions(mode="run", block_q=min(512, args.seq),
                       block_kv=min(512, args.seq), remat=True)
    model = Model(cfg, dt=DtypePolicy(), opts=opts)
    ts_cfg = TrainStepConfig(
        opt=AdamWConfig(lr=args.lr, int8_moments=args.int8_moments,
                        warmup_steps=max(10, args.steps // 20),
                        total_steps=args.steps),
        microbatches=args.microbatches,
        compress=CompressorConfig() if args.compress_grads else None)
    step_fn_raw = make_train_step(model, ts_cfg)

    params, opt = init_train_state(model, ts_cfg, jax.random.key(0))
    p_sh = tree_shardings(rules, params)
    o_sh = tree_shardings(rules, opt)
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, o_sh)
    jitted = jax.jit(step_fn_raw, donate_argnums=(0, 1))

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch,
                          input_mode=cfg.input_mode, d_model=cfg.d_model)
    data = SyntheticLM(data_cfg)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3, async_save=False)
    injector = FailureInjector(
        [int(s) for s in args.inject_failures.split(",") if s]) \
        if args.inject_failures else None
    sup = Supervisor(ckpt, save_every=args.save_every, injector=injector)

    losses = []

    def one_step(state, step):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        if cfg.mrope_sections:
            b, s = batch["labels"].shape
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s)[None, :, None],
                (b, s, len(cfg.mrope_sections))).astype(jnp.int32)
        params, opt, metrics = jitted(params, opt, batch)
        return (params, opt), metrics

    def on_metrics(step, metrics):
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}")

    from ..kernels import dispatch
    dispatch.reset_stats()
    t0 = time.time()
    (params, opt), final = sup.run((params, opt), one_step, args.steps,
                                   on_metrics=on_metrics)
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"done: {final} steps in {dt:.1f}s ({tok_s:,.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f}; "
          f"restarts={sup.restarts} stragglers={len(sup.stragglers.flags)}")
    # route probe: counters are trace-time, so one jit compile of the step
    # is enough to prove which lowerings the train graph flowed through
    routes = dispatch.stats()
    print("[dispatch] routes: "
          + (", ".join(f"{op}/{r}={n}" for (op, r), n in sorted(
              routes.items())) or "none"))
    if args.dispatch == "kernels" and routes.get(("attention", "kernel"), 0):
        assert routes.get(("attention_bwd", "kernel"), 0) > 0, (
            "dispatch=kernels train step did not route the attention "
            f"backward through the fused Pallas kernel: {routes}")
    return losses


if __name__ == "__main__":
    main()
