"""Continuous-batching serving engine.

The layered decomposition of what used to be one monolithic
``PagedScheduler.run`` loop, shaped like the paper's dataflow discipline
(concurrently-executing stages connected by explicit state, not phases
run to completion):

* **load generation** (``launch/loadgen.py``) — timed request streams on
  a virtual clock;
* **admission / resources** (``launch/serve.PagedScheduler``) — page
  reservation, tables, reclamation, recycling;
* **batch composition** (:class:`BatchPolicy`, here) — each iteration
  picks a mix of page-sized prefill chunks from MULTIPLE waiting slots
  and decode steps for running slots under a per-iteration token budget;
* **step execution** (:class:`StepExecutor`, here) — issues the composed
  batch through the registry-routed paged kernels: ONE multi-slot
  ``prefill_attention`` forward (B = number of chunks) plus ONE batched
  ragged decode whose view masks non-decoding slots to the trash page;
* **metrics** (``launch/metrics.py``) — per-request TTFT and per-token
  latency on the same clock.

The engine loop (:class:`ContinuousEngine`) composes the stages and
keeps ``check_page_accounting`` invariants across interleaved
prefill/decode.  ``clock="wall"`` advances the clock by measured step
time (benchmarks); ``clock="tick"`` by a fixed tick (deterministic
tests and seeded load replay).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .loadgen import ArrivalQueue, Request
from .metrics import ServeMetrics
from .speculative import accept_longest_prefix


@dataclass
class StepPlan:
    """One engine iteration's work: ``prefill`` holds (slot, chunk start)
    pairs batched through ONE prefill forward; ``decode`` the slots that
    take a decode token; ``verify`` the draft tokens (per decode slot)
    the speculative mode admitted under the token budget — riding the
    same batched forward as the decode token they extend."""
    prefill: List[Tuple[int, int]] = field(default_factory=list)
    decode: List[int] = field(default_factory=list)
    verify: Dict[int, List[int]] = field(default_factory=dict)

    def empty(self) -> bool:
        return not self.prefill and not self.decode


@dataclass
class _PrefillState:
    """A slot's in-flight chunked prefill: page-padded prompt tokens,
    the true prompt length, and the next chunk's offset.  ``pos`` starts
    at ``skipped`` when a prefix-cache hit covered the leading chunks
    (their pages already hold valid K/V — nothing to compute)."""
    toks: np.ndarray
    ln: int
    pos: int = 0
    skipped: int = 0


class BatchPolicy:
    """Decode-first token-budget batch composition.

    Every running slot gets its decode token first (decode latency is
    the metric tail users feel); the remaining budget admits page-sized
    prefill chunks from distinct mid-prefill slots.  Chunks of one slot
    are sequential (chunk n+1 attends to chunk n's pages), so at most
    one chunk per slot per iteration — multi-slot batching is where the
    prefill parallelism comes from.  A budget smaller than one page
    still forces a chunk through when nothing is decoding, so admission
    can never livelock.

    Decode-first precedence is strict: the running decode set is never
    trimmed to fit the budget (stalling a mid-generation slot would just
    move its token to the next iteration while holding its pages), so
    when decodes alone meet or exceed the budget the remaining prefill
    allowance clamps to zero rather than going negative — the budget
    bounds *prefill admission*, decode cost is bounded by ``slots``.
    """

    def __init__(self, token_budget: int, page: int):
        self.token_budget = int(token_budget)
        self.page = int(page)

    def compose(self, running: List[int],
                prefilling: List[Tuple[int, int]],
                drafts: Optional[Dict[int, List[int]]] = None) -> StepPlan:
        """``drafts`` (speculative mode) maps running slots to proposed
        draft tokens; they are admitted AFTER the mandatory decode tokens
        and BEFORE prefill chunks, under the same budget — a verify chunk
        is cheaper than a prefill chunk (a few tokens vs a page) and its
        accepted tokens pay down decode latency directly, but it must
        never starve admission: leftover budget still prefills."""
        decode = list(running)
        left = max(0, self.token_budget - len(decode))
        verify: Dict[int, List[int]] = {}
        if drafts:
            for slot in decode:
                ks = drafts.get(slot, [])
                take = min(len(ks), left)
                if take > 0:
                    verify[slot] = list(ks[:take])
                    left -= take
        chunks: List[Tuple[int, int]] = []
        for slot, start in prefilling:
            if left < self.page:
                break
            chunks.append((slot, start))
            left -= self.page
        if not decode and not chunks and prefilling:
            chunks.append(prefilling[0])   # forced progress
        return StepPlan(prefill=chunks, decode=decode, verify=verify)


class StepExecutor:
    """Issues a composed :class:`StepPlan` through the scheduler's jitted
    paged forwards, accumulating per-phase wall time and the multi-slot
    batch-width stats the acceptance probes read."""

    def __init__(self, sched):
        self.sched = sched
        self.t_prefill = 0.0
        self.t_decode = 0.0
        self.prefill_calls = 0
        self.prefill_chunks = 0
        self.max_prefill_batch = 0

    def prefill(self, chunks: List[Tuple[int, int]],
                states: List[Optional[_PrefillState]]) -> np.ndarray:
        """One batched multi-slot prefill forward (B = len(chunks)).
        Returns (B, V) logits; row i is chunk i's last real position."""
        sched = self.sched
        page = sched.page
        toks = np.stack([states[s].toks[st:st + page] for s, st in chunks])
        starts = np.asarray([st for _, st in chunks], np.int32)
        tables = sched.table[[s for s, _ in chunks]]
        last = np.asarray([min(states[s].ln, st + page) - 1 - st
                           for s, st in chunks], np.int32)
        t0 = time.perf_counter()
        logits, sched.cache = sched._prefill(
            sched.params, sched.cache, jnp.asarray(toks),
            jnp.asarray(starts), jnp.asarray(tables), jnp.asarray(last))
        logits = np.asarray(logits)
        self.t_prefill += time.perf_counter() - t0
        self.prefill_calls += 1
        self.prefill_chunks += len(chunks)
        self.max_prefill_batch = max(self.max_prefill_batch, len(chunks))
        return logits

    def decode(self, cur: np.ndarray, decode_slots: List[int]) -> np.ndarray:
        """One batched ragged decode.  Non-decoding slots (mid-prefill or
        idle) ride along with a zero length and an all-trash table view,
        so their masked writes can never touch a live page."""
        sched = self.sched
        sched.prepare_decode(decode_slots)   # copy-on-write sweep first
        mask = np.zeros((sched.slots,), bool)
        mask[decode_slots] = True
        lengths = np.where(mask, sched.lengths, 0).astype(np.int32)
        table = np.where(mask[:, None], sched.table, 0).astype(np.int32)
        t0 = time.perf_counter()
        nxt = sched.step(cur, view=(lengths, table))
        self.t_decode += time.perf_counter() - t0
        return nxt

    def verify(self, cur: np.ndarray, decode_slots: List[int],
               drafts: Dict[int, List[int]], width: int) -> np.ndarray:
        """One batched fixed-width verify forward replacing the decode
        step in speculative mode: slot rows carry [current token,
        drafts..., padding]; non-decoding slots ride along masked to the
        trash page exactly as in :meth:`decode`.  Returns (slots, width)
        greedy predictions."""
        sched = self.sched
        sched.prepare_verify(decode_slots, width)  # full-span CoW sweep
        toks = np.zeros((sched.slots, width), np.int32)
        mask = np.zeros((sched.slots,), bool)
        for slot in decode_slots:
            mask[slot] = True
            toks[slot, 0] = cur[slot]
            ks = drafts.get(slot, [])
            toks[slot, 1:1 + len(ks)] = ks
        lengths = np.where(mask, sched.lengths, 0).astype(np.int32)
        table = np.where(mask[:, None], sched.table, 0).astype(np.int32)
        t0 = time.perf_counter()
        preds = sched.verify_step(toks, view=(lengths, table))
        self.t_decode += time.perf_counter() - t0
        return preds


class ContinuousEngine:
    """Admission -> compose -> execute -> account, once per iteration.

    Requests arrive on the virtual clock via an :class:`ArrivalQueue`;
    waiting requests admit FCFS into free slots by reserving their whole
    lifetime's pages up front (the scheduler's admission contract), then
    prefill chunk-by-chunk ACROSS iterations — so one long prompt never
    stalls the decode cadence of running slots, and multiple mid-prefill
    slots share one batched prefill forward.
    """

    def __init__(self, sched, *, token_budget: int = 0,
                 clock: str = "wall", tick: float = 1.0,
                 metrics: Optional[ServeMetrics] = None, drafter=None,
                 log=print):
        if clock not in ("wall", "tick"):
            raise ValueError(f"clock must be wall|tick, got {clock!r}")
        self.sched = sched
        self.policy = BatchPolicy(token_budget or sched.slots * sched.page,
                                  sched.page)
        self.executor = StepExecutor(sched)
        # speculative mode: a drafter swaps the decode step for a fixed-
        # width draft/verify/rollback step (launch/speculative.py)
        self.drafter = drafter
        self.verify_width = (drafter.max_draft + 1) if drafter else 0
        self.clock_mode = clock
        self.tick = float(tick)
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.log = log or (lambda *a, **k: None)
        self.clock = 0.0
        self.queue: Optional[ArrivalQueue] = None
        self.waiting: List[Request] = []
        self.states: List[Optional[_PrefillState]] = [None] * sched.slots
        self.cur = np.zeros((sched.slots,), np.int32)
        self.done: List[Request] = []
        self.admission_order: List[int] = []
        self.iterations = 0
        self.max_resident = 0
        # peak BYTES of live KV pool (pages x per-page bytes at the
        # active storage dtype, scales included) — the residency metric
        # that stays comparable across kv_dtype, unlike max_resident
        # (request count) or held pages (dtype-blind)
        self.max_resident_kv_bytes = 0

    # ------------------------------------------------------------- warmup
    def warmup(self) -> None:
        """Compile every prefill batch width (1..slots) plus the masked
        decode step outside the timed/counted region; all warmup writes
        land on the trash page, so live state is untouched."""
        sched = self.sched
        if getattr(sched, "tp", 1) > 1:
            self.log(f"[engine] warmup on a tp={sched.tp} mesh "
                     f"(sharded decode/prefill steps)")
        for b in range(1, sched.slots + 1):
            _, sched.cache = sched._prefill(
                sched.params, sched.cache,
                jnp.zeros((b, sched.page), jnp.int32),
                jnp.zeros((b,), jnp.int32),
                jnp.zeros((b, sched.n_slot_pages), jnp.int32),
                jnp.full((b,), sched.page - 1, jnp.int32))
        zeros = np.zeros((sched.slots,), np.int32)
        sched.step(zeros, view=(zeros, np.zeros_like(sched.table)))
        if self.drafter is not None:
            sched.verify_step(
                np.zeros((sched.slots, self.verify_width), np.int32),
                view=(zeros, np.zeros_like(sched.table)))
            sched.verify_steps = 0
        sched.decode_steps = 0
        sched.decode_tokens = 0

    # ---------------------------------------------------------- admission
    def _admit(self, now: float) -> None:
        sched = self.sched
        keep: List[Request] = []
        for r in self.waiting:
            if sched.admissible(r):
                keep.append(r)
                continue
            r.done = False
            sched.rejected += 1
            sched.rejected_requests.append(r)
            self.metrics.on_reject(r.rid, now)
            self.log(f"[engine] rejecting request {r.rid}: "
                     f"{sched._reject_reason(r)}")
        self.waiting = keep
        for slot in range(sched.slots):
            if not self.waiting:
                break
            if sched.active[slot] is not None:
                continue
            if not sched.reserve(self.waiting[0], slot):
                break                      # FCFS: never bypass the head
            r = self.waiting.pop(0)
            ln = len(r.prompt)
            shared = int(sched.shared_tokens[slot])
            if shared >= ln:
                # Fully covered by the prefix cache: every prompt position
                # already has valid K/V in shared pages, so no prefill
                # forward runs at all.  The slot goes straight to running
                # with lengths = ln-1 and the last prompt token teacher-
                # forced through the next batched decode — that decode's
                # append lands mid-page in a shared page and copy-on-
                # writes it (reserve stashed the spare page).
                sched.lengths[slot] = ln - 1
                self.cur[slot] = int(r.prompt[ln - 1])
                self.states[slot] = None
            else:
                # Partial coverage is page-aligned (trie matches whole
                # chunks), so prefill resumes at the first uncovered chunk.
                toks = np.zeros((-(-ln // sched.page) * sched.page,),
                                np.int32)
                toks[:ln] = r.prompt
                self.states[slot] = _PrefillState(toks, ln, pos=shared,
                                                  skipped=shared)
            self.admission_order.append(r.rid)
            self.metrics.on_admit(r.rid, now)

    def _maybe_truncate(self, r: Request, slot: int) -> None:
        """Called at finish time: a request stopped by the context wall
        rather than its own ``max_new`` is truncated — flagged, counted,
        logged, never silent."""
        r.truncated = len(r.out) < r.max_new
        if r.truncated:
            self.sched.truncated += 1
            self.metrics.on_truncate(r.rid)
            self.log(f"[engine] truncating request {r.rid} at the context "
                     f"wall: {len(r.out)}/{r.max_new} tokens "
                     f"(max_len={self.sched.max_len})")

    def _finish(self, slot: int, t: float) -> None:
        r = self.sched.active[slot]
        r.done = True
        self.done.append(r)
        self.metrics.on_finish(r.rid, t)
        self.sched._recycle(slot)
        self.states[slot] = None

    # ------------------------------------------------------ one iteration
    def step(self) -> bool:
        """One engine iteration; returns False once fully drained."""
        sched = self.sched
        now = self.clock
        if self.queue is not None:
            for r in self.queue.pop_ready(now):
                self.metrics.on_arrival(r.rid, r.arrival)
                self.waiting.append(r)
        self._admit(now)
        self.max_resident = max(
            self.max_resident,
            sum(1 for a in sched.active if a is not None))
        self.max_resident_kv_bytes = max(
            self.max_resident_kv_bytes, sched.kv_bytes_resident())

        running = [i for i in range(sched.slots)
                   if sched.active[i] is not None and self.states[i] is None]
        prefilling = [(i, self.states[i].pos) for i in range(sched.slots)
                      if self.states[i] is not None]
        drafts = (sched.draft_for(self.drafter, running)
                  if self.drafter is not None and running else None)
        plan = self.policy.compose(running, prefilling, drafts=drafts)

        if plan.empty():
            nxt = (self.queue.next_arrival()
                   if self.queue is not None else None)
            if nxt is not None:
                self.clock = max(self.clock, nxt)   # idle: jump forward
                return True
            if self.waiting:
                # unreachable by construction (an idle engine has every
                # page free, so only inadmissible requests can fail, and
                # those were rejected above) — defensive
                raise RuntimeError(
                    "admission deadlock: empty batch but queued requests "
                    "cannot reserve pages")
            return False

        t0 = time.perf_counter()
        logits = (self.executor.prefill(plan.prefill, self.states)
                  if plan.prefill else None)
        speculative = self.drafter is not None
        nxt_tok = preds = None
        if plan.decode:
            if speculative:
                preds = self.executor.verify(self.cur, plan.decode,
                                             plan.verify, self.verify_width)
            else:
                nxt_tok = self.executor.decode(self.cur, plan.decode)
        self.clock += ((time.perf_counter() - t0)
                       if self.clock_mode == "wall" else self.tick)
        self.iterations += 1
        t = self.clock

        for row, (slot, _start) in enumerate(plan.prefill):
            st = self.states[slot]
            st.pos += sched.page
            if st.pos < st.ln:
                continue
            # last chunk: the first generated token is born (TTFT moment)
            r = sched.active[slot]
            sched.lengths[slot] = st.ln
            sched.prefill_tokens += st.ln - st.skipped
            sched.cache_prefix(slot, r.prompt)
            first = int(np.argmax(logits[row]))
            r.out.append(first)
            self.cur[slot] = first
            self.metrics.on_token(r.rid, t)
            self.states[slot] = None
            if (len(r.out) >= r.max_new
                    or int(sched.lengths[slot]) >= sched.max_len):
                self._maybe_truncate(r, slot)
                self._finish(slot, t)
            else:
                sched._reclaim_slot(slot)   # long prompts outrun the window

        for slot in plan.decode:
            r = sched.active[slot]
            if speculative:
                # longest-correct-prefix acceptance + host rollback: the
                # emission loop replicates the plain decode path's
                # per-token finish checks exactly, so greedy streams
                # (including truncation points) are bit-identical to the
                # non-speculative engine
                ks = plan.verify.get(slot, [])
                emit = accept_longest_prefix(ks, preds[slot])
                accepted = len(emit) - 1
                emitted = 0
                finished = False
                for tok in emit:
                    sched.lengths[slot] += 1
                    r.out.append(tok)
                    self.cur[slot] = tok
                    emitted += 1
                    self.metrics.on_token(r.rid, t)
                    if (len(r.out) >= r.max_new
                            or int(sched.lengths[slot]) >= sched.max_len):
                        finished = True
                        break
                sched.note_spec(len(ks), accepted, emitted)
                self.metrics.on_spec_step(len(ks), accepted, emitted)
                if finished:
                    self._maybe_truncate(r, slot)
                    self._finish(slot, t)
                else:
                    sched._reclaim_slot(slot)
                continue
            sched.lengths[slot] += 1
            tok = int(nxt_tok[slot])
            r.out.append(tok)
            self.cur[slot] = tok
            self.metrics.on_token(r.rid, t)
            if (len(r.out) >= r.max_new
                    or int(sched.lengths[slot]) >= sched.max_len):
                self._maybe_truncate(r, slot)
                self._finish(slot, t)
            else:
                sched._reclaim_slot(slot)
        return True

    # ---------------------------------------------------------------- run
    def submit(self, requests: List[Request]) -> None:
        self.queue = ArrivalQueue(requests)

    def run(self, requests: Optional[List[Request]] = None) -> List[Request]:
        if requests is not None:
            self.submit(requests)
        while self.step():
            pass
        return self.done
