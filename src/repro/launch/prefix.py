"""Prefix cache: a token-id trie mapping shared prompt prefixes to KV
page runs.

At production scale most traffic shares long system/tool prompts, and the
page-table indirection makes exploiting that reuse a pure host-side
change (ROADMAP item #2; the same compute/memory decoupling FBLAS and
Chi et al. use — kernels resolve ``(slot, page_idx)`` through tables and
never learn whether a physical page is private or shared).

Granularity is one FULL page: a node's key is the exact tuple of token
ids that filled one page during prefill, so a node's page is only ever
published once every position in it holds valid K/V.  A request's
partial final chunk is never inserted (its tail positions are not
prefilled yet and will be written by decode), but a *query* may match a
partial prefix of a published full page — the sharer then binds the page
and masks the tail through its own ``lengths``.

Refcount discipline: the trie is one holder.  ``insert`` takes a
reference on every newly published page (``PageAllocator.share``);
``evict``/``flush`` release it.  Eviction only touches childless nodes
whose page has refcount 1 (held by the trie alone) — pages still bound
by a slot are never pulled out from under it — oldest ``last_used``
first, so the cache behaves as an LRU over prefix tails.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("key", "page", "parent", "children", "last_used")

    def __init__(self, key: Optional[tuple], page: Optional[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[tuple, "_Node"] = {}
        self.last_used = 0


class PrefixCache:
    """Trie over page-sized token chunks -> physical page ids."""

    def __init__(self, page_size: int):
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        self.page = int(page_size)
        self.root = _Node(None, None, None)
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.n_nodes = 0

    # ------------------------------------------------------------- helpers
    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.last_used = self._tick

    @staticmethod
    def _chunk(tokens: Sequence, c: int, page: int) -> tuple:
        return tuple(int(t) for t in tokens[c * page:(c + 1) * page])

    def n_pages(self) -> int:
        """Pages currently referenced (one per node)."""
        return self.n_nodes

    # --------------------------------------------------------------- match
    def match(self, tokens: Sequence) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``.

        Returns ``(pages, covered)``: the page run for positions
        ``[0, covered)``.  ``covered`` is either page-aligned (full-chunk
        matches only) or exactly ``len(tokens)`` when the final partial
        chunk is a prefix of some published page — the fully-covered
        case, where the caller can skip prefill entirely and bind the
        last (for it, partial) page copy-on-write.
        """
        n = len(tokens)
        pg = self.page
        node = self.root
        pages: List[int] = []
        covered = 0
        full = True
        for c in range(n // pg):
            child = node.children.get(self._chunk(tokens, c, pg))
            if child is None:
                full = False
                break
            self._touch(child)
            pages.append(child.page)
            covered += pg
            node = child
        if full:
            rem = tuple(int(t) for t in tokens[(n // pg) * pg:])
            if rem:
                for key, child in node.children.items():
                    if key[:len(rem)] == rem:
                        self._touch(child)
                        pages.append(child.page)
                        covered = n
                        break
        if covered:
            self.hits += 1
        else:
            self.misses += 1
        return pages, covered

    # -------------------------------------------------------------- insert
    def insert(self, tokens: Sequence, pages: Sequence[int],
               allocator) -> int:
        """Publish ``tokens``'s fully-prefilled chunks.

        ``pages`` is the owning slot's logical page run; only the
        ``len(tokens) // page`` complete chunks are inserted (the partial
        tail chunk still takes decode writes, so publishing it would hand
        sharers unwritten positions).  Existing nodes are refreshed, not
        replaced (concurrent identical prompts race benignly: first
        publisher wins, the loser's pages stay private).  Returns the
        number of pages newly referenced.
        """
        pg = self.page
        node = self.root
        added = 0
        for c in range(len(tokens) // pg):
            key = self._chunk(tokens, c, pg)
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(pages[c]), node)
                node.children[key] = child
                allocator.share(child.page)
                self.n_nodes += 1
                added += 1
            self._touch(child)
            node = child
        return added

    # --------------------------------------------------------------- evict
    def _evictable(self, allocator) -> List[_Node]:
        out: List[_Node] = []
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            elif allocator.ref[node.page] == 1:
                out.append(node)
        return out

    def evict(self, n: int, allocator) -> int:
        """Free up to ``n`` pages held only by the trie, LRU-first.

        Only childless nodes are candidates (removing an interior node
        would orphan still-valid longer prefixes), so eviction proceeds
        leaf-inward; freeing a leaf can expose its parent next round.
        """
        freed = 0
        while freed < n:
            cands = self._evictable(allocator)
            if not cands:
                break
            victim = min(cands, key=lambda nd: nd.last_used)
            del victim.parent.children[victim.key]
            allocator.release([victim.page])
            self.n_nodes -= 1
            self.evictions += 1
            freed += 1
        return freed

    def flush(self, allocator) -> int:
        """Release every cached page (e.g. before a weight swap)."""
        freed = 0
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            allocator.release([node.page])
            freed += 1
        self.root.children.clear()
        self.n_nodes = 0
        return freed
