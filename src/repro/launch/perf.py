import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration driver (§Perf hillclimbs in EXPERIMENTS.md).

Runs one (arch x shape) cell's cost pipeline under a named VARIANT —
a set of transformation knobs — and appends the resulting roofline terms
to results/perf/<cell>.jsonl.  Each EXPERIMENTS.md §Perf iteration is one
invocation; diffs between rows are the measured effect of one change.

Knobs (all optional; defaults reproduce the baseline):
  remat=full|dots|none        activation-checkpoint policy
  block_kv=INT                attention KV tile
  rwkv_chunk=INT              WKV chunk length
  fsdp=0|1                    weight striping over `data` on/off
  seq_shard=0|1               Megatron-SP residual sharding on/off
  capacity=FLOAT              MoE capacity factor
  microbatches=INT            gradient-accumulation splits
  xent_chunks=INT             sequence tiles for the loss
  q_splits handled structurally (see layers.attention_blockwise)

Usage:
  python -m repro.launch.perf --arch rwkv6-7b --shape train_4k \\
      --name chunk128 rwkv_chunk=128
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_arch, input_specs
from ..core.model import Roofline
from ..models.transformer import ExecOptions, Model, param_counts
from ..optim.adamw import AdamWConfig
from ..runtime.sharding import make_rules, tree_shardings
from ..train.steps import TrainStepConfig, abstract_train_state, \
    make_train_step
from . import dryrun
from .mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "perf"


@dataclasses.dataclass
class Variant:
    name: str = "baseline"
    remat: str = "full"
    block_kv: int = 0            # 0 = auto
    rwkv_chunk: int = 0
    rwkv_intra: str = ""         # "" = config default
    fsdp: bool = True
    seq_shard: bool = True
    embed_stripe: bool = True
    attn_seq: bool = False
    capacity: float = 0.0
    microbatches: int = 1
    xent_chunks: int = 8
    mem_proof: bool = False      # also run the full-depth memory compile


def apply_variant(cfg, shape, v: Variant, rules):
    if v.rwkv_chunk:
        cfg = dataclasses.replace(cfg, rwkv_chunk=v.rwkv_chunk)
    if v.rwkv_intra:
        cfg = dataclasses.replace(cfg, rwkv_intra=v.rwkv_intra)
    if v.capacity:
        cfg = dataclasses.replace(cfg, capacity_factor=v.capacity)
    bq, bkv = dryrun.block_sizes(shape.seq_len)
    if v.block_kv:
        bkv = v.block_kv
    con = dryrun.make_constrain(rules) if v.seq_shard else None
    opts = ExecOptions(
        mode="cost", block_q=bq, block_kv=bkv, remat=v.remat != "none",
        remat_policy=v.remat if v.remat != "none" else "full",
        constrain=con, attn_constrain=dryrun.attn_hook(rules),
        moe_mesh=rules.mesh, moe_dp_axes=rules.dp_axes,
        moe_ep_axes=rules.ep_axes,
        expert_pad=rules.axis_size(rules.ep_axes),
        xent_chunks=v.xent_chunks)
    return cfg, opts


def run_variant(arch: str, shape_name: str, v: Variant, log=print):
    from ..tune.cache import preload as preload_tuned
    preload_tuned(log=log)
    cfg0 = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    rules = make_rules(mesh, fsdp=v.fsdp)
    rules = dataclasses.replace(rules, stripe_embed=v.embed_stripe,
                                attn_prefer_seq=v.attn_seq)
    chips = mesh.size

    # monkey-wire the variant into the dryrun cost pipeline
    orig_build = dryrun.build_model

    def build_model(cfg, shape_, mode, rules_, dt):
        cfg_v, opts = apply_variant(cfg, shape_, v, rules_)
        # cost mode only here; opts already set
        m = Model(cfg_v, dt=dt, opts=dataclasses.replace(opts, mode=mode))
        return m

    dryrun.build_model = build_model
    try:
        t0 = time.time()
        ct = dryrun.cost_terms(cfg0, shape, rules, log=log)
        pc = param_counts(cfg0)
        d_tokens = shape.tokens_per_step
        mf = (6.0 if shape.kind == "train" else 2.0) * pc["n_active"] \
            * d_tokens
        rl = Roofline(
            name=f"{arch}--{shape_name}--{v.name}", chips=chips,
            hlo_flops=ct["totals"]["flops_per_device"] * chips,
            hlo_bytes=ct["totals"]["hbm_bytes_per_device"] * chips,
            collective_bytes=ct["totals"]["collective_bytes_per_chip"]
            * chips,
            model_flops=mf)
        row = {"variant": dataclasses.asdict(v), "arch": arch,
               "shape": shape_name, "roofline": rl.to_dict(),
               "cost": ct, "wall_s": round(time.time() - t0, 1)}
        if v.mem_proof:
            comp, _ = dryrun.compile_cell(cfg0, shape, rules, "mem")
            from ..roofline.analysis import analyze_compiled
            row["mem"] = analyze_compiled(comp, chips)
    finally:
        dryrun.build_model = orig_build

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{arch}--{shape_name}.jsonl"
    with out.open("a") as f:
        f.write(json.dumps(row, default=str) + "\n")
    log(f"[{v.name}] compute={rl.compute_s:.3f}s mem={rl.memory_s:.3f}s "
        f"coll={rl.collective_s:.3f}s dominant={rl.dominant} "
        f"step={rl.step_s:.3f}s frac={rl.roofline_fraction:.4f}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--name", default="baseline")
    ap.add_argument("--mem-proof", action="store_true")
    ap.add_argument("knobs", nargs="*", help="key=value overrides")
    args = ap.parse_args(argv)
    kw = {}
    for k in args.knobs:
        key, val = k.split("=", 1)
        field = Variant.__dataclass_fields__[key]
        kw[key] = field.type(val) if field.type is not bool \
            else val in ("1", "true", "True")
    v = Variant(name=args.name, mem_proof=args.mem_proof, **kw)
    run_variant(args.arch, args.shape, v)


if __name__ == "__main__":
    main()
