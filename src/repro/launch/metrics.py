"""Latency metrics for the serving engine.

Records one :class:`RequestTimeline` per request on the engine's virtual
clock (seconds in ``clock="wall"`` mode, ticks in ``clock="tick"`` mode)
and summarizes the two latencies production serving is judged on:

* **time-to-first-token (TTFT)** — first generated token's timestamp
  minus the request's *arrival* (so queueing delay counts, not just
  prefill compute);
* **per-token latency** — gaps between consecutive generated-token
  timestamps of one request (the inter-token decode cadence).

``summary()`` emits p50/p99 for both, the shape ``BENCH_serve.json``
rows carry and ``scripts/check_bench.py`` gates.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class RequestTimeline:
    rid: int
    arrival: float
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    token_times: List[float] = field(default_factory=list)


class ServeMetrics:
    """Per-request event sink + percentile summaries."""

    def __init__(self):
        self.timelines: Dict[int, RequestTimeline] = {}
        self.rejected: List[int] = []
        self.truncated: List[int] = []
        # Speculative decoding tallies (zero unless a drafter is active).
        self.spec_steps = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0

    def _tl(self, rid: int, t: float = 0.0) -> RequestTimeline:
        if rid not in self.timelines:
            self.timelines[rid] = RequestTimeline(rid, t)
        return self.timelines[rid]

    def on_arrival(self, rid: int, t: float) -> None:
        self.timelines[rid] = RequestTimeline(rid, t)

    def on_admit(self, rid: int, t: float) -> None:
        self._tl(rid, t).admitted = t

    def on_token(self, rid: int, t: float) -> None:
        tl = self._tl(rid, t)
        if tl.first_token is None:
            tl.first_token = t
        tl.token_times.append(t)

    def on_finish(self, rid: int, t: float) -> None:
        self._tl(rid, t).finished = t

    def on_reject(self, rid: int, t: float) -> None:
        self._tl(rid, t)
        self.rejected.append(rid)

    def on_truncate(self, rid: int) -> None:
        self.truncated.append(rid)

    def on_spec_step(self, drafted: int, accepted: int, emitted: int) -> None:
        """One slot's verify outcome: ``drafted`` candidates proposed,
        ``accepted`` of them matched the target, ``emitted`` tokens
        entered the stream (accepted + the bonus token, capped by the
        request's remaining budget)."""
        self.spec_steps += 1
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted

    # ----------------------------------------------------------- summaries
    def ttfts(self) -> List[float]:
        return [tl.first_token - tl.arrival
                for tl in self.timelines.values()
                if tl.first_token is not None]

    def token_gaps(self) -> List[float]:
        gaps: List[float] = []
        for tl in self.timelines.values():
            ts = tl.token_times
            gaps.extend(b - a for a, b in zip(ts, ts[1:]))
        return gaps

    @staticmethod
    def percentile(values: List[float], q: float) -> Optional[float]:
        if not values:
            return None
        return float(np.percentile(np.asarray(values, np.float64), q))

    def summary(self) -> Dict[str, Optional[float]]:
        ttfts = self.ttfts()
        gaps = self.token_gaps()
        new_tokens = sum(len(tl.token_times)
                         for tl in self.timelines.values())
        finished = [tl for tl in self.timelines.values()
                    if tl.finished is not None]
        span = (max(tl.finished for tl in finished)
                - min(tl.arrival for tl in finished)) if finished else None
        return {
            "requests_finished": len(finished),
            "requests_rejected": len(self.rejected),
            "requests_truncated": len(self.truncated),
            "new_tokens": new_tokens,
            "ttft_p50": self.percentile(ttfts, 50),
            "ttft_p99": self.percentile(ttfts, 99),
            "tok_latency_p50": self.percentile(gaps, 50),
            "tok_latency_p99": self.percentile(gaps, 99),
            "clock_span": span,
            "spec_accept_rate": (self.spec_accepted / self.spec_drafted
                                 if self.spec_drafted else None),
            "spec_tokens_per_step": (self.spec_emitted / self.spec_steps
                                     if self.spec_steps else None),
        }
