"""Production mesh construction (assignment-mandated shape).

A function, not a module-level constant: importing this module never touches
jax device state.  Single pod: (data=16, model=16) = 256 chips (v5e-256).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
only the cross-pod gradient all-reduce (or acts as the pipeline-stage axis
when pipeline parallelism is enabled) because inter-pod links are the
scarcest bandwidth — the paper's "routing" objective (Tab. 1 RT) maps to
keeping traffic off that axis.

JAX-version compat: ``jax.make_mesh`` grew an ``axis_types`` kwarg (and
``jax.sharding.AxisType``) only after 0.4.x.  ``make_mesh`` below is the
single version-tolerant entry point — it requests Auto axis types when the
installed JAX supports them and silently omits them otherwise, so every
caller (production meshes, tests, subprocess snippets) works on both sides
of the API change.
"""
from __future__ import annotations

import functools
import inspect
from typing import Optional, Sequence

import jax


@functools.lru_cache(maxsize=1)
def _axis_types_supported() -> bool:
    if not hasattr(jax.sharding, "AxisType"):
        return False
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return False
    return "axis_types" in params


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API allows them."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _axis_types_supported():
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_serving_mesh(tp: int) -> jax.sharding.Mesh:
    """1-axis ``("model",)`` mesh over the first ``tp`` devices — the
    tensor-parallel serving mesh (``launch/serve.py --mesh``).  Use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to simulate
    N devices on CPU."""
    devs = jax.devices()
    if tp < 1:
        raise ValueError(f"mesh size must be >= 1, got {tp}")
    if tp > len(devs):
        raise ValueError(
            f"mesh size {tp} exceeds visible devices ({len(devs)}); set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp} to "
            f"simulate")
    return make_mesh((tp,), ("model",), devices=devs[:tp])


def make_host_mesh(shape=None, axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        model = 1
        for cand in (4, 2, 1):
            if n % cand == 0:
                model = cand
                break
        shape = (n // model, model)
    return make_mesh(shape, axes)
