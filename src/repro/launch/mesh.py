"""Production mesh construction (assignment-mandated shape).

A function, not a module-level constant: importing this module never touches
jax device state.  Single pod: (data=16, model=16) = 256 chips (v5e-256).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
only the cross-pod gradient all-reduce (or acts as the pipeline-stage axis
when pipeline parallelism is enabled) because inter-pod links are the
scarcest bandwidth — the paper's "routing" objective (Tab. 1 RT) maps to
keeping traffic off that axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        model = 1
        for cand in (4, 2, 1):
            if n % cand == 0:
                model = cand
                break
        shape = (n // model, model)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
