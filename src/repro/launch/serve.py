"""Batched serving driver: prefill + continuous decode.

A minimal-but-real serving loop: requests arrive with prompts, get packed
into a fixed-slot batch, prefilled (one forward), then all active slots
decode one token per ``serve_step`` (the paper's cross-input interleaving
§2.1.4: the batch dimension fills the pipeline the way the FPGA interleaves
independent solver instances).  Finished sequences free their slot for the
next queued request (continuous batching).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \\
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..core.memory import DtypePolicy
from ..models.transformer import ExecOptions, Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot continuous-batching decoder."""

    def __init__(self, model: Model, params, *, slots: int, max_len: int):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = 0
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    def _feed_batch(self, tokens: np.ndarray) -> Dict[str, jax.Array]:
        batch = {"tokens": jnp.asarray(tokens)[:, None]}
        if self.model.cfg.mrope_sections:
            batch["positions"] = jnp.full(
                (self.slots, 1, len(self.model.cfg.mrope_sections)),
                self.pos, jnp.int32)
        return batch

    def step(self, tokens: np.ndarray) -> np.ndarray:
        logits, self.cache = self._decode(
            self.params, self.cache, self._feed_batch(tokens),
            jnp.int32(self.pos))
        self.pos += 1
        return np.asarray(jnp.argmax(logits, axis=-1))

    def run(self, requests: List[Request], greedy: bool = True
            ) -> List[Request]:
        queue = list(requests)
        cur = np.zeros((self.slots,), np.int32)
        prompt_cursor = np.zeros((self.slots,), np.int64)
        done: List[Request] = []
        while queue or any(r is not None for r in self.active):
            # fill free slots (continuous batching)
            for i in range(self.slots):
                if self.active[i] is None and queue:
                    self.active[i] = queue.pop(0)
                    prompt_cursor[i] = 0
                    cur[i] = self.active[i].prompt[0]
            nxt = self.step(cur)
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                prompt_cursor[i] += 1
                if prompt_cursor[i] < len(r.prompt):
                    cur[i] = r.prompt[prompt_cursor[i]]   # teacher-forced
                else:
                    r.out.append(int(nxt[i]))
                    cur[i] = nxt[i]
                    if len(r.out) >= r.max_new or self.pos >= self.max_len - 1:
                        r.done = True
                        done.append(r)
                        self.active[i] = None
            if self.pos >= self.max_len - 1:
                break
        return done


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--dispatch", default="auto",
                    choices=("auto", "kernels", "reference"),
                    help="kernel routing for every hot matmul/attention "
                         "(repro.kernels.dispatch)")
    args = ap.parse_args(argv)

    from ..tune.cache import preload as preload_tuned
    preload_tuned(log=print)
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, dispatch=args.dispatch)
    print(f"[dispatch] policy={args.dispatch}")
    if cfg.input_mode == "embeddings":
        raise SystemExit("serving demo drives token-mode archs")
    model = Model(cfg, dt=DtypePolicy(param=jnp.bfloat16),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    server = Server(model, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len),
                    args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = server.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_new} new tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s, {args.slots} slots)")
    for r in done[:4]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    return done


if __name__ == "__main__":
    main()
