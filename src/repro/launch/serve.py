"""Batched serving driver: paged-KV scheduler + legacy dense server.

Two cache layouts behind one CLI (``--cache {dense,paged}``):

* ``dense`` — the original fixed-slot continuous-batching decoder: one
  rectangular (slots, max_len) KV cache, prompts teacher-forced through the
  decode step one token at a time.
* ``paged`` — the serving runtime this module is really about.  The KV
  cache is a pool of fixed-size pages (paper §4.3 memory banking); a
  host-side scheduler does admission control (a request is admitted only
  when its whole lifetime's pages can be reserved), chunked prefill (the
  Pallas ragged multi-token kernel via ``dispatch.prefill_attention``,
  §2.1.4 cross-input interleaving against decode), batched decode over
  ragged lengths (every slot at its own position, the Pallas ragged
  kernel via ``dispatch.decode_attention``), sliding-window page
  reclamation (fully windowed stacks free pages wholly behind
  ``lengths - window`` mid-request), and slot recycling (finished
  sequences return their pages to the free list).  The split mirrors
  Chi et al.'s task-parallel decoupling: the scheduler computes
  addresses (page tables), the kernels only ever see dense tiles.

Two paged schedules (``--schedule {static,continuous}``):

* ``static`` — ``PagedScheduler.run``: admit a static request list,
  whole-prompt prefill on admission, decode rounds to completion.
* ``continuous`` — ``launch/engine.ContinuousEngine``: requests arrive
  on a virtual clock (``launch/loadgen``), each iteration composes a
  mix of multi-slot prefill chunks (one BATCHED ``prefill_attention``
  forward, B > 1) and decode steps under a token budget, and
  ``launch/metrics`` records TTFT + per-token latency percentiles.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \\
      --cache paged --schedule continuous --dispatch kernels \\
      --requests 8 --max-new 16 --rate 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..core.memory import DtypePolicy
from ..models.transformer import ExecOptions, Model, paged_supported
from .loadgen import Request  # noqa: F401  (re-export: the historical home)
from .prefix import PrefixCache

DEFAULT_PAGE_SIZE = 64


class Server:
    """Fixed-slot continuous-batching decoder (dense rectangular cache)."""

    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 log=print):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.log = log or (lambda *a, **k: None)
        self.cache = model.init_cache(slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = 0
        self.truncated = 0                # requests cut short at the wall
        self.rejected = 0                 # unserved at the wall, counted
        self.rejected_requests: List[Request] = []
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    def _feed_batch(self, tokens: np.ndarray) -> Dict[str, jax.Array]:
        batch = {"tokens": jnp.asarray(tokens)[:, None]}
        if self.model.cfg.mrope_sections:
            batch["positions"] = jnp.full(
                (self.slots, 1, len(self.model.cfg.mrope_sections)),
                self.pos, jnp.int32)
        return batch

    def step(self, tokens: np.ndarray) -> np.ndarray:
        logits, self.cache = self._decode(
            self.params, self.cache, self._feed_batch(tokens),
            jnp.int32(self.pos))
        self.pos += 1
        return np.asarray(jnp.argmax(logits, axis=-1))

    def run(self, requests: List[Request], greedy: bool = True
            ) -> List[Request]:
        queue = list(requests)
        cur = np.zeros((self.slots,), np.int32)
        prompt_cursor = np.zeros((self.slots,), np.int64)
        done: List[Request] = []
        while queue or any(r is not None for r in self.active):
            # fill free slots (continuous batching)
            for i in range(self.slots):
                if self.active[i] is None and queue:
                    self.active[i] = queue.pop(0)
                    prompt_cursor[i] = 0
                    cur[i] = self.active[i].prompt[0]
            nxt = self.step(cur)
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                prompt_cursor[i] += 1
                if prompt_cursor[i] < len(r.prompt):
                    cur[i] = r.prompt[prompt_cursor[i]]   # teacher-forced
                else:
                    r.out.append(int(nxt[i]))
                    cur[i] = nxt[i]
                    if len(r.out) >= r.max_new or self.pos >= self.max_len - 1:
                        r.done = True
                        r.truncated = len(r.out) < r.max_new
                        if r.truncated:
                            self.truncated += 1
                        done.append(r)
                        self.active[i] = None
            if self.pos >= self.max_len - 1:
                break
        # context wall: the shared ``pos`` hit max_len with work still in
        # flight.  Requests caught mid-prompt (or mid-generation) are
        # returned flagged — not silently dropped from ``active`` — and
        # requests never admitted are counted as rejected, mirroring the
        # paged scheduler's rejection accounting.
        for i, r in enumerate(self.active):
            if r is None:
                continue
            r.done = True
            r.truncated = True
            self.truncated += 1
            done.append(r)
            self.active[i] = None
            self.log(f"[dense] truncating request {r.rid} at the "
                     f"context wall (max_len={self.max_len}, "
                     f"{len(r.out)} tokens out)")
        for r in queue:
            r.done = False
            self.rejected += 1
            self.rejected_requests.append(r)
            self.log(f"[dense] rejecting request {r.rid}: context wall "
                     f"reached before admission (max_len={self.max_len})")
        return done


# --------------------------------------------------------------------------
# paged runtime
# --------------------------------------------------------------------------

class PageAllocator:
    """Host-side refcounted free list over the shared page pool.

    Physical page 0 is reserved as the TRASH page: inactive slots' tables
    point every logical page at it, so their masked decode writes can
    never corrupt a live sequence.

    Every live page carries a reference count: ``alloc`` hands out pages
    at refcount 1, ``share`` adds a holder (another slot's table binding,
    or the prefix cache), and ``release`` drops one — the page only
    returns to the free list when its last holder lets go.  Without
    sharing every page lives its whole life at refcount 1 and the
    allocator behaves exactly as before.
    """

    def __init__(self, total_pages: int):
        self.total = total_pages
        self._free = list(range(total_pages - 1, 0, -1))
        self.ref = [0] * total_pages
        # single choke point for owners that must react to page reuse:
        # called with the page list every ``alloc`` hands out.  The paged
        # scheduler resets quantization scale rows here — a recycled
        # page's stale scales must never leak into its next sequence.
        # Copy-on-write copies its payload AFTER alloc, so copied scales
        # survive the reset.
        self.on_alloc = None

    def available(self) -> int:
        return len(self._free)

    def held(self) -> int:
        """Pages with at least one holder (excl. the trash page)."""
        return sum(1 for p in range(1, self.total) if self.ref[p] > 0)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        got, self._free = self._free[-n:], self._free[:-n]
        got = got[::-1]
        for p in got:
            assert self.ref[p] == 0, f"page {p} allocated while referenced"
            self.ref[p] = 1
        if got and self.on_alloc is not None:
            self.on_alloc(got)
        return got

    def share(self, page: int) -> None:
        assert self.ref[page] > 0, f"cannot share free page {page}"
        self.ref[page] += 1

    def release(self, pages: List[int]) -> None:
        for p in reversed(pages):
            assert self.ref[p] > 0, f"double free of page {p}"
            self.ref[p] -= 1
            if self.ref[p] == 0:
                self._free.append(p)


def _copy_cache_page(cache, src, dst):
    """Copy one physical page across every layer's K/V pools (the
    copy-on-write payload).  Pool leaves are (P, page, Hkv, hd) and, for
    quantized pools, (P, Hkv) scale rows; scanned layer stacks carry a
    leading period axis (ndim 5 / 3).  Scales ride the same copy so a
    CoW'd page dequantizes identically to its source."""
    def cp(a):
        if a.ndim in (3, 5):
            return a.at[:, dst].set(a[:, src])
        return a.at[dst].set(a[src])
    return jax.tree.map(cp, cache)


def _reset_page_scales(cache, pages):
    """Zero the quantization scale rows of freshly-allocated pages.

    A recycled page still holds its previous sequence's int8 payload and
    scales; ``append_token_quantized`` treats scale 0 as "empty page" and
    wipes the stale payload on the first write, so resetting the scale
    row here is what makes page reuse sound under quantization.  No-op
    for float pools (no ``*_scale`` leaves)."""
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if isinstance(k, str) and k.endswith("_scale"):
                    out[k] = (v.at[:, pages].set(0.0) if v.ndim == 3
                              else v.at[pages].set(0.0))
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(cache)


def _page_bytes(cache) -> int:
    """Bytes ONE physical page occupies across every cache leaf — K/V
    pools at the active storage dtype plus any scale rows.  Pool axis is
    0 for per-layer leaves ((P, page, Hkv, hd) pools, (P, Hkv) scales)
    and 1 for scanned stacks with a leading period axis."""
    total = 0
    for leaf in jax.tree.leaves(cache):
        pool_axis = 1 if leaf.ndim in (3, 5) else 0
        per_page = 1
        for i, s in enumerate(leaf.shape):
            if i != pool_axis:
                per_page *= s
        total += per_page * jnp.dtype(leaf.dtype).itemsize
    return total


def pick_page_size(backend: Optional[str] = None) -> int:
    """Choose the pool layout from tuned decode plans: among cached
    ``decode_attention`` entries for this backend, take the page size of
    the fastest kernel-level plan (layout is a tunable, §3.4); fall back
    to DEFAULT_PAGE_SIZE when nothing was tuned."""
    from ..tune.cache import default_cache, parse_key
    cache = default_cache()
    backend = backend or jax.default_backend()
    best_us, best_page = float("inf"), 0
    for key, entry in cache.entries.items():
        try:
            kernel, shape, _, kb = parse_key(key)
        except ValueError:
            continue
        if kernel != "decode_attention" or kb != backend:
            continue
        plan = entry.get("plan", {})
        page = plan.get("page_size", 0)
        us = entry.get("us", float("inf"))
        if page and us < best_us:
            best_us, best_page = us, page
    return best_page or DEFAULT_PAGE_SIZE


class PagedScheduler:
    """Admission, chunked prefill, batched ragged decode, slot recycling.

    With ``prefix_cache=True`` the scheduler also shares KV pages across
    requests: finished prefills publish their full pages into a token-id
    trie (``launch/prefix.PrefixCache``), ``reserve`` binds a new
    request's leading table rows to matching cached pages (refcounted,
    prefill skipped for covered chunks), and a decode append into a page
    with other holders triggers copy-on-write.  The kernels are oblivious
    — they resolve ``(slot, page_idx)`` through the same tables either
    way — so sharing is zero kernel changes.
    """

    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 page_size: int = 0, total_pages: int = 0,
                 prefix_cache: bool = False, mesh=None, log=print):
        if not paged_supported(model.cfg):
            raise ValueError(
                f"arch {model.cfg.name} has recurrent/stateful layers; "
                "paged serving requires attention-family stacks "
                "(use --cache dense)")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.log = log or (lambda *a, **k: None)
        self.page = page_size or model.cfg.kv_page_size or pick_page_size()
        self.n_slot_pages = -(-max_len // self.page)
        total = total_pages or 1 + slots * self.n_slot_pages
        self.alloc = PageAllocator(total)
        self.cache = model.init_paged_cache(slots, max_len, self.page,
                                            total_pages=total)
        # quantized pools carry per-page scale rows; their lifecycle is
        # slaved to the allocator via on_alloc (reset-on-reuse)
        self._has_scales = any(
            leaf.ndim in (2, 3) for leaf in jax.tree.leaves(self.cache))
        self._page_bytes = _page_bytes(self.cache)
        if self._has_scales:
            self.alloc.on_alloc = self._reset_scales
        self.table = np.zeros((slots, self.n_slot_pages), np.int32)
        self.lengths = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.slot_pages: List[List[int]] = [[] for _ in range(slots)]
        # sliding-window page reclamation: only sound when EVERY attention
        # layer is windowed (a single global-attention layer reads the
        # whole history, so its pages are never dead)
        self.window = model.cfg.window if all(
            m == "swa" for m, _ in model.cfg.layer_kinds()) else 0
        self.reclaimed = [0] * slots      # leading logical pages freed
        self.pages_reclaimed = 0
        self.prefill_tokens = 0
        self.decode_steps = 0
        self.decode_tokens = 0
        # ---- speculative decoding (launch/speculative.py) ----
        self.verify_steps = 0             # batched verify forwards
        self.spec_drafted = 0             # candidate tokens proposed
        self.spec_accepted = 0            # candidates the target agreed with
        self.spec_emitted = 0             # tokens emitted by verify steps
        self.rejected = 0                 # inadmissible requests, counted
        self.rejected_requests: List[Request] = []
        self.truncated = 0                # finished early at max_len
        # ---- prefix sharing (refcounted pages + copy-on-write) ----
        self.prefix = PrefixCache(self.page) if prefix_cache else None
        self.shared_tokens = np.zeros((slots,), np.int64)
        self.shared_tokens_total = 0      # prompt tokens never prefilled
        self.cow_copies = 0
        # a fully-covered request's first decode appends into a shared
        # page; its copy-on-write page is reserved at admission so the
        # reserve-on-admit contract (never stall mid-decode) still holds
        self.cow_stash: List[List[int]] = [[] for _ in range(slots)]
        # ---- tensor parallelism (runtime/tp.py) ----
        # a mesh shards params + KV pools over its "model" axis and swaps
        # the step fns for shard_map'd twins; the scheduler's host-side
        # page metadata (tables, lengths, allocator, trie) is device-free
        # and identical across shards, so nothing else changes
        self.mesh = mesh
        self.tp = int(mesh.shape["model"]) if mesh is not None else 1
        if mesh is not None:
            from ..runtime import tp as tp_mod
            err = tp_mod.tp_error(model.cfg, self.tp)
            if err:
                raise ValueError(err)
            self.params = tp_mod.shard_tree(
                params, tp_mod.param_pspecs(params, model.cfg, self.tp),
                mesh)
            self.cache = tp_mod.shard_tree(
                self.cache, tp_mod.cache_pspecs(self.cache, model.cfg,
                                                self.tp), mesh)
            dec, pre = tp_mod.sharded_paged_fns(model, mesh)
            self._decode = jax.jit(dec, donate_argnums=(1,))
            self._prefill = jax.jit(pre, donate_argnums=(1,))
            self._verify = None        # no sharded verify twin (yet)
        else:
            self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
            self._prefill = jax.jit(model.prefill_step_paged,
                                    donate_argnums=(1,))
            self._verify = jax.jit(model.verify_step_paged,
                                   donate_argnums=(1,))
        # page copies / scale resets are sharding-agnostic (they index the
        # replicated pool axis), so GSPMD propagates the pool sharding
        self._copy_page = jax.jit(_copy_cache_page, donate_argnums=(0,))

    # ------------------------------------------------------------ admission
    def pages_needed(self, r: Request) -> int:
        """Lifetime page budget, clamped to the context window: positions
        beyond ``max_len`` can never be written (the decode guard stops
        there), so reserving pages for them would only waste pool."""
        return -(-min(len(r.prompt) + r.max_new, self.max_len) // self.page)

    def admissible(self, r: Request) -> bool:
        """Can this request EVER be admitted?  Its prompt must leave room
        to generate at least one token inside ``max_len``, and its
        (max_len-clamped) lifetime page budget must fit one slot's table
        and the pool (minus the trash page)."""
        return (len(r.prompt) < self.max_len
                and self.pages_needed(r) <= min(self.n_slot_pages,
                                                self.alloc.total - 1))

    def _reject_reason(self, r: Request) -> str:
        if len(r.prompt) >= self.max_len:
            return (f"prompt {len(r.prompt)} tokens >= max_len "
                    f"{self.max_len}")
        return (f"needs {self.pages_needed(r)} pages "
                f"(> {self.n_slot_pages}/slot or pool)")

    def reserve(self, r: Request, slot: int) -> bool:
        """Reserve the request's whole-lifetime pages up front (admission
        control: a request never stalls mid-decode on an empty free
        list) and bind it to ``slot``.  Prefill is the caller's business:
        the static path prefills the whole prompt immediately
        (``try_admit``), the continuous engine spreads chunks across
        iterations.

        With a prefix cache, matching cached pages are bound shared
        (refcounted) instead of allocated: ``shared_tokens[slot]`` tells
        the caller how many leading prompt tokens already hold valid K/V
        — prefill starts there.  When the cache covers the whole prompt
        the request also reserves one copy-on-write page (its first
        decode append lands mid-page in shared memory).
        """
        need = self.pages_needed(r)
        if need > self.n_slot_pages:
            return False
        shared: List[int] = []
        covered = 0
        if self.prefix is not None:
            shared, covered = self.prefix.match(r.prompt)
            # pin before any eviction below can free them out from under us
            for p in shared:
                self.alloc.share(p)
        n_cow = 1 if covered >= len(r.prompt) else 0
        n_priv = need - len(shared) + n_cow
        if self.alloc.available() < n_priv and self.prefix is not None:
            self.prefix.evict(n_priv - self.alloc.available(), self.alloc)
        if self.alloc.available() < n_priv:
            self.alloc.release(shared)     # unpin: admission failed
            return False
        pages = self.alloc.alloc(n_priv)
        self.cow_stash[slot] = pages[need - len(shared):]
        pages = shared + pages[:need - len(shared)]
        self.slot_pages[slot] = pages
        self.reclaimed[slot] = 0
        self.table[slot] = 0
        self.table[slot, :need] = pages
        self.lengths[slot] = 0
        self.active[slot] = r
        self.shared_tokens[slot] = covered
        self.shared_tokens_total += covered
        self.check_page_accounting()
        return True

    def try_admit(self, r: Request, slot: int) -> bool:
        """Static-schedule admission: reserve, then chunk-prefill the
        (non-shared tail of the) prompt to completion.  A fully-covered
        prompt skips prefill outright: the first token is born from one
        masked ragged decode of the last prompt token (which is also the
        copy-on-write moment for the shared partial page it lands in)."""
        if not self.reserve(r, slot):
            return False
        ln = len(r.prompt)
        start = int(self.shared_tokens[slot])
        if start >= ln:
            self.lengths[slot] = ln - 1
            first = self._first_token_via_decode(slot, int(r.prompt[ln - 1]))
        else:
            first = self._prefill_prompt(r, slot, start=start)
        self.lengths[slot] = ln
        self.cache_prefix(slot, r.prompt)
        r.out.append(first)
        self._reclaim_slot(slot)    # long prompts can outrun the window
        return True

    def _prefill_prompt(self, r: Request, slot: int, start: int = 0) -> int:
        """Chunked prefill (chunk = one page) from page-aligned ``start``
        (shared-covered leading chunks already hold valid K/V); returns
        the first generated token from the last real prompt position's
        logits."""
        ln = len(r.prompt)
        padded = -(-ln // self.page) * self.page
        toks = np.zeros((padded,), np.int32)
        toks[:ln] = r.prompt
        table_row = jnp.asarray(self.table[slot])
        logits = None
        for t0 in range(start, ln, self.page):
            last = min(ln, t0 + self.page) - 1 - t0
            logits, self.cache = self._prefill(
                self.params, self.cache,
                jnp.asarray(toks[t0:t0 + self.page])[None],
                jnp.int32(t0), table_row, jnp.int32(last))
        self.prefill_tokens += ln - start
        return int(np.argmax(np.asarray(logits[0])))

    def _first_token_via_decode(self, slot: int, token: int) -> int:
        """One masked ragged decode advancing only ``slot`` (other slots'
        ride-along writes land on the trash page): teacher-forces the
        last prompt token at position ``lengths[slot]`` and returns the
        argmax of its logits — the fully-covered admission path's TTFT
        moment."""
        self.prepare_decode([slot])
        mask = np.zeros((self.slots,), bool)
        mask[slot] = True
        lengths = np.where(mask, self.lengths, 0).astype(np.int32)
        table = np.where(mask[:, None], self.table, 0).astype(np.int32)
        cur = np.zeros((self.slots,), np.int32)
        cur[slot] = token
        nxt = self.step(cur, view=(lengths, table))
        return int(nxt[slot])

    # --------------------------------------------------- prefix sharing
    def cache_prefix(self, slot: int, prompt) -> None:
        """Publish the slot's fully-prefilled prompt chunks into the
        prefix trie (no-op without a cache).  Sound under window
        reclamation too: reclaiming only drops the slot's own reference,
        and a trie-held page keeps valid K/V for its prompt positions."""
        if self.prefix is None:
            return
        self.prefix.insert(prompt, self.slot_pages[slot], self.alloc)
        self.check_page_accounting()

    def _cow_page(self, slot: int, idx: int) -> None:
        """Give ``slot`` a private copy of its logical page ``idx`` if it
        currently has other holders (prefix cache or sharer slots):
        stashed CoW page first, then eviction-backed allocation; payload
        (and int8 scale rows) copied, table rebound, source released."""
        src = self.slot_pages[slot][idx]
        if self.alloc.ref[src] <= 1:
            return
        if self.cow_stash[slot]:
            dst = self.cow_stash[slot].pop()
        else:
            need = 1 - self.alloc.available()
            if need > 0 and self.prefix is not None:
                self.prefix.evict(need, self.alloc)
            dst = self.alloc.alloc(1)[0]
        self.cache = self._copy_page(self.cache, jnp.int32(src),
                                     jnp.int32(dst))
        self.slot_pages[slot][idx] = dst
        self.table[slot, idx] = dst
        self.alloc.release([src])
        self.cow_copies += 1
        self.check_page_accounting()

    def prepare_decode(self, slots: List[int]) -> None:
        """Copy-on-write sweep before a batched decode step: any slot
        whose next append position sits in a page with other holders
        (prefix cache or sharer slots) gets a private copy first, so the
        write can never corrupt a shared prefix."""
        for slot in slots:
            pos = int(self.lengths[slot])
            idx = pos // self.page
            if idx >= len(self.slot_pages[slot]):
                continue                 # guard: decode loop ends the req
            self._cow_page(slot, idx)

    def prepare_verify(self, slots: List[int], width: int) -> None:
        """Copy-on-write sweep before a batched verify step.  A verify
        window writes the FULL fixed-width span ``[lengths, lengths +
        width)`` — including padded rows for slots with fewer drafts —
        so every reserved page the span touches must be privately held
        before the write, not just the page under the cursor.  Pages
        beyond the reserved span are redirected to the trash page by the
        model's write clamp and need no copy; reclaimed leading pages
        sit provably below the span (window reclamation only frees pages
        wholly behind ``lengths - window``)."""
        for slot in slots:
            lo = int(self.lengths[slot]) // self.page
            hi = min((int(self.lengths[slot]) + width - 1) // self.page,
                     len(self.slot_pages[slot]) - 1)
            for idx in range(max(lo, self.reclaimed[slot]), hi + 1):
                self._cow_page(slot, idx)

    def _reclaim_slot(self, slot: int) -> int:
        """Sliding-window page reclamation (delay buffering §2.2 applied
        to the cache): once every attention layer is windowed, a page
        whose last position sits wholly behind ``lengths - window`` can
        never be read again — every later mask starts at
        ``lengths + 1 - window``.  Free it now (its table entry moves to
        the trash page, so residual masked reads stay harmless) instead of
        holding it until the request retires; queued requests admit
        against the returned pages.  Returns the number of pages freed.
        """
        if not self.window or not self.slot_pages[slot]:
            return 0
        # logical page p covers [p*page, (p+1)*page); dead iff
        # (p+1)*page <= lengths - window  (conservative by one position)
        dead = max(0, (int(self.lengths[slot]) - self.window) // self.page)
        dead = min(dead, len(self.slot_pages[slot]))
        freed = 0
        while self.reclaimed[slot] < dead:
            j = self.reclaimed[slot]
            self.alloc.release([self.slot_pages[slot][j]])
            self.table[slot, j] = 0          # -> trash page (masked reads)
            self.reclaimed[slot] += 1
            freed += 1
        if freed:
            self.pages_reclaimed += freed
            self.check_page_accounting()
        return freed

    def _reset_scales(self, pages: List[int]) -> None:
        """Allocator ``on_alloc`` hook: zero the scale rows of every page
        the allocator just handed out (see ``_reset_page_scales``)."""
        self.cache = _reset_page_scales(
            self.cache, jnp.asarray(pages, jnp.int32))

    def held_pages(self) -> int:
        """Physical pages with at least one holder (excl. trash page 0).
        A page shared by several slots and/or the prefix trie counts
        once — holders are tracked by the allocator's refcounts."""
        return self.alloc.held()

    def kv_bytes_resident(self) -> int:
        """Bytes of KV pool held by live pages, at the ACTIVE storage
        dtype (pools + scale rows): the byte-denominated residency that
        makes fp32/bf16/int8 serving directly comparable — int8 halves
        bf16's per-page cost and quarters fp32's, minus the small scale
        overhead."""
        return self.held_pages() * self._page_bytes

    def check_page_accounting(self) -> None:
        """Invariant, refcount-aware: every page is either free, held
        (refcount > 0), or the trash page — and the total reference count
        equals the number of holders we can name: live slot bindings
        (shared pages counted once per sharing slot), reserved
        copy-on-write pages, and prefix-trie nodes.  Sharing, CoW,
        reclamation, and recycling must never leak or double-free."""
        held = self.held_pages()
        free = self.alloc.available()
        assert held + free + 1 == self.alloc.total, (
            f"page accounting broken: held={held} free={free} "
            f"trash=1 != total={self.alloc.total}")
        expected = (sum(len(p) - r for p, r in zip(self.slot_pages,
                                                   self.reclaimed))
                    + sum(len(s) for s in self.cow_stash)
                    + (self.prefix.n_pages() if self.prefix else 0))
        refs = sum(self.alloc.ref[1:])
        assert refs == expected, (
            f"refcount accounting broken: sum(ref)={refs} != "
            f"slot bindings + cow stash + trie = {expected}")
        # post-rollback cursor sanity: speculative verify may write past
        # ``lengths`` and then roll back by NOT advancing it, so check the
        # cursor itself stayed inside the slot's live binding: at or below
        # the reserved span, at or above the reclaimed frontier
        for slot, r in enumerate(self.active):
            if r is None:
                continue
            ln = int(self.lengths[slot])
            span = len(self.slot_pages[slot]) * self.page
            assert ln <= span, (
                f"slot {slot} cursor {ln} past reserved span {span}")
            assert ln >= self.reclaimed[slot] * self.page, (
                f"slot {slot} cursor {ln} behind reclaimed frontier "
                f"{self.reclaimed[slot] * self.page}")
        # quantized pools: every int8 pages leaf must carry a companion
        # scale leaf sized to the same pool — scales are allocated with
        # their pages and recycled with them (reset via on_alloc), so a
        # missing or mis-sized scale buffer means a leak in that lockstep
        self._check_scale_lockstep()

    def _check_scale_lockstep(self) -> None:
        def walk(node):
            if isinstance(node, list):
                for v in node:
                    walk(v)
                return
            if not isinstance(node, dict):
                return
            for k, v in node.items():
                if isinstance(v, (dict, list)):
                    walk(v)
                elif k in ("k_pages", "v_pages") and v.dtype == jnp.int8:
                    s = node.get(k[0] + "_scale")
                    assert s is not None, (
                        f"int8 pool {k} has no companion {k[0]}_scale")
                    pool = v.shape[1] if v.ndim == 5 else v.shape[0]
                    spool = s.shape[1] if s.ndim == 3 else s.shape[0]
                    assert spool == pool, (
                        f"scale pool {spool} != page pool {pool} for {k}")
        walk(self.cache)

    def _recycle(self, slot: int) -> None:
        self.alloc.release(self.slot_pages[slot][self.reclaimed[slot]:]
                           + self.cow_stash[slot])
        self.slot_pages[slot] = []
        self.cow_stash[slot] = []
        self.reclaimed[slot] = 0
        self.table[slot] = 0
        self.lengths[slot] = 0
        self.shared_tokens[slot] = 0
        self.active[slot] = None
        self.check_page_accounting()

    # --------------------------------------------------------------- decode
    def _feed_batch(self, tokens: np.ndarray,
                    lengths: np.ndarray) -> Dict[str, jax.Array]:
        batch = {"tokens": jnp.asarray(tokens)[:, None]}
        if self.model.cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.asarray(lengths)[:, None, None],
                (self.slots, 1, len(self.model.cfg.mrope_sections))
            ).astype(jnp.int32)
        return batch

    def step(self, tokens: np.ndarray, view=None) -> np.ndarray:
        """One batched ragged decode step: every active slot advances at
        its own length; inactive slots ride along masked (trash page).

        ``view`` = (lengths, table) overrides the scheduler's canonical
        arrays — the continuous engine masks mid-prefill slots to zero
        length and the trash page so their ride-along writes are inert.
        """
        lengths, table = view if view is not None \
            else (self.lengths, self.table)
        logits, self.cache = self._decode(
            self.params, self.cache, self._feed_batch(tokens, lengths),
            jnp.int32(0),
            (jnp.asarray(lengths), jnp.asarray(table)))
        self.decode_steps += 1
        self.decode_tokens += int(np.count_nonzero(lengths))
        return np.asarray(jnp.argmax(logits, axis=-1))

    # --------------------------------------------------- speculative decoding
    def draft_for(self, drafter, slots: List[int]) -> Dict[int, List[int]]:
        """Propose draft tokens for the given active slots from their
        prompt + emitted histories, clamped so the accepted prefix plus
        bonus token can never step past the request's token budget, the
        context wall, or the slot's reserved pages (the clamp is what
        keeps rollback free: every REAL window write stays inside pages
        the slot already holds)."""
        hists = [list(self.active[i].prompt) + list(self.active[i].out)
                 for i in slots]
        proposals = drafter.propose(hists)
        drafts: Dict[int, List[int]] = {}
        for i, ks in zip(slots, proposals):
            r = self.active[i]
            cap = min(len(r.prompt) + r.max_new, self.max_len,
                      len(self.slot_pages[i]) * self.page)
            k = max(0, min(len(ks), drafter.max_draft,
                           cap - int(self.lengths[i]) - 1,
                           r.max_new - len(r.out) - 1))
            drafts[i] = [int(t) for t in ks[:k]]
        return drafts

    def verify_step(self, tokens: np.ndarray, view=None) -> np.ndarray:
        """One batched verify forward: every slot scores a fixed-width
        window ``[last_emitted, d1..d_{W-1}]`` starting at its own length
        through the ragged multi-token ``prefill_attention`` op (mid-page
        starts are legal: the mask is pure position arithmetic).  Returns
        the greedy argmax at EVERY window row — row t is the target's
        prediction for the token after position ``lengths + t``.  The
        forward ingests all W candidate K/V into the paged pool;
        rejecting a suffix costs nothing, the HOST just never advances
        ``lengths`` over it (the stale payload — and any int8
        running-max scale growth it caused — stays masked behind every
        later ``kpos < length`` read)."""
        if self._verify is None:
            raise RuntimeError(
                "speculative verify is not supported under --mesh "
                "tensor parallelism (no sharded verify twin yet); "
                "run unsharded or drop --speculate")
        lengths, table = view if view is not None \
            else (self.lengths, self.table)
        logits, self.cache = self._verify(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(lengths), jnp.asarray(table))
        self.verify_steps += 1
        return np.asarray(jnp.argmax(logits, axis=-1))

    def note_spec(self, drafted: int, accepted: int, emitted: int) -> None:
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted

    def run_speculative(self, requests: List[Request], drafter,
                        metrics=None) -> List[Request]:
        """Static-schedule speculative decoding: :meth:`run` with each
        decode round replaced by draft -> one fixed-width batched verify
        -> longest-correct-prefix acceptance -> host rollback.  Token
        emission replicates :meth:`run`'s per-token finish logic exactly
        (budget and context-wall checks after EVERY token), so greedy
        streams — including truncation points — are bit-identical to the
        non-speculative baseline: the bonus token of an empty acceptance
        IS the plain decode argmax."""
        from .speculative import accept_longest_prefix
        width = drafter.max_draft + 1
        queue = list(requests)
        cur = np.zeros((self.slots,), np.int32)
        for i, r in enumerate(self.active):    # resume pre-admitted slots
            if r is not None:
                cur[i] = r.out[-1]
        done: List[Request] = []
        while queue or any(r is not None for r in self.active):
            blocked = False
            for i in range(self.slots):
                while self.active[i] is None and queue and not blocked:
                    while queue and not self.admissible(queue[0]):
                        r = queue.pop(0)
                        r.done = False
                        self.rejected += 1
                        self.rejected_requests.append(r)
                        self.log(f"[paged] rejecting request {r.rid}: "
                                 f"{self._reject_reason(r)}")
                    if not queue or not self.try_admit(queue[0], i):
                        blocked = True             # wait for free pages
                        break
                    r = queue.pop(0)
                    cur[i] = r.out[-1]
                    if len(r.out) >= r.max_new:    # max_new == 1 edge
                        r.done = True
                        done.append(r)
                        self._recycle(i)
                if blocked:
                    break
            if not any(r is not None for r in self.active):
                if queue:
                    raise RuntimeError(
                        "admission deadlock: empty batch but queued "
                        "requests cannot reserve pages")
                break
            slots = [i for i, r in enumerate(self.active) if r is not None]
            drafts = self.draft_for(drafter, slots)
            self.prepare_verify(slots, width)
            toks = np.zeros((self.slots, width), np.int32)
            mask = np.zeros((self.slots,), bool)
            for i in slots:
                mask[i] = True
                toks[i, 0] = cur[i]
                toks[i, 1:1 + len(drafts[i])] = drafts[i]
            preds = self.verify_step(
                toks, view=(np.where(mask, self.lengths, 0).astype(np.int32),
                            np.where(mask[:, None], self.table, 0
                                     ).astype(np.int32)))
            for i in slots:
                r = self.active[i]
                ks = drafts[i]
                emit = accept_longest_prefix(ks, preds[i])
                accepted = len(emit) - 1
                emitted = 0
                finished = False
                for tok in emit:
                    self.lengths[i] += 1
                    r.out.append(tok)
                    cur[i] = tok
                    emitted += 1
                    if len(r.out) >= r.max_new \
                            or int(self.lengths[i]) >= self.max_len:
                        finished = True
                        break
                self.note_spec(len(ks), accepted, emitted)
                if metrics is not None:
                    metrics.on_spec_step(len(ks), accepted, emitted)
                if finished:
                    r.done = True
                    r.truncated = len(r.out) < r.max_new
                    if r.truncated:
                        self.truncated += 1
                        self.log(f"[paged] truncating request {r.rid} at "
                                 f"max_len={self.max_len} "
                                 f"({len(r.out)}/{r.max_new} tokens)")
                    done.append(r)
                    self._recycle(i)
                else:
                    self._reclaim_slot(i)
        return done

    def run(self, requests: List[Request]) -> List[Request]:
        queue = list(requests)
        cur = np.zeros((self.slots,), np.int32)
        for i, r in enumerate(self.active):    # resume pre-admitted slots
            if r is not None:
                cur[i] = r.out[-1]
        done: List[Request] = []
        while queue or any(r is not None for r in self.active):
            blocked = False
            for i in range(self.slots):
                # `while`, not `if`: a max_new == 1 request finishes right
                # out of prefill and frees its slot for the next in line
                while self.active[i] is None and queue and not blocked:
                    # reject permanently-oversized requests up front (they
                    # must not head-of-line-block servable traffic)
                    while queue and not self.admissible(queue[0]):
                        r = queue.pop(0)
                        r.done = False
                        self.rejected += 1
                        self.rejected_requests.append(r)
                        self.log(f"[paged] rejecting request {r.rid}: "
                                 f"{self._reject_reason(r)}")
                    if not queue or not self.try_admit(queue[0], i):
                        blocked = True             # wait for free pages
                        break
                    r = queue.pop(0)
                    cur[i] = r.out[-1]
                    if len(r.out) >= r.max_new:    # max_new == 1 edge
                        r.done = True
                        done.append(r)
                        self._recycle(i)
                if blocked:
                    break
            if not any(r is not None for r in self.active):
                if queue:
                    # unreachable by construction (an idle scheduler has
                    # every page free, so only inadmissible requests can
                    # fail, and those were rejected above) — defensive
                    raise RuntimeError(
                        "admission deadlock: empty batch but queued "
                        "requests cannot reserve pages")
                break
            self.prepare_decode([i for i, r in enumerate(self.active)
                                 if r is not None])
            nxt = self.step(cur)
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                self.lengths[i] += 1
                r.out.append(int(nxt[i]))
                cur[i] = nxt[i]
                if len(r.out) >= r.max_new \
                        or int(self.lengths[i]) >= self.max_len:
                    r.done = True
                    r.truncated = len(r.out) < r.max_new
                    if r.truncated:
                        self.truncated += 1
                        self.log(f"[paged] truncating request {r.rid} at "
                                 f"max_len={self.max_len} "
                                 f"({len(r.out)}/{r.max_new} tokens)")
                    done.append(r)
                    self._recycle(i)
                else:
                    self._reclaim_slot(i)
        return done


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--cache", default="dense", choices=("dense", "paged"),
                    help="KV-cache layout: dense rectangle or paged pool "
                         "(paged decodes through the ragged Pallas kernel)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged layout page size; 0 = pick from tuned "
                         "decode plans (fallback %d)" % DEFAULT_PAGE_SIZE)
    ap.add_argument("--total-pages", type=int, default=0,
                    help="page-pool size; 0 = full capacity "
                         "(slots x max_len); smaller oversubscribes")
    ap.add_argument("--kv-dtype", default="",
                    choices=("", "fp32", "bf16", "int8"),
                    help="paged KV pool storage dtype ('' = model compute "
                         "dtype); int8 stores symmetric-quantized pages "
                         "with per-(page, kv-head) f32 scales and the "
                         "ragged kernels dequantize at tile load")
    ap.add_argument("--weights-dtype", default="", choices=("", "int8"),
                    help="projection/MLP weight GEMMs: int8 routes through "
                         "dispatch.quantized_matmul (per-channel scales, "
                         "fused dequant, f32 accumulate)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged: share KV pages across requests with "
                         "common prompt prefixes (refcounted pages, "
                         "copy-on-write appends, prefill skipping)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="continuous loadgen: length of the common prompt "
                         "prefix sharing requests start with")
    ap.add_argument("--shared-frac", type=float, default=0.0,
                    help="continuous loadgen: fraction of requests that "
                         "carry the shared prefix (0..1)")
    ap.add_argument("--dispatch", default="auto",
                    choices=("auto", "kernels", "reference"),
                    help="kernel routing for every hot matmul/attention "
                         "(repro.kernels.dispatch)")
    ap.add_argument("--schedule", default="static",
                    choices=("static", "continuous"),
                    help="paged scheduling: static run-to-completion or "
                         "continuous batching on a virtual arrival clock")
    ap.add_argument("--speculate", default="", choices=("", "ngram", "model"),
                    help="paged: speculative decoding drafter — 'ngram' "
                         "(model-free suffix matching over emitted tokens) "
                         "or 'model' (truncated-sibling draft model sharing "
                         "the target's leading layers); draft tokens are "
                         "verified in one fixed-width batched forward "
                         "through the ragged prefill_attention op and "
                         "rejected suffixes rolled back host-side")
    ap.add_argument("--draft-tokens", type=int, default=3,
                    help="speculative: max draft tokens per verify window "
                         "(window width = draft_tokens + 1)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="continuous: max tokens composed per iteration "
                         "(0 = slots x page_size)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="continuous: Poisson arrival rate in requests "
                         "per clock unit (0 = burst at t=0)")
    ap.add_argument("--clock", default="wall", choices=("wall", "tick"),
                    help="continuous: virtual clock advances by measured "
                         "step wall time or a fixed tick")
    ap.add_argument("--tick", type=float, default=1.0,
                    help="continuous: clock increment per iteration in "
                         "tick mode")
    ap.add_argument("--seed", type=int, default=0,
                    help="load-generator seed (arrivals + prompt tokens)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="tensor-parallel degree: shard attention heads "
                         "and KV page pools over an N-device ('model',) "
                         "mesh (launch/mesh.make_serving_mesh). 0 = "
                         "unsharded; 1 = degenerate mesh (bit-identical "
                         "streams); N >= 2 needs N visible devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N to simulate on CPU)")
    args = ap.parse_args(argv)

    from ..kernels import dispatch
    from ..tune.cache import preload as preload_tuned
    preload_tuned(log=print)
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, dispatch=args.dispatch,
                              kv_cache=args.cache,
                              kv_page_size=args.page_size,
                              kv_dtype=args.kv_dtype,
                              weights_dtype=args.weights_dtype)
    print(f"[dispatch] policy={args.dispatch}")
    if cfg.input_mode == "embeddings":
        raise SystemExit("serving demo drives token-mode archs")
    model = Model(cfg, dt=DtypePolicy(param=jnp.bfloat16),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    mesh = None
    if args.mesh:
        if args.cache != "paged":
            raise SystemExit("--mesh requires --cache paged")
        from .mesh import make_serving_mesh
        mesh = make_serving_mesh(args.mesh)
        print(f"[mesh] model={args.mesh} "
              f"devices={len(jax.devices())} visible "
              f"(backend={jax.default_backend()})")
    drafter = None
    if args.speculate:
        if args.cache != "paged":
            raise SystemExit("--speculate requires --cache paged")
        if mesh is not None:
            raise SystemExit("--speculate is not supported with --mesh "
                             "(no sharded verify twin yet)")
        from .speculative import make_drafter
        # same rng key as the target params: the truncated-sibling draft
        # model's layers are then bit-identical to the target's leading
        # layers (early-exit drafting), which is what buys real acceptance
        drafter = make_drafter(args.speculate, cfg,
                               max_draft=args.draft_tokens,
                               dt=DtypePolicy(param=jnp.bfloat16),
                               rng_key=jax.random.key(0),
                               pad_to=args.max_len + args.draft_tokens,
                               batch_pad=args.slots)
        print(f"[spec] drafter={args.speculate} "
              f"draft_tokens={args.draft_tokens}")
    if args.cache == "paged":
        server = PagedScheduler(model, params, slots=args.slots,
                                max_len=args.max_len,
                                page_size=args.page_size,
                                total_pages=args.total_pages,
                                prefix_cache=args.prefix_cache,
                                mesh=mesh)
        print(f"[paged] page_size={server.page} "
              f"pool={server.alloc.total} pages "
              f"({server.n_slot_pages}/slot max, "
              f"kv_dtype={args.kv_dtype or 'compute'}, "
              f"page_bytes={server._page_bytes}, "
              f"prefix_cache={'on' if args.prefix_cache else 'off'}, "
              f"tp={server.tp})")
    else:
        server = Server(model, params, slots=args.slots,
                        max_len=args.max_len)

    if args.schedule == "continuous":
        if args.cache != "paged":
            raise SystemExit("--schedule continuous requires --cache paged")
        from .engine import ContinuousEngine
        from .loadgen import poisson_stream
        reqs = poisson_stream(args.requests, rate=args.rate,
                              vocab_size=cfg.vocab_size,
                              prompt_len=args.prompt_len,
                              max_new=args.max_new, seed=args.seed,
                              shared_prefix_len=args.shared_prefix_len,
                              shared_frac=args.shared_frac)
        engine = ContinuousEngine(server, token_budget=args.token_budget,
                                  clock=args.clock, tick=args.tick,
                                  drafter=drafter)
        # route counters tick at trace time, so reset BEFORE warmup: the
        # warmup compiles (every prefill width + masked decode) are exactly
        # the routes the run then executes from cache
        dispatch.reset_stats()
        engine.warmup()
        t0 = time.time()
        done = engine.run(reqs)
        dt = time.time() - t0
        s = engine.metrics.summary()
        ex = engine.executor
        total_new = sum(len(r.out) for r in done)
        print(f"served {len(done)} requests, {total_new} new tokens "
              f"in {dt:.2f}s ({total_new/dt:.1f} tok/s, {args.slots} "
              f"slots, schedule=continuous, "
              f"budget={engine.policy.token_budget})")
        print(f"[engine] iterations={engine.iterations} "
              f"prefill_calls={ex.prefill_calls} "
              f"max_prefill_batch={ex.max_prefill_batch} "
              f"rejected={server.rejected}")
        fmt = lambda v: "n/a" if v is None else f"{v:.4f}"
        print(f"[engine] ttft p50={fmt(s['ttft_p50'])} "
              f"p99={fmt(s['ttft_p99'])}  tok_latency "
              f"p50={fmt(s['tok_latency_p50'])} "
              f"p99={fmt(s['tok_latency_p99'])} ({args.clock} clock)")
    else:
        if args.shared_prefix_len > args.prompt_len:
            raise SystemExit("--shared-prefix-len exceeds --prompt-len")
        rng = np.random.default_rng(args.seed)
        prefix = (rng.integers(0, cfg.vocab_size, args.shared_prefix_len)
                  if args.shared_prefix_len > 0 else None)
        reqs = []
        for i in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size, args.prompt_len)
            if prefix is not None and float(rng.random()) < args.shared_frac:
                prompt = np.concatenate([prefix, prompt[len(prefix):]])
            reqs.append(Request(i, prompt, args.max_new))
        dispatch.reset_stats()
        t0 = time.time()
        done = (server.run_speculative(reqs, drafter) if drafter is not None
                else server.run(reqs))
        dt = time.time() - t0
        total_new = sum(len(r.out) for r in done)
        print(f"served {len(done)} requests, {total_new} new tokens "
              f"in {dt:.2f}s ({total_new/dt:.1f} tok/s, {args.slots} "
              f"slots, cache={args.cache})")
    if args.cache == "paged" and server.window:
        print(f"[paged] reclaimed {server.pages_reclaimed} window-dead "
              f"page(s) (window={server.window})")
    if args.speculate and server.verify_steps:
        rate = (server.spec_accepted / server.spec_drafted
                if server.spec_drafted else 0.0)
        print(f"[spec] verify_steps={server.verify_steps} "
              f"drafted={server.spec_drafted} "
              f"accepted={server.spec_accepted} "
              f"accept_rate={rate:.3f} emitted={server.spec_emitted} "
              f"tokens_per_step="
              f"{server.spec_emitted / server.verify_steps:.2f}")
    if args.cache == "paged":
        if server.truncated or server.rejected:
            print(f"[paged] truncated={server.truncated} "
                  f"rejected={server.rejected}")
        if server.prefix is not None:
            print(f"[prefix] hits={server.prefix.hits} "
                  f"misses={server.prefix.misses} "
                  f"shared_tokens={server.shared_tokens_total} "
                  f"cow_copies={server.cow_copies} "
                  f"evictions={server.prefix.evictions} "
                  f"cached_pages={server.prefix.n_pages()}")
    routes = dispatch.stats()
    for (op, route), n in sorted(routes.items()):
        print(f"[dispatch] {op:>16s} -> {route:<9s} x{n}")
    for r in done[:4]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    return done


if __name__ == "__main__":
    main()
