"""Registry completeness + plan-source consistency tests.

The OpSpec contract (repro.kernels.registry) promises that registering an
op is the WHOLE hookup: reference oracle, eligibility, tuned-plan key,
optional VJP, tune space.  These tests enforce the contract generically —
every future op registered through the registry is covered the moment it
is declared, with zero test edits:

1. completeness — every dispatch-surface op has a reference lowering, a
   kernel lowering, an eligibility predicate that rejects its declared
   known-bad input, and working example routes on both policies;
2. tune wiring — every tunable op's space yields >= 1 feasible plan on
   its declared default shapes, and ``tune.tuner``'s KERNELS /
   DEFAULT_SHAPES tables are derived from the registry (no parallel op
   tables to drift);
3. VJP — every op declaring a custom-VJP pair passes an fp32 grad
   differential (kernel route vs reference route);
4. plan-source threading — the (op, route, source) counters agree with
   ``tune.cache.lookup_stats()``, including the regression case where a
   tuned entry picks the *reference* lowering under "auto".
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import Level
from repro.kernels import dispatch, registry
from repro.tune import cache as tune_cache
from repro.tune import plan_feasible

DISPATCHABLE = sorted(registry.dispatchable())
TUNABLE = sorted(registry.tunable())
VJP_OPS = sorted(n for n, s in registry.dispatchable().items()
                 if s.vjp_bwd is not None)


@pytest.fixture
def empty_plan_cache(tmp_path, monkeypatch):
    """Point the tuned-plan cache at an empty file so the repo cache's
    (CPU-tuned, often level-1) entries cannot steer routing."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "empty.json"))
    tune_cache.preload()
    yield
    monkeypatch.delenv("REPRO_TUNE_CACHE")
    tune_cache.preload()


# ------------------------------------------------------------ completeness
@pytest.mark.parametrize("op", DISPATCHABLE)
def test_dispatch_ops_declare_full_contract(op):
    spec = registry.get(op)
    assert spec.reference is not None
    assert spec.kernel is not None
    assert spec.eligible is not None
    assert spec.plan_shape is not None, \
        f"{op} has no tuned-plan key schema"
    assert spec.example is not None and spec.bad_example is not None
    # VJP pairs come whole or not at all
    assert (spec.vjp_fwd is None) == (spec.vjp_bwd is None)


@pytest.mark.parametrize("op", DISPATCHABLE)
def test_example_routes_and_differential(op, empty_plan_cache):
    """The declared example runs on BOTH routes (counters prove it) and
    the kernel route matches the reference oracle in fp32."""
    spec = registry.get(op)
    args, kwargs = spec.example(jnp.float32)
    facade = getattr(dispatch, op)
    with dispatch.stats_scope() as stats:
        got = facade(*args, policy="kernels", **kwargs)
        want = facade(*args, policy="reference", **kwargs)
        s = stats()
    assert s.get((op, "kernel"), 0) >= 1, s
    assert s.get((op, "reference"), 0) >= 1, s
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("op", DISPATCHABLE)
def test_eligibility_rejects_known_bad_input(op):
    """policy="kernels" on the declared bad example must fall back to the
    reference route (the predicate rejected it), not crash or mis-route."""
    spec = registry.get(op)
    args, kwargs = spec.bad_example()
    facade = getattr(dispatch, op)
    with dispatch.stats_scope() as stats:
        facade(*args, policy="kernels", **kwargs)
        s = stats()
    assert s.get((op, "kernel"), 0) == 0, s
    assert s.get((op, "reference"), 0) == 1, s


# ------------------------------------------------------------- tune wiring
@pytest.mark.parametrize("op", TUNABLE)
def test_tune_space_yields_feasible_plan_on_default_shapes(op):
    spec = registry.get(op)
    t = spec.tune
    dtype_bytes = jnp.dtype(t.default_dtype).itemsize
    for shape in t.default_shapes:
        cands = t.space(tuple(shape), dtype_bytes)
        assert cands, (op, shape)
        feasible = [c for c in cands
                    if plan_feasible(op if spec.plan_kernel is None
                                     else spec.plan_kernel,
                                     tuple(shape), c,
                                     dtype_bytes=dtype_bytes)]
        assert feasible, f"{op} {shape}: no feasible candidate"


def test_tuner_tables_are_registry_derived():
    from repro.tune import DEFAULT_SHAPES, KERNELS
    assert sorted(KERNELS) == TUNABLE
    assert sorted(DEFAULT_SHAPES) == TUNABLE
    for name, spec in registry.tunable().items():
        assert tuple(DEFAULT_SHAPES[name]) == spec.tune.default_shapes
        assert KERNELS[name].call is spec.tune.call
        assert KERNELS[name].make_inputs is spec.tune.make_inputs


# --------------------------------------------------------------------- vjp
@pytest.mark.parametrize("op", VJP_OPS)
def test_vjp_ops_pass_fp32_grad_differential(op, empty_plan_cache):
    spec = registry.get(op)
    args, kwargs = spec.example(jnp.float32)
    facade = getattr(dispatch, op)
    cot = jax.random.normal(jax.random.key(9), jnp.shape(
        facade(*args, policy="reference", **kwargs)), jnp.float32)

    def loss(policy):
        def f(*diff_args):
            out = facade(*diff_args, *args[2:], policy=policy, **kwargs)
            return jnp.sum(out.astype(jnp.float32) * cot)
        return f

    gk = jax.grad(loss("kernels"), argnums=(0, 1))(*args[:2])
    gr = jax.grad(loss("reference"), argnums=(0, 1))(*args[:2])
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-4, atol=5e-4), gk, gr)


def test_matmul_bwd_routes_through_tuned_gemms(empty_plan_cache):
    """Satellite: the matmul VJP's projection grads are plain GEMMs
    dispatched through the staged tuned kernel (dx = g @ w.T, dw =
    x.T @ g) — each resolving its own transposed shape's plan and
    counting its route via the public ``matmul_bwd`` hook, the same
    paired-schedule idiom as the attention backward."""
    x = jax.random.normal(jax.random.key(0), (2, 16, 32), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (32, 24), jnp.float32)
    cot = jax.random.normal(jax.random.key(2), (2, 16, 24), jnp.float32)

    def f(x_, w_):
        return jnp.sum(dispatch.matmul(x_, w_, policy="kernels") * cot)

    with dispatch.stats_scope() as stats:
        gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
        s = stats()
    assert s.get(("matmul_bwd", "kernel"), 0) == 2, s   # dA and dB
    # the tuned-GEMM grads still match the plain einsum contraction
    np.testing.assert_allclose(
        np.asarray(gx, np.float32),
        np.einsum("bsn,kn->bsk", np.asarray(cot), np.asarray(w)),
        rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(
        np.asarray(gw, np.float32),
        np.einsum("bsk,bsn->kn", np.asarray(x), np.asarray(cot)),
        rtol=5e-4, atol=5e-4)


def test_matmul_bwd_respects_tuned_level_pin(tmp_path, monkeypatch):
    """A tuned entry at the dA GEMM's own (transposed) shape pinning
    level 1 sends THAT grad to the reference contraction under auto mode
    while the dB grad still runs the kernel — the backward resolves
    per-shape plans, never reusing the forward's."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    x = jax.random.normal(jax.random.key(0), (2, 16, 32), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (32, 24), jnp.float32)
    cache = tune_cache.PlanCache(tmp_path / "plans.json")
    # dx GEMM is g2 (32, 24) @ w2.T (24, 32) -> plan key (32, 24, 32)
    cache.put("matmul", (32, 24, 32), jnp.float32,
              {"level": int(Level.T1_PIPELINED)}, us=1.0)
    # the forward and the dw GEMM share the key (32, 32, 24); pin it to
    # the kernel level so the T1 entry above can't hijack it via the
    # nearest-shape fallback
    cache.put("matmul", (32, 32, 24), jnp.float32,
              {"level": int(Level.T3_REPLICATED)}, us=1.0)
    cache.save()
    tune_cache.preload()
    # emulate a TPU-style auto route so ctx.mode stays "auto" (an explicit
    # "kernels" policy overrides tuned level pins by contract)
    monkeypatch.setattr(dispatch, "_kernels_by_default", lambda: True)
    try:
        def f(x_, w_):
            return jnp.sum(dispatch.matmul(x_, w_, policy="auto"))

        with dispatch.stats_scope() as stats:
            jax.grad(f, argnums=(0, 1))(x, w)
            s = stats()
            sources = dispatch.plan_source_stats()
        assert s.get(("matmul_bwd", "reference"), 0) == 1, s
        assert s.get(("matmul_bwd", "kernel"), 0) == 1, s
        assert sources.get(("matmul_bwd", "reference", "exact"), 0) == 1, \
            sources
    finally:
        monkeypatch.delenv("REPRO_TUNE_CACHE")
        tune_cache.preload()


# ----------------------------------------------------- plan-source threading
def test_plan_source_tags_agree_with_lookup_stats(tmp_path, monkeypatch):
    """Satellite regression: a tuned entry that says "the reference
    lowering wins at this shape" (level 1) must be counted as the
    REFERENCE route under "auto", tagged with the exact-hit source — so
    ``dispatch.stats()`` and ``tune.cache.lookup_stats()`` tell one story
    instead of a "kernel" count with no kernel behind it."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    spec = registry.get("decode_attention")
    (q, kp, vp, table, lengths), _ = spec.example(jnp.float32)
    shape = spec.plan_shape({"softcap": 0.0}, q, kp, vp, table, lengths)
    cache = tune_cache.PlanCache(tmp_path / "plans.json")
    cache.put("decode_attention", shape, jnp.float32,
              {"level": int(Level.T1_PIPELINED),
               "page_size": kp.shape[1]}, us=1.0)
    cache.save()
    tune_cache.preload()
    # emulate a TPU-style auto route: backend gate open, mode stays "auto"
    monkeypatch.setattr(dispatch, "_kernels_by_default", lambda: True)
    try:
        with dispatch.stats_scope() as stats, \
                tune_cache.lookup_scope() as looks:
            got = dispatch.decode_attention(q, kp, vp, table, lengths,
                                            policy="auto")
            s, l = stats(), looks()
            sources = dispatch.plan_source_stats()
        assert s == {("decode_attention", "reference"): 1}, s
        assert sources.get(("decode_attention", "reference", "exact"),
                           0) == 1, sources
        assert l["exact"] == 1 and l["nearest"] == 0, l
        # ... while an explicit "kernels" policy overrides the tuned level
        # and forces the Pallas lowering
        with dispatch.stats_scope() as stats:
            forced = dispatch.decode_attention(q, kp, vp, table, lengths,
                                               policy="kernels")
            assert stats() == {("decode_attention", "kernel"): 1}
        np.testing.assert_allclose(np.asarray(forced, np.float32),
                                   np.asarray(got, np.float32),
                                   rtol=2e-4, atol=2e-4)
    finally:
        monkeypatch.delenv("REPRO_TUNE_CACHE")
        tune_cache.preload()


def test_plan_source_stats_isolated_by_stats_scope(empty_plan_cache):
    before = dispatch.plan_source_stats()
    with dispatch.stats_scope():
        x = jax.random.normal(jax.random.key(0), (8, 16), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (16, 8), jnp.float32)
        dispatch.matmul(x, w, policy="kernels")
        inside = dispatch.plan_source_stats()
        assert inside.get(("matmul", "kernel", "heuristic"), 0) == 1, inside
    assert dispatch.plan_source_stats() == before   # scope did not leak


def test_tune_only_ops_have_no_dispatch_surface():
    for name in ("flash_attention_bwd", "stencil", "histogram", "nbody"):
        spec = registry.get(name)
        assert not spec.dispatchable
        with pytest.raises(ValueError, match="no dispatch surface"):
            registry.call(name)
