"""Tensor-parallel paged serving (runtime/tp.py + mesh-aware OpSpecs).

Differential discipline for the sharded serving stack:

1. degenerate mesh — a 1-device ("model",) mesh must produce BIT-identical
   token streams to the unsharded scheduler (same params, same requests),
   with ``registry.tp_stats()`` proving every op routed through
   ``registry.call`` inside the shard_map'd region;
2. real mesh — a simulated 2-device mesh (subprocess, forced host device
   count) must match the single-device oracle stream-for-stream, for both
   sharded GQA pools (codeqwen, Hkv % tp == 0) and MQA replication
   (gemma, Hkv == 1), and for int8 KV pools;
3. contract surface — TP tags are inert outside ``registry.tp_scope``,
   unknown tags fail loudly inside one, and ``tp_error`` gates the
   divisibility requirements.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.memory import DtypePolicy
from repro.kernels import dispatch, registry
from repro.launch.loadgen import Request
from repro.launch.mesh import make_serving_mesh
from repro.launch.serve import PagedScheduler
from repro.models.transformer import ExecOptions, Model
from repro.runtime import tp as tp_mod

from helpers import run_multidevice


def _make_model(arch="gemma-2b", **over):
    cfg = get_arch(arch).smoke()
    cfg = dataclasses.replace(cfg, dispatch="kernels", kv_cache="paged",
                              **over)
    return Model(cfg, dt=DtypePolicy(param=jnp.bfloat16),
                 opts=ExecOptions(mode="run"))


def _requests(n, vocab, prompt_len=6, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, vocab, prompt_len), max_new)
            for i in range(n)]


def _run_sched(model, params, mesh=None, seed=0):
    sched = PagedScheduler(model, params, slots=2, max_len=64,
                           page_size=16, mesh=mesh, log=None)
    done = sched.run(_requests(3, model.cfg.vocab_size, seed=seed))
    return [list(r.out) for r in sorted(done, key=lambda r: r.rid)]


# --------------------------------------------------------------- tp == 1

def test_tp1_streams_bit_identical():
    """Degenerate 1-device mesh: token streams match the unsharded path
    exactly, and the tp route counters prove registry.call fired inside
    the mapped region."""
    model = _make_model()
    params = model.init(jax.random.key(0))
    with registry.stats_scope():
        base = _run_sched(model, params)
        assert registry.tp_stats() == {}, \
            "unsharded serving must not tick tp counters"
    with registry.stats_scope():
        sharded = _run_sched(model, params, mesh=make_serving_mesh(1))
        tp_routes = registry.tp_stats()
    assert sharded == base
    ops = {op for op, _ in tp_routes}
    assert {"matmul", "decode_attention", "prefill_attention"} <= ops, \
        f"expected the serving ops inside the shard_map region: {tp_routes}"
    # kernels policy: the mapped region must still route to kernels
    assert all(route == "kernel" for _, route in tp_routes), tp_routes


def test_tp1_scheduler_reports_mesh():
    model = _make_model()
    params = model.init(jax.random.key(1))
    sched = PagedScheduler(model, params, slots=2, max_len=64,
                           page_size=16, mesh=make_serving_mesh(1), log=None)
    assert sched.tp == 1 and sched.mesh is not None


# ------------------------------------------------------------ eligibility

def test_tp_error_gates():
    gemma = get_arch("gemma-2b").smoke()       # H=4, Hkv=1 (MQA)
    qwen = get_arch("codeqwen1.5-7b").smoke()  # H=4, Hkv=4
    assert tp_mod.tp_error(gemma, 1) is None
    assert tp_mod.tp_error(qwen, 1) is None
    assert tp_mod.tp_error(gemma, 2) is None          # MQA replicates pools
    assert tp_mod.tp_error(qwen, 2) is None           # GQA pools shard
    assert "n_heads" in tp_mod.tp_error(qwen, 3)      # 4 % 3 != 0
    assert not tp_mod.kv_sharded(gemma, 2)
    assert tp_mod.kv_sharded(qwen, 2)
    rwkv = get_arch("rwkv6-7b").smoke()
    assert "attention-only" in tp_mod.tp_error(rwkv, 2)


def test_pspec_derivation():
    """wq/bias shard the head axis, wo/norms/embed replicate, MLP shards
    col/row, and the stacked scan axis never shifts the sharded dim."""
    model = _make_model("codeqwen1.5-7b")
    cfg = model.cfg
    params = model.param_specs()
    specs = tp_mod.param_pspecs(params, cfg, 2)
    cache = jax.eval_shape(lambda: model.init_paged_cache(2, 64, 16))
    cspecs = tp_mod.cache_pspecs(cache, cfg, 2)

    def axis_of(spec):
        return tuple(spec).index("model") if "model" in tuple(spec) else None

    group = next(g for g in ("stack", "prefix", "tail") if params[g])
    layer = specs[group][0]
    lead = 1 if group == "stack" else 0
    assert axis_of(layer["attn"]["wq"]) == lead + 1      # (d, H, hd) -> H
    assert axis_of(layer["attn"]["wk"]) == lead + 1      # Hkv sharded (GQA)
    assert tuple(layer["attn"]["wo"]) == ()              # replicated
    assert tuple(specs["embed"]) == ()
    assert axis_of(layer["mlp"]["wg"]) == lead + 1       # (d, ff) -> ff
    assert axis_of(layer["mlp"]["wd"]) == lead + 0       # (ff, d) -> ff
    cgroup = next(g for g in ("stack", "prefix", "tail") if cache[g])
    clayer = cspecs[cgroup][0]
    clead = 1 if cgroup == "stack" else 0
    assert axis_of(clayer["k_pages"]) == clead + 2       # (P,page,Hkv,hd)
    # MQA: everything KV replicates
    gemma = _make_model()
    gcache = jax.eval_shape(lambda: gemma.init_paged_cache(2, 64, 16))
    for leaf in jax.tree.leaves(tp_mod.cache_pspecs(gcache, gemma.cfg, 2)):
        assert tuple(leaf) == ()


# ------------------------------------------------------- contract surface

def test_tp_tags_inert_outside_scope():
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    base = dispatch.matmul(x, w, policy="reference")
    tagged = dispatch.matmul(x, w, policy="reference", tp="col")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tagged))
    assert registry.tp_axis() is None


def test_unknown_tp_tag_raises_inside_scope():
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    with registry.tp_scope("model"):
        with pytest.raises(ValueError, match="no tp contract"):
            registry.call("matmul", x, w, mode="reference", tp="bogus")


def test_opspec_contracts_registered():
    for op, tags in {"matmul": {"col", "row"},
                     "quantized_matmul": {"col", "row"},
                     "decode_attention": {"heads"},
                     "prefill_attention": {"heads"}}.items():
        spec = registry.get(op)
        assert set(spec.tp or {}) == tags, op
    assert registry.get("matmul").tp["row"].collective == "psum"
    assert registry.get("decode_attention").tp["heads"].collective \
        == "all_gather"


# ----------------------------------------------------------- tp == 2 (slow)

_TP2_CODE = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.core.memory import DtypePolicy
from repro.kernels import registry
from repro.launch.loadgen import Request
from repro.launch.mesh import make_serving_mesh
from repro.launch.serve import PagedScheduler
from repro.models.transformer import ExecOptions, Model

assert len(jax.devices()) == 2, jax.devices()

def run(arch, kv_dtype, mesh):
    cfg = dataclasses.replace(get_arch(arch).smoke(), dispatch="kernels",
                              kv_cache="paged", kv_dtype=kv_dtype)
    model = Model(cfg, dt=DtypePolicy(param=jnp.bfloat16),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    sched = PagedScheduler(model, params, slots=2, max_len=64,
                           page_size=16, mesh=mesh, log=None)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 6), 4)
            for i in range(3)]
    done = sched.run(reqs)
    return [list(r.out) for r in sorted(done, key=lambda r: r.rid)]

for arch, kv in (("codeqwen1.5-7b", ""),   # GQA: pools shard 2-way
                 ("gemma-2b", ""),         # MQA: pools replicate
                 ("codeqwen1.5-7b", "int8")):  # scales shard with pools
    oracle = run(arch, kv, None)
    registry.reset_stats()
    sharded = run(arch, kv, make_serving_mesh(2))
    assert sharded == oracle, (arch, kv, sharded, oracle)
    ops = {op for op, _ in registry.tp_stats()}
    assert {"matmul", "decode_attention", "prefill_attention"} <= ops, ops
    print(f"OK {arch} kv={kv or 'compute'}")
print("ALL_MATCH")
"""


@pytest.mark.slow
def test_tp2_matches_single_device_oracle():
    """2-way simulated mesh vs unsharded oracle: identical greedy streams
    for sharded-GQA, replicated-MQA, and int8-KV pools, with the tp route
    counters proving in-region registry.call dispatch."""
    out = run_multidevice(_TP2_CODE, n_devices=2, timeout=900)
    assert "ALL_MATCH" in out, out
