"""Unit tests for the dry-run analysis machinery: HLO collective parsing,
ring-cost model, affine extrapolation — plus a live end-to-end check that
the parser finds the collectives XLA actually emits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (CollectiveOp, collective_stats,
                                     combine_affine, parse_collectives)
from helpers import run_multidevice

FAKE_HLO = """
HloModule jit_train_step

ENTRY %main {
  %ar = f32[2048,1024]{1,0} all-reduce(%x), replica_groups=[32,16]<=[512], to_apply=%add
  %ag = bf16[16,4096]{1,0} all-gather(%y), replica_groups={{0,1,2,3}, {4,5,6,7}}, dimensions={1}
  %rs = f32[128]{0} reduce-scatter(%z), replica_groups=[64,8]<=[512], to_apply=%add
  %a2a = bf16[8,256]{1,0} all-to-all(%w), replica_groups=[32,16]<=[512]
  %cp = f32[333]{0} collective-permute(%v), source_target_pairs={{0,1},{1,0}}
  %ard = f32[64]{0} all-reduce-done(%ar2)
  %fusion.1 = f32[10]{0} fusion(%a), kind=kLoop
}
"""


def test_parse_collectives_finds_all_and_sizes():
    ops = parse_collectives(FAKE_HLO)
    kinds = sorted(o.op for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    by = {o.op: o for o in ops}
    assert by["all-reduce"].operand_bytes == 2048 * 1024 * 4
    assert by["all-reduce"].group_size == 16
    assert by["all-gather"].operand_bytes == 16 * 4096 * 2
    assert by["all-gather"].group_size == 4
    # reduce-scatter operand = result shard * group
    assert by["reduce-scatter"].operand_bytes == 128 * 4 * 8
    assert by["collective-permute"].operand_bytes == 333 * 4


def test_ring_traffic_model():
    ar = CollectiveOp("all-reduce", 1000, 10, "")
    assert ar.per_chip_traffic == pytest.approx(2 * 1000 * 9 / 10)
    ag = CollectiveOp("all-gather", 1000, 10, "")   # operand_bytes=result
    assert ag.per_chip_traffic == pytest.approx(1000 / 10 * 9)
    cp = CollectiveOp("collective-permute", 1000, 2, "")
    assert cp.per_chip_traffic == 1000


def test_collective_stats_aggregation():
    st = collective_stats(FAKE_HLO)
    assert st.count == 5
    assert set(st.by_op) == {"all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute"}
    assert st.per_chip_bytes == pytest.approx(
        sum(st.by_op.values()))


def test_affine_combine():
    base = {"flops_per_device": 10.0, "hbm_bytes_per_device": 5.0,
            "collective_bytes_per_chip": 1.0}
    kind = {"attn/mlp": {"flops_per_device": 14.0,
                         "hbm_bytes_per_device": 7.0,
                         "collective_bytes_per_chip": 1.5}}
    tot = combine_affine(base, kind, {"attn/mlp": 10})
    assert tot["flops_per_device"] == pytest.approx(10 + 10 * 4)
    assert tot["hbm_bytes_per_device"] == pytest.approx(5 + 10 * 2)
    assert tot["collective_bytes_per_chip"] == pytest.approx(1 + 10 * 0.5)


@pytest.mark.slow   # multi-device subprocess compile
def test_parser_on_real_xla_output():
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.analysis import collective_stats
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("d",))
        def f(x):
            # force an all-reduce: row-sharded contraction
            return x.T @ x
        xs = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
        sh = NamedSharding(mesh, P("d", None))
        c = jax.jit(f, in_shardings=(sh,)).lower(xs).compile()
        st = collective_stats(c.as_text())
        assert st.count >= 1, c.as_text()[:2000]
        assert st.per_chip_bytes > 0
        print("PARSER-LIVE-OK", st.by_op)
    """)
    assert "PARSER-LIVE-OK" in out


@pytest.mark.slow   # multi-device subprocess compile
def test_affine_method_against_full_unroll():
    """The dry-run's core claim: cost(L layers) is affine in layer count.
    Verified by compiling 0,1,2,5-layer variants of a real arch and
    checking the 5-layer FLOPs against the affine prediction."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models.transformer import Model, ExecOptions
        import dataclasses

        cfg0 = get_arch("gemma-2b").smoke()
        kind = cfg0.layer_kinds()[0]

        def flops(n_layers):
            cfg = cfg0.with_layers((kind,) * n_layers)
            m = Model(cfg, opts=ExecOptions(mode="cost", block_q=16,
                                            block_kv=16))
            def loss(p, b):
                return m.loss_fn(p, b)[0]
            params = jax.eval_shape(m.init, jax.random.key(0))
            batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
            c = jax.jit(jax.grad(loss)).lower(params, batch).compile()
            ca = c.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            return float(ca["flops"])

        f0, f1, f5 = flops(0), flops(1), flops(5)
        pred5 = f0 + 5 * (f1 - f0)
        rel = abs(pred5 - f5) / f5
        # at toy (smoke) scale, XLA fusion differences across depths add a
        # few % of non-affinity on elementwise ops; matmul-dominated real
        # configs are affine to <1% (layer cost is depth-independent)
        assert rel < 0.08, (f0, f1, f5, pred5, rel)
        print("AFFINE-OK", rel)
    """, n_devices=1)
    assert "AFFINE-OK" in out
