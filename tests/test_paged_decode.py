"""Paged-KV serving runtime tests.

Three layers of evidence, mirroring the dispatch discipline:

1. kernel differential — the Pallas ragged decode kernel against the
   gather-and-mask reference, for ragged lengths x every attention arch's
   own geometry (GQA groups, windows) x {fp32, bf16};
2. paged-vs-dense equivalence — chunked prefill + batched ragged decode
   must produce the same logits as the dense full-sequence forward (same
   tokens in -> same logits out), including across slot-recycle
   boundaries in the scheduler;
3. runtime properties — admission control, page recycling, tuned-plan
   consumption, and the paged-arch support gate.

All probes run inside ``dispatch.stats_scope()`` / ``tune.lookup_scope()``
so counters never leak across test modules.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.core.memory import DtypePolicy
from repro.kernels import dispatch
from repro.models.transformer import (ExecOptions, Model, paged_supported)
from repro.tune import cache as tune_cache

DTYPES = {
    "float32": DtypePolicy(compute=jnp.float32),
    "bfloat16": DtypePolicy(),
}
TOLS = {
    "float32": dict(rtol=2e-4, atol=2e-4),
    "bfloat16": dict(rtol=5e-2, atol=5e-2),
}

# ragged length vectors covering: inactive slot, single token, page
# boundary +/- 1, exactly-full cache
RAGGED_LENGTHS = [(0, 24, 9), (1, 8, 7), (17, 24, 16)]


def _assert_close(got, want, dtype_name):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **TOLS[dtype_name])


def _paged_inputs(n_heads, n_kv_heads, hd, dtype, *, slots=3, page=8,
                  n_pages=3):
    pool = 1 + slots * n_pages
    ks = jax.random.split(jax.random.key(0), 3)
    q = (0.5 * jax.random.normal(ks[0], (slots, n_heads, hd),
                                 jnp.float32)).astype(dtype)
    kp = (0.5 * jax.random.normal(ks[1], (pool, page, n_kv_heads, hd),
                                  jnp.float32)).astype(dtype)
    vp = (0.5 * jax.random.normal(ks[2], (pool, page, n_kv_heads, hd),
                                  jnp.float32)).astype(dtype)
    rng = np.random.default_rng(0)
    table = jnp.asarray(
        1 + rng.permutation(pool - 1)[:slots * n_pages].reshape(
            slots, n_pages), jnp.int32)
    return q, kp, vp, table


# ---------------------------------------------------- kernel differential
@pytest.fixture
def empty_plan_cache(tmp_path, monkeypatch):
    """Point the tuned-plan cache at an empty file: the repo cache may
    hold a (CPU-tuned) level-1 decode plan, which would silently resolve
    the kernel route's ``plan="tuned"`` to the reference lowering — the
    differential must drive the actual Pallas kernel."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "empty.json"))
    tune_cache.preload()
    yield
    monkeypatch.delenv("REPRO_TUNE_CACHE")
    tune_cache.preload()


@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_attention_differential(arch, dtype_name, empty_plan_cache):
    """Kernel route == reference route for the arch's own attention
    geometry over ragged lengths (masked tail pages, GQA, windows)."""
    cfg = ARCHS[arch].smoke()
    mixers = {m for m, _ in cfg.layer_kinds()}
    if not ({"attn", "swa"} & mixers):
        pytest.skip("attention-free arch")
    window = cfg.window if "swa" in mixers else 0
    dt = DTYPES[dtype_name]
    q, kp, vp, table = _paged_inputs(cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim, dt.compute)
    with dispatch.stats_scope() as stats:
        for lens in RAGGED_LENGTHS:
            lengths = jnp.asarray(lens, jnp.int32)
            got = dispatch.decode_attention(
                q, kp, vp, table, lengths, window=window,
                policy="kernels")
            want = dispatch.decode_attention(
                q, kp, vp, table, lengths, window=window,
                policy="reference")
            assert got.dtype == want.dtype
            _assert_close(got, want, dtype_name)
        s = stats()
    assert s[("decode_attention", "kernel")] == len(RAGGED_LENGTHS)
    assert s[("decode_attention", "reference")] == len(RAGGED_LENGTHS)


def test_decode_attention_inactive_slot_zero_and_finite():
    """lengths == 0 slots (pointing at the trash page) must come out
    exactly zero on both routes — no NaNs from empty softmaxes."""
    q, kp, vp, table = _paged_inputs(4, 2, 16, jnp.float32)
    lengths = jnp.asarray([0, 0, 5], jnp.int32)
    for policy in ("kernels", "reference"):
        out = dispatch.decode_attention(q, kp, vp, table, lengths,
                                        policy=policy)
        assert bool(jnp.all(jnp.isfinite(out)))
        assert float(jnp.max(jnp.abs(out[:2]))) == 0.0


def test_decode_attention_pages_per_tile_invariant():
    """KV-tile geometry is a pure performance knob: every pages_per_tile
    (incl. non-divisors of n_pages -> padded tail tiles) agrees."""
    from repro.kernels.attention import decode_attention as decode_op
    q, kp, vp, table = _paged_inputs(4, 2, 16, jnp.float32, n_pages=4)
    lengths = jnp.asarray([3, 30, 12], jnp.int32)
    base = decode_op(q, kp, vp, table, lengths, pages_per_tile=1)
    for ppt in (2, 3, 4, 16):
        got = decode_op(q, kp, vp, table, lengths, pages_per_tile=ppt)
        _assert_close(got, base, "float32")


# Accuracy bound for the int8 KV path: symmetric per-(page, kv-head)
# quantization of ~N(0, 0.5) K/V keeps the attention output within this
# max-abs-error of the fp32 oracle (measured ~1e-2 on these geometries;
# 5e-2 leaves noise headroom while still failing a wrong-scale bug by
# orders of magnitude).  The kernel's in-tile dequant vs the dequantizing
# reference is a SAME-MATH differential and runs at the fp32 tolerance.
INT8_KV_MAX_ABS_ERR = 5e-2


def _quantized_pools(kp, vp):
    from repro.core import quant
    kq, ks = quant.quantize_pages(kp)
    vq, vs = quant.quantize_pages(vp)
    return kq, ks, vq, vs


def _check_decode_int8(cfg, window):
    q, kp, vp, table = _paged_inputs(cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim, jnp.float32)
    kq, ks, vq, vs = _quantized_pools(kp, vp)
    with dispatch.stats_scope() as stats:
        for lens in RAGGED_LENGTHS:
            lengths = jnp.asarray(lens, jnp.int32)
            got = dispatch.decode_attention(
                q, kq, vq, table, lengths, ks, vs, window=window,
                policy="kernels")
            oracle = dispatch.decode_attention(
                q, kq, vq, table, lengths, ks, vs, window=window,
                policy="reference")
            _assert_close(got, oracle, "float32")
            full = dispatch.decode_attention(
                q, kp, vp, table, lengths, window=window,
                policy="reference")
            err = float(jnp.max(jnp.abs(got - full)))
            assert err < INT8_KV_MAX_ABS_ERR, (
                f"int8 decode error {err} exceeds bound "
                f"{INT8_KV_MAX_ABS_ERR} (lengths={lens})")
        s = stats()
    assert s[("decode_attention", "kernel")] == len(RAGGED_LENGTHS)


def test_decode_attention_int8_differential(empty_plan_cache):
    """int8 pools + per-page scales: the kernel's in-tile dequant agrees
    with the dequantizing reference at fp32 tolerance, and both stay
    within the documented quantization-noise bound of the fp32 oracle."""
    _check_decode_int8(ARCHS["gemma-2b"].smoke(), 0)


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_attention_int8_all_archs(arch, empty_plan_cache):
    """The int8 decode differential swept over every attention arch's own
    geometry (GQA groups, windows)."""
    cfg = ARCHS[arch].smoke()
    mixers = {m for m, _ in cfg.layer_kinds()}
    if not ({"attn", "swa"} & mixers):
        pytest.skip("attention-free arch")
    _check_decode_int8(cfg, cfg.window if "swa" in mixers else 0)


def test_decode_tuned_plan_consumed(tmp_path, monkeypatch):
    """A seeded exact-shape decode plan is picked up by the kernel route
    (lookup counters prove the cache was consulted)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    q, kp, vp, table = _paged_inputs(4, 2, 16, jnp.float32)
    shape = (q.shape[0], q.shape[1], table.shape[1], kp.shape[1],
             q.shape[2])
    cache = tune_cache.PlanCache(tmp_path / "plans.json")
    cache.put("decode_attention", shape, jnp.float32,
              {"level": 3, "page_size": kp.shape[1], "pages_per_tile": 2,
               "prefetch_depth": 2}, us=1.0)
    cache.save()
    tune_cache.preload()
    try:
        lengths = jnp.asarray([4, 20, 11], jnp.int32)
        with tune_cache.lookup_scope() as looks, \
                dispatch.stats_scope() as stats:
            got = dispatch.decode_attention(q, kp, vp, table, lengths,
                                            policy="kernels")
            assert looks()["exact"] == 1
            assert stats()[("decode_attention", "kernel")] == 1
        want = dispatch.decode_attention(q, kp, vp, table, lengths,
                                         policy="reference")
        _assert_close(got, want, "float32")
    finally:
        monkeypatch.delenv("REPRO_TUNE_CACHE")
        tune_cache.preload()             # restore the repo default cache


# ------------------------------------------------- paged-vs-dense logits
def _tiny_cfg(name, **overrides):
    cfg = ARCHS[name].smoke()
    return dataclasses.replace(
        cfg, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
        vocab_size=128, n_experts=min(cfg.n_experts, 4) or 0,
        **overrides)


@pytest.mark.parametrize("arch,policy,layout", [
    ("gemma-2b", "reference", "prefix"),
    ("gemma-2b", "kernels", "prefix"),
    ("gemma-2b", "reference", "scan"),    # scanned layer periods
    ("gemma3-4b", "reference", "prefix"),  # sliding-window mask
    ("gemma3-4b", "kernels", "prefix"),
])
def test_paged_prefill_decode_matches_dense_forward(arch, policy, layout):
    """Same tokens in -> same logits out: chunked prefill (incl. a padded
    partial page) + teacher-forced ragged decode against the paged cache
    reproduce the dense full-sequence forward, on both dispatch routes
    and through both stacking strategies (unrolled prefix layers and
    lax.scan'd layer periods)."""
    page, slots, max_len = 4, 2, 32
    cfg = _tiny_cfg(arch, dispatch=policy)
    if layout == "scan":
        cfg = dataclasses.replace(
            cfg, n_layers=5, prefix=(("attn", "mlp"),),
            pattern=(("attn", "mlp"), ("attn", "mlp")))
    assert paged_supported(cfg)
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    cache = model.init_paged_cache(slots, max_len, page)
    n_slot_pages = max_len // page

    rng = np.random.default_rng(1)
    L = 6                                  # not page-aligned: padded tail
    prompt = rng.integers(0, cfg.vocab_size, L)
    table = np.zeros((slots, n_slot_pages), np.int32)
    table[0] = np.arange(1, 1 + n_slot_pages)
    lengths = np.zeros((slots,), np.int32)

    toks = np.zeros((((L + page - 1) // page) * page,), np.int32)
    toks[:L] = prompt
    logits = None
    for t0 in range(0, L, page):
        last = min(L, t0 + page) - 1 - t0
        logits, cache = model.prefill_step_paged(
            params, cache, jnp.asarray(toks[t0:t0 + page])[None],
            jnp.int32(t0), jnp.asarray(table[0]), jnp.int32(last))
    lengths[0] = L

    # paged-incremental and full-forward are different (equivalent)
    # reduction orders; multi-layer fp32 drift on logits of magnitude ~10
    # sits near 2e-4, so this equivalence check runs at 1e-3 — a wrong
    # mask/page/position produces O(1) errors, far above it
    eq_tol = dict(rtol=1e-3, atol=1e-3)

    seq = list(prompt)
    full = model.forward(params, {"tokens": jnp.asarray(seq)[None]})
    np.testing.assert_allclose(np.asarray(logits[0], np.float32),
                               np.asarray(full[0, -1], np.float32), **eq_tol)

    for _ in range(4):                     # teacher-forced ragged decode
        nxt = int(np.argmax(np.asarray(logits[0])))
        seq.append(nxt)
        dl, cache = model.decode_step(
            params, cache, {"tokens": jnp.asarray([[nxt], [0]], jnp.int32)},
            jnp.int32(0),
            paged=(jnp.asarray(lengths), jnp.asarray(table)))
        lengths[0] += 1
        full = model.forward(params, {"tokens": jnp.asarray(seq)[None]})
        np.testing.assert_allclose(np.asarray(dl[0], np.float32),
                                   np.asarray(full[0, -1], np.float32),
                                   **eq_tol)
        logits = dl[:1]


# --------------------------------------------------- scheduler properties
def _make_scheduler(slots=2, max_len=32, page=4, total_pages=0,
                    arch="gemma-2b", dispatch="reference", kv_dtype="",
                    log=print):
    from repro.launch.serve import PagedScheduler
    cfg = _tiny_cfg(arch, dispatch=dispatch, kv_dtype=kv_dtype)
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    return PagedScheduler(model, params, slots=slots, max_len=max_len,
                          page_size=page, total_pages=total_pages,
                          log=log), cfg


def test_paged_scheduler_recycle_equivalence():
    """Slot recycling is invisible to results: requests served through a
    2-slot scheduler (forcing recycles + batched ragged decode) emit the
    same tokens as each request alone in a fresh scheduler."""
    from repro.launch.serve import Request
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 128, rng.integers(3, 9)) for _ in range(4)]

    sched, _ = _make_scheduler(slots=2)
    done = sched.run([Request(i, p, 5) for i, p in enumerate(prompts)])
    assert len(done) == 4
    batched = {r.rid: list(r.out) for r in done}

    for i, p in enumerate(prompts):
        solo_sched, _ = _make_scheduler(slots=2)
        solo = solo_sched.run([Request(0, p, 5)])
        assert batched[i] == list(solo[0].out), f"request {i} diverged"


def test_paged_scheduler_admission_and_page_accounting():
    """Reserve-on-admit: with a pool of 5 usable pages and 3-page
    requests, only one runs at a time; every page returns to the free
    list when its request retires."""
    from repro.launch.serve import Request
    sched, _ = _make_scheduler(slots=2, max_len=16, page=4, total_pages=6)
    free0 = sched.alloc.available()
    assert free0 == 5
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, 128, 6), 4) for i in range(3)]
    assert sched.pages_needed(reqs[0]) == 3          # ceil((6+4)/4)
    done = sched.run(reqs)
    assert len(done) == 3 and all(len(r.out) == 4 for r in done)
    assert sched.alloc.available() == free0          # no page leaked
    assert all(not pages for pages in sched.slot_pages)


def test_paged_scheduler_instant_finish_readmits():
    """max_new == 1 requests finish straight out of prefill; the freed
    slot must be re-offered to the queue in the same admission pass (more
    one-token requests than slots used to trip the deadlock guard)."""
    from repro.launch.serve import Request
    sched, _ = _make_scheduler(slots=2)
    rng = np.random.default_rng(6)
    reqs = [Request(i, rng.integers(0, 128, 4), 1) for i in range(5)]
    done = sched.run(reqs)
    assert sorted(r.rid for r in done) == list(range(5))
    assert all(len(r.out) == 1 for r in done)


def test_paged_scheduler_rejects_oversized_request():
    """A request that can never be admitted (prompt >= max_len leaves no
    room to generate) must be rejected (done=False, no output), not
    head-of-line block the queue."""
    from repro.launch.serve import Request
    sched, _ = _make_scheduler(slots=2, max_len=16, page=4)
    rng = np.random.default_rng(5)
    big = Request(0, rng.integers(0, 128, 17), 8)   # prompt >= max_len
    ok = Request(1, rng.integers(0, 128, 5), 3)
    done = sched.run([big, ok])
    assert [r.rid for r in done] == [1]
    assert len(done[0].out) == 3
    assert big.done is False and big.out == []


def test_paged_gate_rejects_recurrent_archs():
    from repro.launch.serve import PagedScheduler
    cfg = ARCHS["rwkv6-7b"].smoke()
    assert not paged_supported(cfg)
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32))
    with pytest.raises(ValueError, match="paged serving requires"):
        PagedScheduler(model, None, slots=1, max_len=16, page_size=4)


def _all_swa_cfg(window, **overrides):
    """A fully sliding-window stack (every attention layer windowed) —
    the only layout where window page reclamation is sound."""
    cfg = _tiny_cfg("gemma3-4b", window=window, **overrides)
    return dataclasses.replace(
        cfg, n_layers=2, prefix=(("swa", "mlp"), ("swa", "mlp")),
        pattern=())


def test_window_reclamation_frees_pages_behind_window():
    """swa slots stop holding max_len pages: once decode advances past
    the window, wholly-dead pages return to the free list mid-request,
    and the accounting invariant (held + free + trash == total) holds."""
    from repro.launch.serve import PagedScheduler, Request
    page, window, max_len = 4, 8, 32
    cfg = _all_swa_cfg(window, dispatch="reference")
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    sched = PagedScheduler(model, params, slots=1, max_len=max_len,
                           page_size=page)
    assert sched.window == window
    free0 = sched.alloc.available()
    rng = np.random.default_rng(8)
    done = sched.run([Request(0, rng.integers(0, 128, 6), 18)])
    assert len(done) == 1 and len(done[0].out) == 18
    # final length 6 + 18 = 24 -> (24 - 8) // 4 = 4 pages were dead by
    # the end; all pages back after retirement, none double-freed
    assert sched.pages_reclaimed >= 3
    assert sched.alloc.available() == free0
    sched.check_page_accounting()


def test_window_reclamation_lets_queued_requests_admit_early():
    """Reclaimed pages are immediately admissible capital: with a pool
    too small for two whole-lifetime reservations, the second request
    admits while the first is still decoding (it could not without
    reclamation, since the first holds its full budget until retirement)."""
    from repro.launch.serve import PagedScheduler, Request
    page, window, max_len = 4, 4, 32
    cfg = _all_swa_cfg(window, dispatch="reference")
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    # each request: 6 prompt + 14 new = 20 tokens -> 5 pages; pool of 8
    # usable pages cannot hold two reservations at once
    sched = PagedScheduler(model, params, slots=2, max_len=max_len,
                           page_size=page, total_pages=9)
    rng = np.random.default_rng(9)
    reqs = [Request(i, rng.integers(0, 128, 6), 14) for i in range(2)]
    done = sched.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1]
    assert all(len(r.out) == 14 for r in done)
    assert sched.pages_reclaimed > 0
    assert sched.alloc.available() == 8
    sched.check_page_accounting()


def test_window_reclamation_does_not_change_outputs():
    """Reclamation only frees provably-dead pages: generated tokens match
    a run with reclamation disabled (window forced off on the scheduler),
    and the paged outputs still match the dense full-sequence forward."""
    from repro.launch.serve import PagedScheduler, Request
    page, window = 4, 8
    cfg = _all_swa_cfg(window, dispatch="reference")
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, 128, 7)

    def run(reclaim):
        sched = PagedScheduler(model, params, slots=1, max_len=32,
                               page_size=page)
        if not reclaim:
            sched.window = 0          # disable reclamation only
        done = sched.run([Request(0, prompt, 12)])
        return list(done[0].out), sched.pages_reclaimed

    with_reclaim, n_freed = run(True)
    without_reclaim, n_kept = run(False)
    assert n_freed > 0 and n_kept == 0
    assert with_reclaim == without_reclaim
    # and against the dense forward: teacher-force the same sequence
    seq = list(prompt) + with_reclaim[:-1]
    full = model.forward(params, {"tokens": jnp.asarray(seq)[None]})
    assert int(jnp.argmax(full[0, -1])) == with_reclaim[-1]


def test_no_reclamation_for_global_or_mixed_attention():
    """A single global-attention layer reads the whole history: schedulers
    over global or mixed (gemma3 5:1 swa:attn) stacks must never reclaim."""
    sched, _ = _make_scheduler(slots=1, arch="gemma-2b")
    assert sched.window == 0
    mixed, _ = _make_scheduler(slots=1, arch="gemma3-4b")
    assert mixed.window == 0          # swa AND global layers -> unsound


# ------------------------------------------------ continuous-batching engine
def _make_engine(slots=2, max_len=32, page=4, total_pages=0,
                 dispatch="reference", kv_dtype="", token_budget=0,
                 log=None):
    from repro.launch.engine import ContinuousEngine
    sched, cfg = _make_scheduler(slots=slots, max_len=max_len, page=page,
                                 total_pages=total_pages,
                                 dispatch=dispatch, kv_dtype=kv_dtype,
                                 log=log)
    return ContinuousEngine(sched, token_budget=token_budget,
                            clock="tick", log=log), cfg


def test_continuous_engine_seeded_determinism():
    """Same loadgen seed -> identical arrival times, admission order, and
    token streams across two fresh engines (tick clock: the run is a pure
    function of the seed)."""
    from repro.launch.loadgen import poisson_stream

    def run_once():
        engine, _ = _make_engine()
        reqs = poisson_stream(5, rate=2.0, vocab_size=128, prompt_len=5,
                              max_new=4, seed=7, prompt_jitter=3)
        done = engine.run(reqs)
        return (list(engine.admission_order),
                {r.rid: list(r.out) for r in done},
                engine.metrics.summary())

    order_a, out_a, sum_a = run_once()
    order_b, out_b, sum_b = run_once()
    assert len(out_a) == 5 and all(len(o) == 4 for o in out_a.values())
    assert order_a == order_b
    assert out_a == out_b
    assert sum_a == sum_b
    assert sum_a["requests_finished"] == 5
    assert sum_a["ttft_p50"] is not None and sum_a["ttft_p50"] >= 0
    assert sum_a["tok_latency_p99"] is not None


def test_continuous_burst_matches_static_schedule_outputs():
    """The engine's interleaved chunked prefill + masked ride-along decode
    is invisible to results: a burst workload emits exactly the tokens the
    static run-to-completion schedule emits."""
    from repro.launch.loadgen import poisson_stream

    def stream():
        return poisson_stream(4, rate=0.0, vocab_size=128, prompt_len=6,
                              max_new=4, seed=13)

    engine, _ = _make_engine(slots=2)
    done_c = engine.run(stream())
    sched, _ = _make_scheduler(slots=2)
    done_s = sched.run(stream())
    assert {r.rid: list(r.out) for r in done_c} \
        == {r.rid: list(r.out) for r in done_s}
    assert engine.executor.max_prefill_batch >= 2   # and it DID batch


def test_continuous_interleaved_kernels_match_reference():
    """Kernel route == reference route token-for-token under interleaved
    multi-slot prefill + decode, with route counters proving a B > 1
    batched prefill_attention kernel forward fired."""
    from repro.launch.loadgen import poisson_stream

    def run(policy):
        engine, _ = _make_engine(slots=2, dispatch=policy)
        with dispatch.stats_scope() as stats:
            engine.warmup()      # trace-time counters tick at compile
            done = engine.run(poisson_stream(
                4, rate=0.0, vocab_size=128, prompt_len=6, max_new=4,
                seed=11))
            s = stats()
        return ({r.rid: list(r.out) for r in done},
                engine.executor.max_prefill_batch, s)

    got, width_k, s_kern = run("kernels")
    want, width_r, _ = run("reference")
    assert got == want
    assert len(got) == 4
    assert width_k >= 2 and width_r >= 2
    assert s_kern.get(("prefill_attention", "kernel"), 0) > 0
    assert s_kern.get(("decode_attention", "kernel"), 0) > 0


def test_continuous_page_accounting_under_oversubscription():
    """Oversubscribed pool + mid-stream arrivals: the page-accounting
    invariant (held + free + trash == total) holds after EVERY engine
    iteration, requests queue instead of deadlocking, and every page
    returns to the free list at drain."""
    from repro.launch.loadgen import trace_stream
    # 3 pages per request (ceil((6+4)/4)); 5 usable pages -> one resident
    # reservation at a time, later arrivals must wait for recycling
    engine, _ = _make_engine(slots=2, max_len=16, total_pages=6)
    sched = engine.sched
    trace = [{"t": 0.0, "prompt_len": 6, "max_new": 4},
             {"t": 0.5, "prompt_len": 6, "max_new": 4},
             {"t": 3.0, "prompt_len": 6, "max_new": 4}]
    engine.submit(trace_stream(trace, vocab_size=128, seed=3))
    steps = 0
    while engine.step():
        sched.check_page_accounting()
        steps += 1
        assert steps < 200, "engine failed to drain"
    assert len(engine.done) == 3
    assert all(len(r.out) == 4 for r in engine.done)
    assert sched.rejected == 0
    assert sched.alloc.available() == 5
    sched.check_page_accounting()


def test_continuous_engine_rejects_and_counts():
    """An inadmissible request is counted + logged through the injected
    callback and surfaced in the metrics summary; admissible traffic
    behind it still completes."""
    from repro.launch.loadgen import trace_stream
    logs = []
    engine, _ = _make_engine(slots=2, max_len=16, log=logs.append)
    trace = [{"t": 0.0, "prompt_len": 17, "max_new": 8},  # >= max_len
             {"t": 0.0, "prompt_len": 5, "max_new": 3}]
    done = engine.run(trace_stream(trace, vocab_size=128, seed=5))
    sched = engine.sched
    assert [r.rid for r in done] == [1] and len(done[0].out) == 3
    assert sched.rejected == 1
    assert sched.rejected_requests[0].rid == 0
    assert engine.metrics.summary()["requests_rejected"] == 1
    assert any("rejecting" in m for m in logs)


def test_static_rejection_is_counted_and_logged(capsys):
    """The static schedule's rejection path routes through the injected
    log callback (no bare print) and ticks the counted ``rejected`` stat."""
    from repro.launch.serve import Request
    logs = []
    sched, _ = _make_scheduler(slots=2, max_len=16, page=4,
                               log=logs.append)
    rng = np.random.default_rng(5)
    big = Request(0, rng.integers(0, 128, 17), 8)    # prompt >= max_len
    ok = Request(1, rng.integers(0, 128, 5), 3)
    done = sched.run([big, ok])
    assert [r.rid for r in done] == [1]
    assert sched.rejected == 1
    assert sched.rejected_requests == [big]
    assert len(logs) == 1 and "rejecting request 0" in logs[0]
    assert "rejecting" not in capsys.readouterr().out


def test_paged_serve_executes_through_dispatch():
    """The acceptance probe: a paged serve (prefill + decode) with
    dispatch="kernels" takes the decode-attention kernel route, counted
    inside an isolated stats scope."""
    from repro.launch.serve import PagedScheduler, Request
    cfg = _tiny_cfg("gemma-2b", dispatch="kernels")
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    outside = dispatch.stats()
    with dispatch.stats_scope() as stats:
        sched = PagedScheduler(model, params, slots=2, max_len=16,
                               page_size=4)
        rng = np.random.default_rng(4)
        done = sched.run([Request(i, rng.integers(0, 128, 5), 3)
                          for i in range(3)])
        assert len(done) == 3
        s = stats()
    assert s.get(("decode_attention", "kernel"), 0) > 0
    assert s.get(("matmul", "kernel"), 0) > 0
    assert dispatch.stats() == outside       # scope did not leak


# ------------------------------------------------------- int8 KV serving
def test_paged_scheduler_int8_greedy_matches_fp32():
    """Quantization noise must not flip greedy decisions on the smoke
    arch: an int8-pool scheduler emits token-for-token the fp32 streams
    (same prompts, same seeds) — the end-to-end accuracy gate."""
    from repro.launch.serve import Request
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, 128, rng.integers(3, 9)) for _ in range(4)]

    def run(kv_dtype):
        sched, _ = _make_scheduler(slots=2, kv_dtype=kv_dtype)
        done = sched.run([Request(i, p, 5) for i, p in enumerate(prompts)])
        assert len(done) == 4
        return {r.rid: list(r.out) for r in done}

    assert run("int8") == run("")


def test_int8_scale_lockstep_and_byte_residency():
    """int8 pools carry per-page scale leaves whose lifecycle is slaved
    to the page allocator: check_page_accounting's lockstep invariant
    holds through a full serve, byte residency drains to zero with the
    pages (no scale leak on recycle), and reallocated pages come back
    with their scale rows reset."""
    from repro.launch.serve import Request
    sched8, _ = _make_scheduler(slots=2, kv_dtype="int8")
    sched32, _ = _make_scheduler(slots=2)
    assert sched8._page_bytes < sched32._page_bytes
    assert sched8.kv_bytes_resident() == 0

    rng = np.random.default_rng(9)
    done = sched8.run([Request(i, rng.integers(0, 128, 6), 4)
                       for i in range(3)])
    assert len(done) == 3
    sched8.check_page_accounting()          # incl. scale-lockstep check
    assert sched8.kv_bytes_resident() == 0  # all pages back, none leaked

    # retired sequences leave stale scale rows behind; the allocator's
    # on_alloc hook must wipe them before the page is reused
    stale = [leaf for leaf in jax.tree.leaves(sched8.cache)
             if leaf.ndim in (2, 3)]
    assert stale and any(float(jnp.abs(s).max()) > 0 for s in stale)
    got = sched8.alloc.alloc(sched8.alloc.available())
    for leaf in (l for l in jax.tree.leaves(sched8.cache)
                 if l.ndim in (2, 3)):
        rows = leaf[:, jnp.asarray(got)] if leaf.ndim == 3 \
            else leaf[jnp.asarray(got)]
        assert float(jnp.abs(rows).max()) == 0.0
    sched8.alloc.release(got)
    sched8.check_page_accounting()


def test_continuous_engine_tracks_kv_byte_residency():
    """The engine's max_resident_kv_bytes is the dtype-aware residency
    peak: positive under load, and strictly smaller for an int8 pool
    than for the fp32 pool on the same workload (the capacity win the
    quantized cache exists to deliver); the token streams still agree."""
    from repro.launch.loadgen import poisson_stream

    def run(kv_dtype):
        engine, _ = _make_engine(slots=2, kv_dtype=kv_dtype)
        done = engine.run(poisson_stream(
            4, rate=0.0, vocab_size=128, prompt_len=6, max_new=4, seed=13))
        assert len(done) == 4
        return engine.max_resident_kv_bytes, \
            {r.rid: list(r.out) for r in done}

    bytes8, out8 = run("int8")
    bytes32, out32 = run("")
    assert 0 < bytes8 < bytes32
    assert out8 == out32
