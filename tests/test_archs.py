"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates at a reduced same-family config and runs one train step
and one decode step on CPU, asserting shapes + finiteness.  Plus the
consistency checks the dry-run methodology relies on: scanned-vs-unrolled
equivalence and blockwise-vs-naive attention equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, input_specs, \
    shape_applicable
from repro.models.transformer import ExecOptions, Model, param_counts

RNG = jax.random.key(0)


def make_batch(cfg, b=2, s=32, seed=7):
    batch = {"labels": jax.random.randint(jax.random.key(seed), (b, s), 0,
                                          cfg.vocab_size)}
    if cfg.input_mode == "embeddings":
        batch["embeddings"] = jax.random.normal(RNG, (b, s, cfg.d_model),
                                                jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, :, None],
            (b, s, len(cfg.mrope_sections))).astype(jnp.int32)
    return batch


@pytest.mark.slow   # one full train step per arch: minutes in total
@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_train_step(name):
    cfg = ARCHS[name].smoke()
    model = Model(cfg, opts=ExecOptions(mode="run", block_q=16, block_kv=16))
    params = model.init(RNG)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), name
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_decode_step(name):
    cfg = ARCHS[name].smoke()
    model = Model(cfg, opts=ExecOptions(mode="run"))
    params = model.init(RNG)
    B = 2
    cache = model.init_cache(B, 64)
    batch = make_batch(cfg, b=B, s=1)
    batch.pop("labels")
    logits, new_cache = jax.jit(model.decode_step)(params, cache, batch,
                                                   jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size), name
    assert bool(jnp.all(jnp.isfinite(logits))), name
    assert jax.tree_util.tree_structure(cache) \
        == jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize("name", ["gemma3-4b", "recurrentgemma-9b",
                                  "deepseek-67b", "rwkv6-7b"])
def test_scan_equals_unrolled(name):
    """mem-mode (scanned) and cost-mode (python-unrolled) produce the same
    loss — the numerical backbone of the dry-run's affine cost method."""
    cfg = ARCHS[name].smoke()
    batch = make_batch(cfg)
    losses = {}
    for mode in ("mem", "cost"):
        model = Model(cfg, opts=ExecOptions(mode=mode, block_q=16,
                                            block_kv=16))
        params = model.init(RNG)
        losses[mode] = float(jax.jit(model.loss_fn)(params, batch)[0])
    assert np.isclose(losses["mem"], losses["cost"], rtol=2e-3), losses


def test_blockwise_attention_matches_naive():
    cfg = get_arch("codeqwen1.5-7b").smoke()
    batch = make_batch(cfg)
    outs = {}
    for impl in ("naive", "blockwise"):
        model = Model(cfg, opts=ExecOptions(mode="run", attn_impl=impl,
                                            block_q=16, block_kv=16,
                                            remat=False))
        params = model.init(RNG)
        outs[impl] = model.forward(params, batch)
    np.testing.assert_allclose(
        np.asarray(outs["naive"], np.float32),
        np.asarray(outs["blockwise"], np.float32), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("name", ["gemma-2b", "rwkv6-7b",
                                  "recurrentgemma-9b", "gemma3-4b"])
def test_decode_matches_forward(name):
    """Token-by-token decode with caches reproduces the teacher-forced
    forward logits — validates every cache layout (KV, rolling-window,
    rwkv state, rglru state + conv delay buffer)."""
    cfg = ARCHS[name].smoke()
    model = Model(cfg, opts=ExecOptions(mode="run", block_q=8, block_kv=8,
                                        remat=False))
    params = model.init(RNG)
    B, S = 1, 12
    batch = make_batch(cfg, b=B, s=S)
    full_logits = model.forward(params, batch)          # (B, S, V)

    cache = model.init_cache(B, max_len=32)
    step = jax.jit(model.decode_step)
    got = []
    for t in range(S):
        db = {}
        if cfg.input_mode == "embeddings":
            db["embeddings"] = batch["embeddings"][:, t:t + 1]
        else:
            db["tokens"] = batch["tokens"][:, t:t + 1]
        if cfg.mrope_sections:
            db["positions"] = batch["positions"][:, t:t + 1]
        logits, cache = step(params, cache, db, jnp.int32(t))
        got.append(logits)
    got = jnp.stack(got, axis=1)
    # bf16 logits of magnitude ~20: a couple of ulps (0.25) of
    # accumulation-order noise is expected; cache bugs produce O(1-10)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=0.1, atol=0.35)


def test_param_counts_match_published():
    expected = {
        "kimi-k2-1t-a32b": (1.03e12, 0.05),
        "deepseek-67b": (67e9, 0.02),
        "qwen2-moe-a2.7b": (14.3e9, 0.05),
        "rwkv6-7b": (7.5e9, 0.10),
        "recurrentgemma-9b": (8.6e9, 0.15),
        "gemma-2b": (2.5e9, 0.05),
        "gemma3-4b": (3.9e9, 0.10),
    }
    for name, (want, tol) in expected.items():
        got = param_counts(get_arch(name))["total"]
        assert abs(got - want) / want < tol, (name, got, want)


def test_moe_active_params():
    pc = param_counts(get_arch("kimi-k2-1t-a32b"))
    assert 30e9 < pc["n_active"] < 36e9      # "a32b"
    pc = param_counts(get_arch("qwen2-moe-a2.7b"))
    assert 2.0e9 < pc["n_active"] < 3.0e9    # "a2.7b"


def test_shape_applicability_rules():
    skips = [n for n, c in ARCHS.items()
             if not shape_applicable(c, SHAPES["long_500k"])[0]]
    assert set(skips) == {
        "qwen2-moe-a2.7b", "kimi-k2-1t-a32b", "musicgen-large", "gemma-2b",
        "deepseek-67b", "codeqwen1.5-7b", "qwen2-vl-2b"}
    for c in ARCHS.values():
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(c, SHAPES[shape])[0]


def test_input_specs_cover_all_cells():
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert all(hasattr(v, "shape") for v in specs.values())
            if shape.kind == "train":
                assert "labels" in specs
            if cfg.input_mode == "embeddings":
                assert "embeddings" in specs
