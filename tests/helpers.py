"""Shared test utilities."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run `code` in a subprocess with N virtual CPU devices (shard_map /
    mesh tests must not pollute the main process's device count)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
