"""Autotuner (repro.tune): cache round-trip through the ops wrappers,
deterministic search under a stubbed measurement harness, VMEM-budget
pruning of every enumerated candidate, and the nearest-shape lookup the
dispatch layer relies on."""
import json
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.plan import Level, TransformConfig, enumerate_configs
from repro.core.scaling import TilePlanner
from repro.tune import (DEFAULT_SHAPES, Harness, PlanCache, SPACES,
                        lookup_stats, make_key, plan_feasible,
                        reset_lookup_stats, tune)
from repro.tune.cache import resolve_plan, shape_distance
from repro.tune.measure import Measurement


class StubHarness(Harness):
    """Deterministic 'measurements': cost is a pure function of the plan
    dict, so the sweep's choice depends only on the search itself."""

    def __init__(self, cost_fn):
        super().__init__(reps=1, warmup=0)
        self.cost_fn = cost_fn
        self.measured = []

    def measure(self, fn):
        plan = fn.args[1]          # functools.partial(spec.call, args, plan)
        self.measured.append(plan)
        return Measurement(us=float(self.cost_fn(plan)), reps=1)


def _prefers_small_blocks(plan):
    """Fake cost model: smaller block products are faster, T1 is slow."""
    if plan.get("level") == int(Level.T1_PIPELINED):
        return 1e12
    prod = 1
    for k, v in plan.items():
        if k not in ("level", "prefetch_depth"):
            prod *= v
    return float(prod)


# ------------------------------------------------------------------ pruning
@pytest.mark.parametrize("vmem_fraction", [0.02, 0.1, 0.75])
@pytest.mark.parametrize("shape", [(512, 512, 512), (2048, 1024, 4096)])
def test_enumerate_matmul_never_exceeds_budget(vmem_fraction, shape):
    m, k, n = shape
    planner = TilePlanner(vmem_fraction=vmem_fraction)
    plans = planner.enumerate_matmul(m, n, k, in_bytes=2)
    for p in plans:
        assert p.vmem_bytes <= planner.budget
        assert m % min(p.bm, m) == 0
        assert n % min(p.bn, n) == 0
        assert k % min(p.bk, k) == 0
    if plans:   # best-first: heuristic == plans[0]
        assert planner.plan_matmul(m, n, k, in_bytes=2) == plans[0]


def test_plan_from_tiles_rejects_infeasible():
    planner = TilePlanner(vmem_fraction=0.001)
    with pytest.raises(ValueError):
        planner.plan_from_tiles(4096, 4096, 4096, 2048, 2048, 2048)


@pytest.mark.parametrize("kernel", sorted(SPACES))
def test_spaces_emit_only_feasible_plans(kernel):
    budget = TilePlanner().budget
    for shape in DEFAULT_SHAPES[kernel]:
        dtype_bytes = 2 if kernel == "attention" else 4
        cands = SPACES[kernel](shape, dtype_bytes)
        assert cands, (kernel, shape)
        # candidate 0 is the heuristic; every T3 candidate fits VMEM
        for c in cands:
            if c.get("level") != int(Level.T3_REPLICATED):
                continue
            if kernel == "matmul":
                m, k, n = shape
                planner = TilePlanner(
                    double_buffer=c.get("prefetch_depth", 2) >= 2)
                plan = planner.plan_from_tiles(
                    m, n, k, c["bm"], c["bn"], c["bk"],
                    in_bytes=dtype_bytes)    # raises if over budget
                assert plan.vmem_bytes <= budget
            elif kernel == "stencil":
                rows, _ = shape
                assert rows % c["block_rows"] == 0


def test_enumerate_configs_sweeps_levels_and_knobs():
    cfgs = list(enumerate_configs(
        TransformConfig(), vector_widths=(128, 256),
        prefetch_depths=(1, 2)))
    assert len(cfgs) == 3 * 2 * 2     # levels x vector_widths x prefetch
    assert {c.level for c in cfgs} == {Level.T1_PIPELINED,
                                       Level.T2_VECTORIZED,
                                       Level.T3_REPLICATED}
    # None = keep base value
    base = TransformConfig(accum_lanes=5)
    assert all(c.accum_lanes == 5 for c in enumerate_configs(base))


# ------------------------------------------------------------- determinism
def test_tune_is_deterministic_under_stubbed_measurement():
    results = []
    for _ in range(2):
        h = StubHarness(_prefers_small_blocks)
        res = tune("matmul", (256, 256, 256), harness=h)
        results.append((res.best, res.best_us,
                        [tuple(sorted(p.items())) for p in h.measured]))
    assert results[0] == results[1]
    best = results[0][0]
    assert best["level"] == int(Level.T3_REPLICATED)
    # the winner is exactly the fake-cost argmin over the candidate space
    # (first occurrence on ties — the sweep must be order-stable)
    expected = min(SPACES["matmul"]((256, 256, 256), 4),
                   key=_prefers_small_blocks)
    assert best == expected


def test_tuned_never_loses_to_heuristic_in_sweep():
    """The heuristic is candidate 0, so the winner can only match or beat
    it — even when the fake cost model makes the heuristic optimal."""
    h = StubHarness(lambda plan: 1.0 if "bm" in plan else 2.0)
    res = tune("matmul", (256, 256, 256), harness=h)
    assert res.best_us <= res.heuristic_us


# ------------------------------------------------------------- round-trip
def test_cache_roundtrip_and_ops_pickup(tmp_path, monkeypatch):
    cache_path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache_path))

    shape = (256, 256, 256)
    h = StubHarness(_prefers_small_blocks)
    cache = PlanCache(cache_path)
    res = tune("matmul", shape, cache=cache, harness=h)
    cache.save()

    # file format: versioned, keyed entries with plan + stats
    data = json.loads(cache_path.read_text())
    key = make_key("matmul", shape, jnp.float32, res.backend)
    assert data["version"] == 1
    assert data["entries"][key]["plan"] == res.best
    assert data["entries"][key]["heuristic_us"] >= data["entries"][key]["us"]

    # reload from disk -> resolve_plan hands the ops wrapper the cached plan
    reloaded = PlanCache(cache_path).load()
    assert reloaded.get("matmul", shape, jnp.float32) is not None
    level, kw = resolve_plan("matmul", shape, jnp.float32,
                             Level.T3_REPLICATED, "tuned")
    assert level == Level.T3_REPLICATED
    assert {"bm": kw["bm"], "bn": kw["bn"], "bk": kw["bk"],
            "prefetch_depth": kw["prefetch_depth"],
            "level": int(level)} == res.best

    # and the kernel actually runs with it, numerically correct
    a = jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    from repro.kernels.matmul import matmul
    got = matmul(a, b, plan="tuned")
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


def test_tuned_miss_falls_back_to_heuristic(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "empty.json"))
    level, kw = resolve_plan("matmul", (64, 64, 64), jnp.float32,
                             Level.T3_REPLICATED, "tuned")
    assert level == Level.T3_REPLICATED and kw is None
    # unknown plan strings are an error, not a silent fallback
    with pytest.raises(ValueError):
        resolve_plan("matmul", (64, 64, 64), jnp.float32,
                     Level.T3_REPLICATED, "bogus")


def test_tuned_plan_level_overrides_caller(tmp_path, monkeypatch):
    cache_path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache_path))
    cache = PlanCache(cache_path)
    cache.put("stencil", (128, 256), jnp.float32,
              {"level": int(Level.T1_PIPELINED)}, us=1.0)
    cache.save()
    level, kw = resolve_plan("stencil", (128, 256), jnp.float32,
                             Level.T3_REPLICATED, "tuned")
    assert level == Level.T1_PIPELINED and kw == {}

    # end to end: jacobi4 with the tuned (T1) plan matches the reference
    x = jax.random.normal(jax.random.key(0), (128, 256), jnp.float32)
    from repro.kernels.stencil import jacobi4
    np.testing.assert_allclose(jacobi4(x, plan="tuned"),
                               jacobi4(x, level=Level.T1_PIPELINED),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------- nearest-shape
def _cache_with(entries):
    cache = PlanCache("/tmp/unused-nearest-cache.json")
    for (kernel, shape, plan) in entries:
        cache.put(kernel, shape, jnp.float32, plan, backend="cpu", us=1.0)
    return cache


_T3 = int(Level.T3_REPLICATED)


def test_nearest_exact_hit_beats_nearest(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    exact_plan = {"level": _T3, "bm": 128, "bn": 128, "bk": 128,
                  "prefetch_depth": 2}
    near_plan = {"level": _T3, "bm": 256, "bn": 256, "bk": 256,
                 "prefetch_depth": 2}
    cache = PlanCache(tmp_path / "plans.json")
    cache.put("matmul", (256, 256, 256), jnp.float32, exact_plan, us=1.0)
    cache.put("matmul", (512, 512, 512), jnp.float32, near_plan, us=1.0)
    cache.save()
    reset_lookup_stats()
    _, kw = resolve_plan("matmul", (256, 256, 256), jnp.float32,
                         Level.T3_REPLICATED, "tuned")
    assert {k: kw[k] for k in ("bm", "bn", "bk")} == \
        {"bm": 128, "bn": 128, "bk": 128}
    assert lookup_stats()["exact"] == 1 and lookup_stats()["nearest"] == 0
    # and a miss on a third shape picks the geometrically closest entry
    _, kw = resolve_plan("matmul", (512, 512, 1024), jnp.float32,
                         Level.T3_REPLICATED, "tuned")
    assert {k: kw[k] for k in ("bm", "bn", "bk")} == \
        {"bm": 256, "bn": 256, "bk": 256}       # 512 entry is closer
    assert lookup_stats()["nearest"] == 1


def test_nearest_skips_infeasible_plans():
    """The distance-closest entry whose plan cannot run at the query shape
    (ragged tiles / VMEM blowout) is skipped for a farther feasible one."""
    cache = _cache_with([
        # closest by distance, but bm=384 does not divide m=512
        ("matmul", (640, 512, 512),
         {"level": _T3, "bm": 384, "bn": 128, "bk": 128}),
        # farther, feasible
        ("matmul", (2048, 2048, 2048),
         {"level": _T3, "bm": 256, "bn": 256, "bk": 256,
          "prefetch_depth": 2}),
    ])
    entry = cache.get_nearest("matmul", (512, 512, 512), jnp.float32,
                              backend="cpu")
    assert entry is not None and entry["plan"]["bm"] == 256
    assert not plan_feasible("matmul", (512, 512, 512),
                             {"level": _T3, "bm": 384, "bn": 128,
                              "bk": 128}, dtype_bytes=4)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([128, 192, 256, 384, 512, 768, 1024]),
       st.sampled_from([128, 256, 512]),
       st.sampled_from([128, 256, 512, 2048]))
def test_nearest_never_returns_infeasible(m, k, n):
    """Property: whatever get_nearest returns is VMEM-feasible for the
    query shape per the TilePlanner working-set arithmetic."""
    cache = _cache_with([
        ("matmul", (256, 256, 256),
         {"level": _T3, "bm": 256, "bn": 256, "bk": 128}),
        ("matmul", (512, 512, 512),
         {"level": _T3, "bm": 384, "bn": 384, "bk": 384}),   # often ragged
        ("matmul", (4096, 4096, 4096),
         {"level": _T3, "bm": 2048, "bn": 2048, "bk": 2048}),  # VMEM blowout
        ("matmul", (1024, 1024, 1024), {"level": int(Level.T1_PIPELINED)}),
    ])
    entry = cache.get_nearest("matmul", (m, k, n), jnp.float32,
                              backend="cpu")
    assert entry is not None    # the T1 entry is always feasible
    assert plan_feasible("matmul", (m, k, n), entry["plan"], dtype_bytes=4)


def test_nearest_empty_cache_falls_back_to_heuristic(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "none.json"))
    reset_lookup_stats()
    level, kw = resolve_plan("matmul", (96, 96, 96), jnp.float32,
                             Level.T3_REPLICATED, "tuned")
    assert level == Level.T3_REPLICATED and kw is None
    assert lookup_stats() == {"exact": 0, "nearest": 0, "miss": 1}


def test_nearest_deterministic_under_dict_order_shuffles():
    entries = [
        ("matmul", (256, 256, 256),
         {"level": _T3, "bm": 128, "bn": 128, "bk": 128,
          "prefetch_depth": 2}),
        ("matmul", (256, 256, 512),
         {"level": _T3, "bm": 128, "bn": 128, "bk": 128,
          "prefetch_depth": 1}),
        ("matmul", (512, 256, 256),
         {"level": _T3, "bm": 128, "bn": 128, "bk": 128,
          "prefetch_depth": 2}),
        ("matmul", (512, 512, 512),
         {"level": _T3, "bm": 256, "bn": 256, "bk": 256,
          "prefetch_depth": 2}),
    ]
    # (384,256,384) is exactly equidistant from (256,256,512) and
    # (512,256,256) (distinct plans): the sorted-key tie-break must pick
    # the same entry for any insertion order
    queries = [(384, 256, 384), (768, 256, 768), (512, 384, 512)]
    results = []
    rng = random.Random(0)
    for _ in range(6):
        shuffled = entries[:]
        rng.shuffle(shuffled)
        cache = _cache_with(shuffled)
        results.append([cache.get_nearest("matmul", q, jnp.float32,
                                          backend="cpu")["plan"]
                        for q in queries])
    assert all(r == results[0] for r in results)


def test_nearest_plan_reaches_the_kernel(tmp_path, monkeypatch):
    """End to end: a plan tuned at (256,256,256) is transplanted (clamped)
    onto a (128,128) matmul via the nearest-shape fallback and produces
    correct numerics."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    cache = PlanCache(tmp_path / "plans.json")
    cache.put("matmul", (256, 256, 256), jnp.float32,
              {"level": _T3, "bm": 256, "bn": 256, "bk": 128,
               "prefetch_depth": 2}, us=1.0)
    cache.save()
    reset_lookup_stats()
    a = jax.random.normal(jax.random.key(0), (128, 128), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (128, 128), jnp.float32)
    from repro.kernels.matmul import matmul
    got = matmul(a, b, plan="tuned")
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)
    assert lookup_stats()["nearest"] == 1


def test_shape_distance_is_geometric():
    assert shape_distance((256, 256, 256), (256, 256, 256)) == 0.0
    assert shape_distance((256, 256, 256), (512, 512, 512)) < \
        shape_distance((256, 256, 256), (256, 256, 4096))


def test_real_measurement_smoke():
    """One real (tiny) sweep through the wall-clock harness: sane output,
    winner cached, all candidates measured."""
    cache = PlanCache("/tmp/unused-tune-cache.json")
    res = tune("stencil", (128, 256), cache=cache,
               harness=Harness(reps=1, warmup=1))
    assert res.best_us > 0 and np.isfinite(res.best_us)
    assert res.best_us <= res.heuristic_us
    assert len(res.rows) >= 2
    assert cache.get("stencil", (128, 256), jnp.float32) is not None
