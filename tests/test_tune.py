"""Autotuner (repro.tune): cache round-trip through the ops wrappers,
deterministic search under a stubbed measurement harness, and VMEM-budget
pruning of every enumerated candidate."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import Level, TransformConfig, enumerate_configs
from repro.core.scaling import TilePlanner
from repro.tune import (DEFAULT_SHAPES, Harness, PlanCache, SPACES,
                        make_key, tune)
from repro.tune.cache import resolve_plan
from repro.tune.measure import Measurement


class StubHarness(Harness):
    """Deterministic 'measurements': cost is a pure function of the plan
    dict, so the sweep's choice depends only on the search itself."""

    def __init__(self, cost_fn):
        super().__init__(reps=1, warmup=0)
        self.cost_fn = cost_fn
        self.measured = []

    def measure(self, fn):
        plan = fn.args[1]          # functools.partial(spec.call, args, plan)
        self.measured.append(plan)
        return Measurement(us=float(self.cost_fn(plan)), reps=1)


def _prefers_small_blocks(plan):
    """Fake cost model: smaller block products are faster, T1 is slow."""
    if plan.get("level") == int(Level.T1_PIPELINED):
        return 1e12
    prod = 1
    for k, v in plan.items():
        if k not in ("level", "prefetch_depth"):
            prod *= v
    return float(prod)


# ------------------------------------------------------------------ pruning
@pytest.mark.parametrize("vmem_fraction", [0.02, 0.1, 0.75])
@pytest.mark.parametrize("shape", [(512, 512, 512), (2048, 1024, 4096)])
def test_enumerate_matmul_never_exceeds_budget(vmem_fraction, shape):
    m, k, n = shape
    planner = TilePlanner(vmem_fraction=vmem_fraction)
    plans = planner.enumerate_matmul(m, n, k, in_bytes=2)
    for p in plans:
        assert p.vmem_bytes <= planner.budget
        assert m % min(p.bm, m) == 0
        assert n % min(p.bn, n) == 0
        assert k % min(p.bk, k) == 0
    if plans:   # best-first: heuristic == plans[0]
        assert planner.plan_matmul(m, n, k, in_bytes=2) == plans[0]


def test_plan_from_tiles_rejects_infeasible():
    planner = TilePlanner(vmem_fraction=0.001)
    with pytest.raises(ValueError):
        planner.plan_from_tiles(4096, 4096, 4096, 2048, 2048, 2048)


@pytest.mark.parametrize("kernel", sorted(SPACES))
def test_spaces_emit_only_feasible_plans(kernel):
    budget = TilePlanner().budget
    for shape in DEFAULT_SHAPES[kernel]:
        dtype_bytes = 2 if kernel == "attention" else 4
        cands = SPACES[kernel](shape, dtype_bytes)
        assert cands, (kernel, shape)
        # candidate 0 is the heuristic; every T3 candidate fits VMEM
        for c in cands:
            if c.get("level") != int(Level.T3_REPLICATED):
                continue
            if kernel == "matmul":
                m, k, n = shape
                planner = TilePlanner(
                    double_buffer=c.get("prefetch_depth", 2) >= 2)
                plan = planner.plan_from_tiles(
                    m, n, k, c["bm"], c["bn"], c["bk"],
                    in_bytes=dtype_bytes)    # raises if over budget
                assert plan.vmem_bytes <= budget
            elif kernel == "stencil":
                rows, _ = shape
                assert rows % c["block_rows"] == 0


def test_enumerate_configs_sweeps_levels_and_knobs():
    cfgs = list(enumerate_configs(
        TransformConfig(), vector_widths=(128, 256),
        prefetch_depths=(1, 2)))
    assert len(cfgs) == 3 * 2 * 2     # levels x vector_widths x prefetch
    assert {c.level for c in cfgs} == {Level.T1_PIPELINED,
                                       Level.T2_VECTORIZED,
                                       Level.T3_REPLICATED}
    # None = keep base value
    base = TransformConfig(accum_lanes=5)
    assert all(c.accum_lanes == 5 for c in enumerate_configs(base))


# ------------------------------------------------------------- determinism
def test_tune_is_deterministic_under_stubbed_measurement():
    results = []
    for _ in range(2):
        h = StubHarness(_prefers_small_blocks)
        res = tune("matmul", (256, 256, 256), harness=h)
        results.append((res.best, res.best_us,
                        [tuple(sorted(p.items())) for p in h.measured]))
    assert results[0] == results[1]
    best = results[0][0]
    assert best["level"] == int(Level.T3_REPLICATED)
    # the winner is exactly the fake-cost argmin over the candidate space
    # (first occurrence on ties — the sweep must be order-stable)
    expected = min(SPACES["matmul"]((256, 256, 256), 4),
                   key=_prefers_small_blocks)
    assert best == expected


def test_tuned_never_loses_to_heuristic_in_sweep():
    """The heuristic is candidate 0, so the winner can only match or beat
    it — even when the fake cost model makes the heuristic optimal."""
    h = StubHarness(lambda plan: 1.0 if "bm" in plan else 2.0)
    res = tune("matmul", (256, 256, 256), harness=h)
    assert res.best_us <= res.heuristic_us


# ------------------------------------------------------------- round-trip
def test_cache_roundtrip_and_ops_pickup(tmp_path, monkeypatch):
    cache_path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache_path))

    shape = (256, 256, 256)
    h = StubHarness(_prefers_small_blocks)
    cache = PlanCache(cache_path)
    res = tune("matmul", shape, cache=cache, harness=h)
    cache.save()

    # file format: versioned, keyed entries with plan + stats
    data = json.loads(cache_path.read_text())
    key = make_key("matmul", shape, jnp.float32, res.backend)
    assert data["version"] == 1
    assert data["entries"][key]["plan"] == res.best
    assert data["entries"][key]["heuristic_us"] >= data["entries"][key]["us"]

    # reload from disk -> resolve_plan hands the ops wrapper the cached plan
    reloaded = PlanCache(cache_path).load()
    assert reloaded.get("matmul", shape, jnp.float32) is not None
    level, kw = resolve_plan("matmul", shape, jnp.float32,
                             Level.T3_REPLICATED, "tuned")
    assert level == Level.T3_REPLICATED
    assert {"bm": kw["bm"], "bn": kw["bn"], "bk": kw["bk"],
            "prefetch_depth": kw["prefetch_depth"],
            "level": int(level)} == res.best

    # and the kernel actually runs with it, numerically correct
    a = jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    from repro.kernels.matmul import matmul
    got = matmul(a, b, plan="tuned")
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


def test_tuned_miss_falls_back_to_heuristic(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "empty.json"))
    level, kw = resolve_plan("matmul", (64, 64, 64), jnp.float32,
                             Level.T3_REPLICATED, "tuned")
    assert level == Level.T3_REPLICATED and kw is None
    # unknown plan strings are an error, not a silent fallback
    with pytest.raises(ValueError):
        resolve_plan("matmul", (64, 64, 64), jnp.float32,
                     Level.T3_REPLICATED, "bogus")


def test_tuned_plan_level_overrides_caller(tmp_path, monkeypatch):
    cache_path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache_path))
    cache = PlanCache(cache_path)
    cache.put("stencil", (128, 256), jnp.float32,
              {"level": int(Level.T1_PIPELINED)}, us=1.0)
    cache.save()
    level, kw = resolve_plan("stencil", (128, 256), jnp.float32,
                             Level.T3_REPLICATED, "tuned")
    assert level == Level.T1_PIPELINED and kw == {}

    # end to end: jacobi4 with the tuned (T1) plan matches the reference
    x = jax.random.normal(jax.random.key(0), (128, 256), jnp.float32)
    from repro.kernels.stencil import jacobi4
    np.testing.assert_allclose(jacobi4(x, plan="tuned"),
                               jacobi4(x, level=Level.T1_PIPELINED),
                               rtol=1e-6, atol=1e-6)


def test_real_measurement_smoke():
    """One real (tiny) sweep through the wall-clock harness: sane output,
    winner cached, all candidates measured."""
    cache = PlanCache("/tmp/unused-tune-cache.json")
    res = tune("stencil", (128, 256), cache=cache,
               harness=Harness(reps=1, warmup=1))
    assert res.best_us > 0 and np.isfinite(res.best_us)
    assert res.best_us <= res.heuristic_us
    assert len(res.rows) >= 2
    assert cache.get("stencil", (128, 256), jnp.float32) is not None
