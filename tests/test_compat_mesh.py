"""Feature-detection branches of the JAX-version compat helpers.

``runtime/compat.shard_map`` and ``launch/mesh.make_mesh`` are the two
mandated choke points for shard_map / mesh construction (lint-enforced).
Their version branches were previously only exercised implicitly by
whichever JAX the container pins; these tests drive BOTH sides of each
feature detection directly on degenerate 1-device meshes, so an upgrade
that flips a branch fails here instead of deep inside serving.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as mesh_mod
from repro.runtime import compat


# ------------------------------------------------------------- shard_map

def test_shard_map_1device_runs():
    """Whichever branch the installed JAX selects, a degenerate 1-device
    mapped identity round-trips values exactly."""
    m = mesh_mod.make_mesh((1,), ("model",))
    x = jnp.arange(12.0).reshape(3, 4)
    out = compat.shard_map(lambda t: t * 2.0, mesh=m, in_specs=(P(),),
                           out_specs=P())(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) * 2.0)


def test_shard_map_check_vma_kwarg_both_values():
    """check_vma must be accepted on both branches (mapped to check_rep on
    0.4.x); False is what sharded serving uses for collective outputs."""
    m = mesh_mod.make_mesh((1,), ("model",))
    x = jnp.ones((2, 2))
    for flag in (True, False):
        out = compat.shard_map(lambda t: t + 1.0, mesh=m, in_specs=(P(),),
                               out_specs=P(), check_vma=flag)(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x) + 1.0)


def test_shard_map_toplevel_branch(monkeypatch):
    """When jax.shard_map exists, compat must use it and pass check_vma."""
    seen = {}

    def fake(f, *, mesh, in_specs, out_specs, check_vma):
        seen.update(mesh=mesh, check_vma=check_vma)
        return f

    monkeypatch.setattr(jax, "shard_map", fake, raising=False)
    m = mesh_mod.make_mesh((1,), ("model",))
    f = compat.shard_map(lambda t: t, mesh=m, in_specs=(P(),),
                         out_specs=P(), check_vma=False)
    assert seen == {"mesh": m, "check_vma": False}
    assert f(3) == 3


def test_shard_map_experimental_branch(monkeypatch):
    """Without jax.shard_map, compat falls back to the experimental API
    (check_vma renamed check_rep) — the live branch on the pinned 0.4.x."""
    monkeypatch.delattr(jax, "shard_map", raising=False)
    m = mesh_mod.make_mesh((1,), ("model",))
    x = jnp.arange(4.0)
    out = compat.shard_map(lambda t: t * 3.0, mesh=m, in_specs=(P(),),
                          out_specs=P(), check_vma=False)(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) * 3.0)


# ------------------------------------------------------------- make_mesh

def test_make_mesh_1device():
    m = mesh_mod.make_mesh((1,), ("model",))
    assert m.shape == {"model": 1}
    assert m.axis_names == ("model",)


def test_make_mesh_axis_types_branch(monkeypatch):
    """Force the axis_types-supported branch and check the kwarg flows."""
    seen = {}

    def fake_make_mesh(shape, axes, **kwargs):
        seen.update(shape=shape, axes=axes, kwargs=kwargs)
        return "mesh-sentinel"

    monkeypatch.setattr(mesh_mod, "_axis_types_supported", lambda: True)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    if not hasattr(jax.sharding, "AxisType"):
        # pinned 0.4.x has no AxisType: fake one so the forced branch can
        # build its tuple (newer JAX exercises the real enum)
        class _FakeAxisType:
            Auto = "auto"
        monkeypatch.setattr(jax.sharding, "AxisType", _FakeAxisType,
                            raising=False)
    assert mesh_mod.make_mesh((1,), ("model",)) == "mesh-sentinel"
    assert seen["shape"] == (1,) and seen["axes"] == ("model",)
    assert "axis_types" in seen["kwargs"]
    assert len(seen["kwargs"]["axis_types"]) == 1


def test_make_mesh_no_axis_types_branch(monkeypatch):
    """Force the legacy branch: the kwarg must be omitted entirely."""
    seen = {}

    def fake_make_mesh(shape, axes, **kwargs):
        seen.update(kwargs=kwargs)
        return "mesh-sentinel"

    monkeypatch.setattr(mesh_mod, "_axis_types_supported", lambda: False)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    assert mesh_mod.make_mesh((1,), ("model",)) == "mesh-sentinel"
    assert "axis_types" not in seen["kwargs"]


def test_axis_types_detection_is_bool():
    # the real detection must run (lru_cached) and return a plain bool —
    # never a version-string comparison artifact
    assert isinstance(mesh_mod._axis_types_supported(), bool)


# ------------------------------------------------------ make_serving_mesh

def test_make_serving_mesh_degenerate():
    m = mesh_mod.make_serving_mesh(1)
    assert m.shape == {"model": 1}


def test_make_serving_mesh_bounds():
    with pytest.raises(ValueError, match=">= 1"):
        mesh_mod.make_serving_mesh(0)
    with pytest.raises(ValueError, match="exceeds visible devices"):
        mesh_mod.make_serving_mesh(len(jax.devices()) + 1)
