"""Differential + routing tests for the fused flash-attention backward.

Three layers of evidence, mirroring the dispatch-differential discipline:

1. kernel-level — ``flash_attention_bwd`` (fused recompute Pallas kernels,
   interpret mode) against the dense reference VJP on fixed seeds, over
   {fp32, bf16} x causal/sliding-window, plus the lse residual itself;
2. model-level — gradients of ``layers.attention_blockwise`` through
   ``dispatch`` with policy "kernels" vs "reference" for every assigned
   arch's own attention geometry (GQA/MQA, window, qkv bias, M-RoPE);
3. route-level — a real train step with ``dispatch="kernels"`` inside a
   ``forbid_dense_scores()`` scope: the counters prove the fused backward
   fired and the tripwire proves no dense (S, S) lowering could have.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.core.memory import DtypePolicy
from repro.kernels import dispatch
from repro.kernels.attention import flash_attention, flash_attention_bwd
from repro.kernels.attention import ref
from repro.models import layers
from repro.models.transformer import ExecOptions, Model, _attn_spec

KEY = jax.random.key(0)
B, S = 2, 8

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
}
TOLS = {
    "float32": dict(rtol=5e-4, atol=5e-4),
    "bfloat16": dict(rtol=8e-2, atol=8e-2),
}
MASKS = {"causal": (True, 0), "window": (True, 12), "full": (False, 0)}


def _assert_close(got, want, dtype_name, msg=""):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               err_msg=msg, **TOLS[dtype_name])


def _fused_plan(s):
    return {"level": 3, "block_q": min(16, s), "block_kv": min(32, s)}


# ------------------------------------------------------------ kernel level
@pytest.mark.parametrize("mask_name", sorted(MASKS))
@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
def test_fused_backward_matches_reference_vjp(dtype_name, mask_name):
    causal, window = MASKS[mask_name]
    dtype = DTYPES[dtype_name]
    b, h, s, hd = 2, 3, 64, 16
    ks = jax.random.split(KEY, 4)
    q, k, v = (jax.random.normal(kk, (b, h, s, hd), dtype) for kk in ks[:3])
    do = jax.random.normal(ks[3], (b, h, s, hd), jnp.float32)
    o, lse = flash_attention(q, k, v, causal=causal, window=window,
                             plan=_fused_plan(s), return_residuals=True)
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                     window=window, plan=_fused_plan(s))
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                             window=window), q, k, v)
    want = vjp(do)
    for got, ref_g, name in zip((dq, dk, dv), want, ("dq", "dk", "dv")):
        assert got.dtype == ref_g.dtype
        _assert_close(got, ref_g, dtype_name, f"{name} {mask_name}")


@pytest.mark.parametrize("mask_name", sorted(MASKS))
def test_forward_lse_residual_matches_reference(mask_name):
    causal, window = MASKS[mask_name]
    b, h, s, hd = 1, 2, 32, 16
    ks = jax.random.split(jax.random.key(7), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, hd), jnp.float32)
               for kk in ks)
    o, lse = flash_attention(q, k, v, causal=causal, window=window,
                             plan=_fused_plan(s), return_residuals=True)
    o_only = flash_attention(q, k, v, causal=causal, window=window,
                             plan=_fused_plan(s))
    _assert_close(o, o_only, "float32")       # residuals don't perturb o
    want = ref.attention_lse_ref(q, k, causal=causal, window=window)
    _assert_close(lse, want, "float32")


def test_backward_reference_level_matches_vjp_exactly():
    """plan level T1 (the stash schedule) IS the dense reference VJP."""
    b, h, s, hd = 1, 2, 16, 8
    ks = jax.random.split(jax.random.key(3), 4)
    q, k, v = (jax.random.normal(kk, (b, h, s, hd), jnp.float32)
               for kk in ks[:3])
    do = jax.random.normal(ks[3], (b, h, s, hd), jnp.float32)
    o, lse = flash_attention(q, k, v, plan=_fused_plan(s),
                             return_residuals=True)
    got = flash_attention_bwd(q, k, v, o, lse, do, plan={"level": 1})
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_), q, k, v)
    for g, w in zip(got, vjp(do)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ------------------------------------------------------------- model level
def _positions(cfg):
    if cfg.mrope_sections:
        return jnp.broadcast_to(
            jnp.arange(S)[None, :, None],
            (B, S, len(cfg.mrope_sections))).astype(jnp.int32)
    return jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)


@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_attention_grad_differential(arch, dtype_name):
    """d(loss)/d(params, x) of the arch's attention block agrees between
    the fused-kernel route and the reference route — the gradient twin of
    test_attention_differential, covering GQA grouping (the KV-head
    broadcast VJP reduces dK/dV over query-head groups) and windows."""
    cfg = ARCHS[arch].smoke()
    mixers = {m for m, _ in cfg.layer_kinds()}
    if not ({"attn", "swa"} & mixers):
        pytest.skip("attention-free arch")
    mixer = "swa" if "swa" in mixers else "attn"
    dt = DtypePolicy(compute=DTYPES[dtype_name])
    spec_k = _attn_spec(dataclasses.replace(cfg, dispatch="kernels"), mixer)
    spec_r = _attn_spec(dataclasses.replace(cfg, dispatch="reference"),
                        mixer)
    p = layers.attention_init(KEY, spec_r)
    x = (0.2 * jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                                 jnp.float32)).astype(dt.compute)
    pos = _positions(cfg)
    cot = jax.random.normal(jax.random.key(2), (B, S, cfg.d_model),
                            jnp.float32)

    def make_loss(spec):
        def loss(p_, x_):
            out = layers.attention_blockwise(p_, spec, x_, pos, dt)
            return jnp.sum(out.astype(jnp.float32) * cot)
        return loss

    with dispatch.stats_scope() as stats_fn:
        gk = jax.grad(make_loss(spec_k), argnums=(0, 1))(p, x)
        stats = stats_fn()
    assert stats.get(("attention_bwd", "kernel"), 0) == 1, stats
    assert stats.get(("attention_bwd", "reference"), 0) == 0
    gr = jax.grad(make_loss(spec_r), argnums=(0, 1))(p, x)
    jax.tree.map(
        lambda got, want: _assert_close(got, want, dtype_name,
                                        f"{arch} grads"), gk, gr)


# ------------------------------------------------------------- route level
def _tiny_cfg(name="gemma-2b", **overrides):
    cfg = ARCHS[name].smoke()
    return dataclasses.replace(
        cfg, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
        vocab_size=128, **overrides)


def test_train_step_takes_fused_backward_route():
    """A dispatch="kernels" train step routes the attention backward
    through the fused Pallas kernels — and, under forbid_dense_scores(),
    provably never materializes an (S, S) score tensor on that route."""
    from repro.optim.adamw import AdamWConfig
    from repro.train.steps import (TrainStepConfig, init_train_state,
                                   make_train_step)

    cfg = _tiny_cfg(dispatch="kernels")
    model = Model(cfg, dt=DtypePolicy(),
                  opts=ExecOptions(mode="run", block_q=8, block_kv=8,
                                   xent_chunks=2))
    ts = TrainStepConfig(opt=AdamWConfig(lr=1e-3))
    step = make_train_step(model, ts)
    params, opt = init_train_state(model, ts, jax.random.key(0))
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    with dispatch.stats_scope() as stats_fn, dispatch.forbid_dense_scores():
        _, _, metrics = jax.jit(step)(params, opt, batch)
        stats = stats_fn()
    assert np.isfinite(float(metrics["loss"]))
    assert stats.get(("attention", "kernel"), 0) > 0
    assert stats.get(("attention_bwd", "kernel"), 0) > 0
    assert stats.get(("attention_bwd", "reference"), 0) == 0


def test_forbid_dense_scores_trips_on_dense_lowerings():
    b, s, h, hd = 1, 8, 2, 8
    ks = jax.random.split(jax.random.key(5), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, hd), jnp.float32)
               for kk in ks)
    with dispatch.forbid_dense_scores():
        # blockwise reference and the fused kernel route both trace clean
        dispatch.attention(q, k, v, policy="reference")
        jax.grad(lambda q_: jnp.sum(
            dispatch.attention(q_, k, v, policy="kernels")))(q)
        with pytest.raises(AssertionError, match="dense"):
            dispatch.attention(q, k, v, impl="naive", policy="reference")
    # outside the scope the naive lowering is allowed again
    dispatch.attention(q, k, v, impl="naive", policy="reference")


def test_tuned_reference_plan_respected_under_auto(tmp_path, monkeypatch):
    """A tuned flash_attention_bwd entry that says "the dense VJP wins at
    this shape" (level 1) is honored on the backward route — unless the
    policy is an explicit "kernels", which forces the fused kernels."""
    from repro.tune import cache as tune_cache

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    cache = tune_cache.PlanCache(tmp_path / "plans.json")
    b, s, h, hd = 1, 16, 2, 8
    cache.put("flash_attention_bwd", (b, h, s, hd), jnp.float32,
              {"level": 1}, us=1.0)
    cache.save()
    tune_cache.preload()
    try:
        ks = jax.random.split(jax.random.key(6), 3)
        q, k, v = (jax.random.normal(kk, (b, s, h, hd), jnp.float32)
                   for kk in ks)

        def loss(q_):
            return jnp.sum(dispatch.attention(q_, k, v, policy="kernels"))

        with dispatch.stats_scope() as stats_fn:
            jax.grad(loss)(q)
            assert stats_fn().get(("attention_bwd", "kernel"), 0) == 1
        # force the auto decision path: module default "kernels" would
        # force fused, so emulate a TPU-style auto route via policy_scope
        monkeypatch.setattr(dispatch, "_kernels_by_default", lambda: True)
        with dispatch.stats_scope() as stats_fn:
            jax.grad(lambda q_: jnp.sum(
                dispatch.attention(q_, k, v, policy="auto")))(q)
            assert stats_fn().get(("attention_bwd", "reference"), 0) == 1
    finally:
        monkeypatch.delenv("REPRO_TUNE_CACHE")
        tune_cache.preload()
