"""Unit + property tests for repro.core — the transformation toolbox."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    TABLE1, TABLE2, Level, Objective, PipelineModel, Roofline, TilePlanner,
    TransformClass, TPU_V5E, by_class, cross_input_interleave,
    dequantize_block, flatten_grid, fuse_phases, interleaved_accumulate,
    lane_utilization, machine_balance, quantize_block, recommend,
    tiled_accumulate, vector_pad,
)
from repro.core.memory import QuantizedBlock


# ---------------------------------------------------------------- taxonomy
def test_table1_covers_all_three_classes():
    for cls in TransformClass:
        assert len(by_class(cls)) >= 3, cls


def test_table1_count_matches_paper():
    # 7 pipelining + 4 scaling + 4 memory transformations
    assert len(TABLE1) == 15


def test_every_objective_has_a_recommendation():
    for obj in Objective:
        assert recommend(obj), f"no transformation targets {obj}"


def test_transformations_name_repo_entrypoints():
    for t in TABLE1.values():
        assert t.tpu_mechanism and t.fpga_mechanism
        assert t.repo_entrypoints, t.name


# ---------------------------------------------------------- pipeline model
def test_pipeline_model_eq1():
    pm = PipelineModel(latency=100, initiation_interval=2, n=51)
    assert pm.cycles() == 100 + 2 * 50


def test_pipeline_sequential_composition():
    a = PipelineModel(10, 1, 100)
    b = PipelineModel(20, 2, 100)
    c = a.then(b)
    assert c.latency == 30 and c.initiation_interval == 2


def test_folding_cuts_iterations():
    pm = PipelineModel(10, 1, 1000).folded(8)
    assert pm.n == 125


# --------------------------------------------------- accumulation interleave
@settings(max_examples=30, deadline=None)
@given(st.integers(3, 400), st.integers(1, 16))
def test_interleaved_accumulate_matches_sum(n, lanes):
    xs = jnp.asarray(np.random.default_rng(n).normal(size=n), jnp.float32)
    got = interleaved_accumulate(xs, lanes=lanes)
    np.testing.assert_allclose(got, xs.sum(), rtol=1e-5, atol=1e-5)


def test_interleaved_accumulate_max():
    xs = jnp.asarray(np.random.default_rng(0).normal(size=777), jnp.float32)
    got = interleaved_accumulate(xs, lanes=8, op=jnp.maximum, init=-jnp.inf)
    assert got == xs.max()


def test_tiled_accumulate():
    def terms(idx):
        return jnp.sin(idx.astype(jnp.float32))[:, None] * jnp.ones((1, 3))

    got = tiled_accumulate(terms, n=64, tile=8, out_shape=(3,))
    want = jnp.sin(jnp.arange(64.0))[:, None].sum(0) * jnp.ones(3)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_cross_input_interleave_is_vmapped_iteration():
    def step(x):
        return 0.5 * x + 1.0

    states = jnp.arange(8.0)
    got = cross_input_interleave(step, states, n_steps=10)
    want = states
    for _ in range(10):
        want = 0.5 * want + 1.0
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_fuse_phases_equals_composition():
    phases = [jnp.sin, jnp.cos, jnp.tanh]
    x = jnp.linspace(-1, 1, 17)
    np.testing.assert_allclose(
        fuse_phases(phases)(x), jnp.tanh(jnp.cos(jnp.sin(x))), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 7), min_size=1, max_size=4))
def test_flatten_grid_roundtrip(dims):
    total, unflatten = flatten_grid(dims)
    assert total == int(np.prod(dims))
    for flat in [0, total - 1, total // 2]:
        idx = [int(v) for v in unflatten(jnp.asarray(flat))]
        want = list(np.unravel_index(flat, dims))
        assert idx == want


# -------------------------------------------------------------- tile planner
@settings(max_examples=15, deadline=None)
@given(st.sampled_from([512, 1024, 4096, 8192]),
       st.sampled_from([512, 2048, 8192]),
       st.sampled_from([512, 1024, 8192]))
def test_tileplanner_respects_vmem_and_alignment(m, n, k):
    tp = TilePlanner()
    plan = tp.plan_matmul(m, n, k)
    assert plan.vmem_bytes <= tp.budget
    for b in (plan.bm, plan.bn, plan.bk):
        assert b % 128 == 0


def test_tileplanner_prefers_reuse():
    plan = TilePlanner().plan_matmul(8192, 8192, 8192)
    small = TilePlanner().plan_matmul(256, 256, 8192)
    assert plan.arithmetic_intensity >= small.arithmetic_intensity


def test_vector_pad_and_lane_utilization():
    assert vector_pad((100,), 4) == (128,)
    assert vector_pad((5, 100), 4) == (8, 128)
    assert vector_pad((5, 100), 2) == (16, 128)     # bf16 packs 2x
    assert 0 < lane_utilization((5, 100)) < 1
    assert lane_utilization((8, 128)) == 1.0


# ---------------------------------------------------------------- roofline
def test_roofline_terms_and_dominance():
    r = Roofline("t", chips=256, hlo_flops=197e12 * 256,
                 hlo_bytes=819e9 * 128, collective_bytes=50e9 * 512,
                 model_flops=197e12 * 256)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 0.5) < 1e-9
    assert abs(r.collective_s - 2.0) < 1e-9
    assert r.dominant == "collective"
    assert r.useful_flops_ratio == 1.0


def test_machine_balance_positive():
    assert machine_balance(TPU_V5E) > 100  # v5e is very compute-rich


# ----------------------------------------------------------- type demotion
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 5, 127, 128, 300]),
       st.floats(0.01, 100.0))
def test_quantize_roundtrip_error_bound(ndim, last, scale):
    rng = np.random.default_rng(last)
    shape = tuple([2] * (ndim - 1) + [last])
    x = jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)
    qb = quantize_block(x, block=128)
    back = dequantize_block(qb)
    # symmetric int8: error <= scale_per_block / 2 = amax/254
    err = np.abs(np.asarray(back - x))
    bound = np.abs(np.asarray(x)).max() / 127.0 + 1e-7
    assert err.max() <= bound


def test_quantized_block_is_pytree_with_static_block():
    qb = quantize_block(jnp.arange(256.0), block=64)
    leaves, treedef = jax.tree_util.tree_flatten(qb)
    assert len(leaves) == 2
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.block == 64


def test_quantize_shape_preserved():
    x = jnp.ones((3, 5, 257))
    qb = quantize_block(x)
    assert qb.q.shape == x.shape
    assert dequantize_block(qb).shape == x.shape
