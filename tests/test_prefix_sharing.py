"""Prefix-sharing copy-on-write KV page tests.

Three layers, mirroring the tentpole's structure:

1. unit — the refcounted ``PageAllocator`` and the ``PrefixCache`` trie
   (match granularity, full-chunk-only publication, LRU eviction of
   trie-only pages) with no model in the loop;
2. differential — a scheduler WITH the prefix cache must emit exactly
   the tokens a scheduler WITHOUT it emits (and the dense-equivalence
   suite already ties the latter to the dense forward), including the
   copy-on-write divergence case where a fully-covered request appends
   mid-page into shared memory;
3. runtime properties — refcounted recycling under an oversubscribed
   pool, sliding-window reclamation on shared pages, and the engine's
   prefill-skip accounting.  ``check_page_accounting`` (held + free +
   trash == total AND sum(refs) == nameable holders) asserts inside
   every scheduler mutation, so each run here exercises it throughout.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.core.memory import DtypePolicy
from repro.launch.prefix import PrefixCache
from repro.launch.serve import PageAllocator, PagedScheduler, Request
from repro.models.transformer import ExecOptions, Model


def _tiny_cfg(name, **overrides):
    cfg = ARCHS[name].smoke()
    return dataclasses.replace(
        cfg, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
        vocab_size=128, n_experts=min(cfg.n_experts, 4) or 0,
        **overrides)


def _make_scheduler(slots=2, max_len=32, page=4, total_pages=0,
                    arch="gemma-2b", prefix_cache=False, log=None):
    cfg = _tiny_cfg(arch, dispatch="reference")
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    return PagedScheduler(model, params, slots=slots, max_len=max_len,
                          page_size=page, total_pages=total_pages,
                          prefix_cache=prefix_cache,
                          log=log or (lambda *a, **k: None))


# ------------------------------------------------------------------- units
def test_allocator_refcounts():
    alloc = PageAllocator(6)               # page 0 = trash
    pages = alloc.alloc(3)
    assert sorted(pages) == [1, 2, 3]
    assert all(alloc.ref[p] == 1 for p in pages)
    assert alloc.held() == 3 and alloc.available() == 2

    alloc.share(pages[0])
    assert alloc.ref[pages[0]] == 2
    alloc.release([pages[0]])              # one holder left: stays held
    assert alloc.ref[pages[0]] == 1
    assert alloc.held() == 3 and alloc.available() == 2
    alloc.release(pages)                   # last holders: all freed
    assert alloc.held() == 0 and alloc.available() == 5

    with pytest.raises(AssertionError, match="double free"):
        alloc.release([pages[0]])
    with pytest.raises(AssertionError, match="free page"):
        alloc.share(pages[0])


def test_allocator_alloc_never_hands_out_referenced_pages():
    alloc = PageAllocator(4)
    a = alloc.alloc(3)
    alloc.share(a[1])
    alloc.release(a)                       # a[1] still referenced
    got = alloc.alloc(2)                   # must be the two ref == 0 pages
    assert a[1] not in got
    with pytest.raises(RuntimeError, match="exhausted"):
        alloc.alloc(1)


def test_prefix_trie_full_chunk_publication_and_match():
    alloc = PageAllocator(8)
    trie = PrefixCache(4)
    toks = list(range(10))                 # 2 full chunks + 2-token tail
    pages = alloc.alloc(3)
    added = trie.insert(toks, pages, alloc)
    assert added == 2 and trie.n_pages() == 2   # tail chunk NOT published
    assert alloc.ref[pages[0]] == alloc.ref[pages[1]] == 2
    assert alloc.ref[pages[2]] == 1             # partial page stays private

    # page-aligned coverage: one full chunk + a diverging second chunk
    got, covered = trie.match(list(range(4)) + [99, 98, 97, 96])
    assert got == [pages[0]] and covered == 4
    # fully covered: a partial prefix of a PUBLISHED page matches too
    got, covered = trie.match(list(range(6)))
    assert got == [pages[0], pages[1]] and covered == 6
    # the unpublished tail can never be matched
    got, covered = trie.match(toks)
    assert got == [pages[0], pages[1]] and covered == 8
    assert trie.hits == 3
    got, covered = trie.match([55, 56, 57, 58])
    assert got == [] and covered == 0 and trie.misses == 1


def test_prefix_trie_evicts_lru_trie_only_pages():
    alloc = PageAllocator(8)
    trie = PrefixCache(2)
    pa = alloc.alloc(2)
    pb = alloc.alloc(1)
    trie.insert([1, 2, 3, 4], pa, alloc)   # chain: [1,2] -> [3,4]
    trie.insert([5, 6], pb, alloc)
    alloc.release(pa + pb)                 # slots retired: trie-only now
    trie.match([5, 6])                     # refresh pb: pa chain is LRU

    # interior node [1,2] is not evictable while its child lives, so the
    # first eviction takes the chain leaf [3,4], the second its parent
    assert trie.evict(2, alloc) == 2
    assert trie.n_pages() == 1
    assert alloc.ref[pa[0]] == 0 and alloc.ref[pa[1]] == 0
    assert alloc.ref[pb[0]] == 1           # recently used: survived

    alloc.share(pb[0])                     # a slot re-binds the page
    assert trie.evict(1, alloc) == 0       # ref > 1: never stolen
    alloc.release([pb[0]])
    assert trie.evict(1, alloc) == 1 and trie.n_pages() == 0


def test_prefix_trie_flush_releases_everything():
    alloc = PageAllocator(8)
    trie = PrefixCache(2)
    pages = alloc.alloc(3)
    trie.insert([1, 2, 3, 4, 5, 6], pages, alloc)
    alloc.release(pages)
    assert trie.flush(alloc) == 3
    assert trie.n_pages() == 0 and alloc.available() == 7


# ----------------------------------------------------------- differentials
def test_sharing_matches_unshared_scheduler_exactly():
    """The sharing scheduler's tokens must equal the non-sharing
    scheduler's, while actually sharing (hits, skipped prefill): full
    repeat (fully covered), page-aligned partial overlap, and a cold
    miss, served back-to-back through one slot."""
    rng = np.random.default_rng(11)
    base = rng.integers(0, 128, 16)
    prompts = [base,                              # publisher
               base.copy(),                       # fully covered repeat
               np.concatenate([base[:8], rng.integers(0, 128, 4)]),
               rng.integers(0, 128, 12)]          # cold miss

    def serve(prefix_cache):
        sched = _make_scheduler(slots=1, max_len=32, page=4,
                                prefix_cache=prefix_cache)
        done = sched.run([Request(i, p, 4) for i, p in enumerate(prompts)])
        return {r.rid: list(r.out) for r in done}, sched

    want, cold = serve(False)
    got, shared = serve(True)
    assert got == want
    assert shared.prefix.hits >= 2
    assert shared.shared_tokens_total == 16 + 8   # repeat + aligned overlap
    # skipped prompt tokens never hit the prefill kernel
    assert shared.prefill_tokens == cold.prefill_tokens - 24
    assert shared.cow_copies >= 1                 # the fully-covered repeat


def test_cow_divergence_mid_page_preserves_shared_pages():
    """A fully-covered sharer appends into a shared partial page: the
    append must copy-on-write (its tokens match a fresh unshared run) and
    must NOT corrupt the published page — a later full-prompt repeat
    still matches the original publisher's tokens."""
    rng = np.random.default_rng(3)
    base = rng.integers(0, 128, 16)
    mid = base[:10]                    # ends mid-page (page = 4)

    sched = _make_scheduler(slots=1, max_len=32, page=4, prefix_cache=True)
    a, b, c = sched.run([Request(0, base, 4), Request(1, mid, 4),
                         Request(2, base.copy(), 4)])

    # request 1 was fully covered (10 of 10 tokens: 2 full chunks + a
    # partial match of the published third chunk) and diverged mid-page
    assert sched.shared_tokens_total == 10 + 16
    assert sched.cow_copies >= 2       # request 1's append + request 2's

    solo = _make_scheduler(slots=1, max_len=32, page=4, prefix_cache=False)
    want_mid = solo.run([Request(0, mid.copy(), 4)])[0]
    assert list(b.out) == list(want_mid.out), "CoW path diverged"
    assert list(c.out) == list(a.out), "shared pages were corrupted"


def test_refcounted_recycling_under_oversubscription():
    """An oversubscribed pool (less than slots x slot-capacity) with
    sharing on: admission blocks, recycles, trie evictions, and CoW all
    interleave, the accounting invariant asserts on every mutation, and
    every request still completes with the unshared token streams."""
    rng = np.random.default_rng(7)
    base = rng.integers(0, 128, 8)
    prompts = []
    for i in range(6):
        tail = rng.integers(0, 128, 4)
        prompts.append(np.concatenate([base, tail]) if i % 2 == 0
                       else rng.integers(0, 128, 12))

    def serve(prefix_cache):
        sched = _make_scheduler(slots=2, max_len=16, page=4,
                                total_pages=7, prefix_cache=prefix_cache)
        done = sched.run([Request(i, p.copy(), 3)
                          for i, p in enumerate(prompts)])
        return {r.rid: list(r.out) for r in done}, sched

    want, _ = serve(False)
    got, sched = serve(True)
    assert got == want and len(got) == 6
    assert sched.rejected == 0
    assert sched.prefix.hits >= 2
    # drained: every page is free again except those the trie still holds
    sched.check_page_accounting()
    assert (sched.alloc.available()
            == sched.alloc.total - 1 - sched.prefix.n_pages())


def test_window_reclamation_and_refcounts_interact_soundly():
    """Fully-windowed stacks reclaim pages mid-request; with sharing the
    slot's release must only drop ITS reference — trie-held prefix pages
    survive reclamation with valid K/V and still serve later sharers."""
    page, window, max_len = 4, 8, 32
    cfg = _tiny_cfg("gemma3-4b", window=window, dispatch="reference")
    cfg = dataclasses.replace(
        cfg, n_layers=2, prefix=(("swa", "mlp"), ("swa", "mlp")),
        pattern=())
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    sched = PagedScheduler(model, params, slots=1, max_len=max_len,
                           page_size=page, prefix_cache=True,
                           log=lambda *a, **k: None)
    assert sched.window == window
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, 128, 12)
    a, b = sched.run([Request(0, prompt, 14), Request(1, prompt.copy(), 14)])
    assert sched.pages_reclaimed > 0           # window freed pages mid-run
    assert sched.prefix.hits >= 1              # request 1 reused the prefix
    assert sched.shared_tokens_total == 12
    assert list(a.out) == list(b.out)          # reclaimed sharer == owner


def test_engine_sharing_differential_and_prefill_skip():
    """Continuous engine: a shared-prefix stream served with the prefix
    cache emits the same tokens as without it, while skipping prefill
    work and tracking residency."""
    from repro.launch.engine import ContinuousEngine
    from repro.launch.loadgen import poisson_stream

    def serve(prefix_cache):
        sched = _make_scheduler(slots=2, max_len=32, page=4,
                                prefix_cache=prefix_cache)
        engine = ContinuousEngine(sched, clock="tick",
                                  log=lambda *a, **k: None)
        reqs = poisson_stream(6, rate=0.0, vocab_size=128, prompt_len=12,
                              max_new=4, seed=5, shared_prefix_len=8,
                              shared_frac=1.0)
        done = engine.run(reqs)
        return {r.rid: list(r.out) for r in done}, sched, engine

    want, cold, _ = serve(False)
    got, shared, engine = serve(True)
    assert got == want and len(got) == 6
    assert shared.prefix.hits >= 4             # burst admits 2 cold, rest hit
    assert shared.prefill_tokens < cold.prefill_tokens
    assert shared.shared_tokens_total >= 4 * 8
    assert engine.max_resident == 2
    shared.check_page_accounting()


def test_engine_fully_covered_admission_skips_prefill_entirely():
    """A fully-covered engine request runs zero prefill chunks: its first
    token is born through the batched decode path (CoW against the
    shared partial page) and the stream still matches the cold run."""
    from repro.launch.engine import ContinuousEngine
    from repro.launch.loadgen import trace_stream

    rng = np.random.default_rng(13)
    base = list(rng.integers(0, 128, 12))
    trace = [{"t": 0.0, "tokens": base, "max_new": 3},
             {"t": 6.0, "tokens": base[:10], "max_new": 3}]

    def serve(prefix_cache):
        sched = _make_scheduler(slots=1, max_len=32, page=4,
                                prefix_cache=prefix_cache)
        engine = ContinuousEngine(sched, clock="tick",
                                  log=lambda *a, **k: None)
        done = engine.run(trace_stream(trace, vocab_size=128, seed=0))
        return {r.rid: list(r.out) for r in done}, sched, engine

    want, _, _ = serve(False)
    got, sched, engine = serve(True)
    assert got == want
    assert sched.cow_copies >= 1
    # the covered request contributed nothing to prefill: only the
    # publisher's 12 tokens ever hit the prefill kernel
    assert sched.prefill_tokens == 12
    assert engine.executor.prefill_chunks == 3   # ceil(12 / 4), once
