"""Pallas WKV kernel vs the validated chunked oracle (§Perf-1 blueprint)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.wkv import wkv
from repro.kernels.wkv.ref import wkv_ref

KEY = jax.random.key(0)


def make_inputs(b, s, h, hd, seed=0, decay_scale=2.0):
    ks = jax.random.split(jax.random.key(seed), 5)
    r = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) - decay_scale)
    u = jax.random.normal(ks[4], (h, hd), jnp.float32)
    return r, k, v, lw, u


@pytest.mark.parametrize("shape,chunk,sub", [
    ((2, 64, 2, 16), 32, 8),
    ((1, 128, 3, 32), 64, 16),
    ((2, 96, 1, 64), 32, 16),
])
def test_wkv_kernel_matches_oracle(shape, chunk, sub):
    b, s, h, hd = shape
    r, k, v, lw, u = make_inputs(b, s, h, hd)
    got = wkv(r, k, v, lw, u, chunk=chunk, subchunk=sub)
    want = wkv_ref(r, k, v, lw, u, chunk=chunk)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1.0, 3.0]))
def test_wkv_kernel_property(seed, decay_scale):
    r, k, v, lw, u = make_inputs(1, 64, 2, 16, seed=seed,
                                 decay_scale=decay_scale)
    got = wkv(r, k, v, lw, u, chunk=32, subchunk=8)
    want = wkv_ref(r, k, v, lw, u, chunk=32)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_wkv_kernel_strong_decay_stable():
    b, s, h, hd = 1, 64, 1, 16
    r = jnp.ones((b, s, h, hd))
    k = jnp.ones((b, s, h, hd))
    v = jnp.ones((b, s, h, hd))
    lw = jnp.full((b, s, h, hd), -45.0)
    u = jnp.zeros((h, hd))
    out = wkv(r, k, v, lw, u, chunk=32, subchunk=8)
    assert bool(jnp.all(jnp.isfinite(out)))
