"""Multi-device (subprocess) tests: sharded training equivalence, shard_map
MoE vs the global reference, pipeline parallelism vs sequential."""
import pytest

from helpers import run_multidevice

pytestmark = pytest.mark.slow   # multi-device subprocess tests


def test_sharded_train_step_matches_single_device():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch
        from repro.core.memory import DtypePolicy, F32_POLICY
        from repro.models.transformer import Model, ExecOptions
        from repro.runtime.sharding import make_rules, tree_shardings
        from repro.train.steps import (TrainStepConfig, init_train_state,
                                       make_train_step)
        from repro.optim.adamw import AdamWConfig

        cfg = get_arch("codeqwen1.5-7b").smoke()
        dt = F32_POLICY  # exact comparison needs f32 compute
        batch = {
            "tokens": jax.random.randint(jax.random.key(1), (4, 32), 0,
                                         cfg.vocab_size),
            "labels": jax.random.randint(jax.random.key(2), (4, 32), 0,
                                         cfg.vocab_size),
        }
        ts = TrainStepConfig(opt=AdamWConfig(lr=1e-2))

        # single-device reference
        m = Model(cfg, dt=dt, opts=ExecOptions(mode="run", block_q=16,
                                               block_kv=16))
        params, opt = init_train_state(m, ts, jax.random.key(0))
        step = jax.jit(make_train_step(m, ts))
        _, _, met_ref = step(params, opt, batch)

        # sharded on a (4,2) mesh with SP/TP/FSDP constraints
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = make_rules(mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        def con(x):
            spec = rules.activation_spec(x.shape)
            if x.ndim != 3 or spec is None:
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        m2 = Model(cfg, dt=dt, opts=ExecOptions(
            mode="run", block_q=16, block_kv=16, constrain=con,
            moe_mesh=mesh, moe_dp_axes=rules.dp_axes,
            expert_pad=2))
        params2, opt2 = init_train_state(m2, ts, jax.random.key(0))
        p_sh = tree_shardings(rules, params2)
        o_sh = tree_shardings(rules, opt2)
        params2 = jax.device_put(params2, p_sh)
        opt2 = jax.device_put(opt2, o_sh)
        step2 = jax.jit(make_train_step(m2, ts))
        with mesh:
            _, _, met_sh = step2(params2, opt2, batch)
        a, b = float(met_ref["loss"]), float(met_sh["loss"])
        assert abs(a - b) / abs(a) < 1e-4, (a, b)
        print("SHARDED-TRAIN-OK", a, b)
    """)
    assert "SHARDED-TRAIN-OK" in out


def test_moe_sharded_matches_global():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.memory import F32_POLICY
        from repro.models import moe
        from repro.models.moe_sharded import moe_apply_sharded

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        # ample capacity so neither path drops tokens
        s = moe.MoESpec(d_model=16, n_experts=8, top_k=2, d_expert=32,
                        capacity_factor=8.0, norm_topk=True, pad_to=4)
        p = moe.moe_init(jax.random.key(0), s)
        x = jax.random.normal(jax.random.key(1), (4, 8, 16), jnp.float32)
        ref, _ = moe.moe_apply(p, s, x, F32_POLICY)
        with mesh:
            got, aux = moe_apply_sharded(p, s, x, F32_POLICY, mesh=mesh,
                                         dp_axes=("data",))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-3, atol=2e-4)
        assert np.isfinite(float(aux))
        # gradients flow through the a2a path
        def loss(p):
            o, aux = moe_apply_sharded(p, s, x, F32_POLICY, mesh=mesh,
                                       dp_axes=("data",))
            return jnp.sum(o * o) + aux
        with mesh:
            g = jax.grad(loss)(p)
        gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        print("MOE-SHARDED-OK")
    """)
    assert "MOE-SHARDED-OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline_parallel import (bubble_fraction,
                                                     pipeline_apply)

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("pod",))
        S, M, mb, d = 4, 8, 2, 16
        ks = jax.random.split(jax.random.key(0), S)
        stage_params = {"w": jnp.stack([
            0.1 * jax.random.normal(k, (d, d)) for k in ks])}

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        x = jax.random.normal(jax.random.key(9), (M, mb, d))
        with mesh:
            got = pipeline_apply(stage_fn, stage_params, x, mesh=mesh,
                                 stage_axis="pod")
        want = x
        for i in range(S):
            want = jnp.tanh(want @ stage_params["w"][i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
        assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
        print("PP-OK")
    """, n_devices=4)
    assert "PP-OK" in out
