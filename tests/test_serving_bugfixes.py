"""Regression tests for the serving-correctness bugfix sweep.

Each test pins one previously-silent failure mode of the request
lifecycle:

* dense ``Server.run`` dropped in-flight/queued requests when the shared
  position hit the context wall — now they come back flagged + counted;
* the paged paths admitted ``n_slot_pages * page`` tokens (> ``max_len``
  when ``max_len`` is not page-divisible) and truncated at the wall with
  ``done=True`` and no signal — admissibility now clamps to ``max_len``
  and wall-stopped requests carry ``Request.truncated``;
* ``BatchPolicy.compose`` let the prefill allowance go negative when the
  running decode set alone exceeded the token budget;
* ``trace_stream`` hardcoded rids so mixed streams collided keys in
  ``ServeMetrics.timelines`` — now ``start_rid`` offsets them and
  ``ArrivalQueue`` refuses duplicates outright.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.core.memory import DtypePolicy
from repro.launch.loadgen import ArrivalQueue, Request, trace_stream
from repro.launch.serve import PagedScheduler, Server
from repro.models.transformer import ExecOptions, Model


def _tiny_cfg(name="gemma-2b", **overrides):
    cfg = ARCHS[name].smoke()
    return dataclasses.replace(
        cfg, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
        vocab_size=128, n_experts=min(cfg.n_experts, 4) or 0,
        **overrides)


def _model_params():
    model = Model(_tiny_cfg(), dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    return model, model.init(jax.random.key(0))


# --------------------------------------------------------- dense wall drop
def test_dense_wall_returns_flagged_requests_not_silence():
    """Shared-position context wall with work still in flight: every
    request is accounted for — finished normally, returned truncated, or
    counted rejected.  None vanish."""
    model, params = _model_params()
    logs = []
    srv = Server(model, params, slots=2, max_len=12, log=logs.append)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, 128, 6), 4) for i in range(5)]
    done = srv.run(list(reqs))

    assert len(done) + srv.rejected == 5           # nothing dropped
    assert all(r is None for r in srv.active)      # nothing left behind
    by_rid = {r.rid: r for r in done}
    # slots 0/1 finish inside the wall (6 prompt + 4 out = 10 <= 12)
    assert not by_rid[0].truncated and len(by_rid[0].out) == 4
    assert not by_rid[1].truncated and len(by_rid[1].out) == 4
    # the wall catches the second wave mid-prompt: flagged, not dropped
    wall = [r for r in done if r.truncated]
    assert wall and srv.truncated == len(wall)
    assert all(r.done for r in wall)
    # never-admitted requests are rejections, with done=False
    assert srv.rejected == len(srv.rejected_requests)
    assert all(not r.done for r in srv.rejected_requests)
    assert srv.rejected > 0
    assert any("truncating" in m for m in logs)
    assert any("rejecting" in m for m in logs)


# ----------------------------------------- paged max_len clamp + truncation
def test_paged_admission_clamps_to_max_len():
    """max_len NOT page-divisible: page capacity (4 pages x 4 = 16) used
    to shadow max_len=14.  The budget now clamps, so a request whose
    lifetime exceeds max_len still admits (it will truncate, flagged)
    while a prompt >= max_len can never admit."""
    model, params = _model_params()
    sched = PagedScheduler(model, params, slots=1, max_len=14, page_size=4,
                           log=lambda *a, **k: None)
    rng = np.random.default_rng(2)
    over = Request(0, rng.integers(0, 128, 5), 20)    # 5 + 20 > 14
    assert sched.pages_needed(over) == 4              # ceil(14/4), clamped
    assert sched.admissible(over)
    full = Request(1, rng.integers(0, 128, 14), 2)    # prompt == max_len
    assert not sched.admissible(full)
    assert "max_len" in sched._reject_reason(full)


def test_paged_static_truncates_with_flag_at_the_wall():
    model, params = _model_params()
    logs = []
    sched = PagedScheduler(model, params, slots=1, max_len=14, page_size=4,
                           log=logs.append)
    rng = np.random.default_rng(2)
    fits = Request(1, rng.integers(0, 128, 4), 3)
    over = Request(0, rng.integers(0, 128, 5), 20)
    done = sched.run([over, fits])
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].truncated and by_rid[0].done
    # stored tokens never exceed max_len: 5 prompt + 9 appended = 14,
    # plus the final token predicted from the full window
    assert len(by_rid[0].out) == 14 - 5 + 1
    assert not by_rid[1].truncated and len(by_rid[1].out) == 3
    assert sched.truncated == 1
    assert any("truncating" in m for m in logs)


def test_paged_continuous_truncates_with_flag_at_the_wall():
    """Same wall, continuous schedule: the engine decode guard and the
    (defensive) prefill-born guard stop at max_len with the flag set and
    the metrics summary counting it."""
    from repro.launch.engine import ContinuousEngine
    model, params = _model_params()
    sched = PagedScheduler(model, params, slots=1, max_len=14, page_size=4,
                           log=lambda *a, **k: None)
    engine = ContinuousEngine(sched, clock="tick", log=lambda *a, **k: None)
    trace = [{"t": 0.0, "prompt_len": 5, "max_new": 20},
             {"t": 0.0, "prompt_len": 4, "max_new": 3}]
    done = engine.run(trace_stream(trace, vocab_size=128, seed=2))
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].truncated and len(by_rid[0].out) == 14 - 5 + 1
    assert not by_rid[1].truncated and len(by_rid[1].out) == 3
    assert sched.truncated == 1
    assert engine.metrics.summary()["requests_truncated"] == 1


def test_static_and_continuous_agree_at_the_wall():
    """Differential: both schedules must emit the same (truncated) token
    stream for the same wall-limited request."""
    from repro.launch.engine import ContinuousEngine
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, 128, 5)

    model, params = _model_params()
    s1 = PagedScheduler(model, params, slots=1, max_len=14, page_size=4,
                        log=lambda *a, **k: None)
    a = s1.run([Request(0, prompt.copy(), 20)])[0]

    model2, params2 = _model_params()
    s2 = PagedScheduler(model2, params2, slots=1, max_len=14, page_size=4,
                        log=lambda *a, **k: None)
    engine = ContinuousEngine(s2, clock="tick", log=lambda *a, **k: None)
    b = engine.run([Request(0, prompt.copy(), 20)])[0]
    assert a.truncated and b.truncated
    assert list(a.out) == list(b.out)


# ------------------------------------------------- BatchPolicy budget clamp
def test_compose_never_overruns_budget_with_decode_backlog():
    from repro.launch.engine import BatchPolicy
    policy = BatchPolicy(token_budget=2, page=4)
    # decode set alone exceeds the budget: prefill allowance must clamp
    # to zero, not go negative (negative `left` admitted no chunks only
    # by accident of the comparison; pin the clamp explicitly)
    plan = policy.compose(running=[0, 1, 2], prefilling=[(3, 0)])
    assert plan.decode == [0, 1, 2]        # decode-first: never trimmed
    assert plan.prefill == []
    # with headroom, chunks admit up to the budget, one per slot
    plan = BatchPolicy(9, 4).compose([0], [(1, 0), (2, 4), (3, 0)])
    assert plan.decode == [0] and plan.prefill == [(1, 0), (2, 4)]
    # nothing decoding, budget below one page: forced progress, no stall
    plan = BatchPolicy(2, 4).compose([], [(1, 0)])
    assert plan.prefill == [(1, 0)]


# ------------------------------------------------------ loadgen rid hygiene
def test_trace_stream_start_rid_offsets_ids():
    trace = [{"t": 0.0, "prompt_len": 3, "max_new": 2},
             {"t": 1.0, "prompt_len": 2, "max_new": 1}]
    a = trace_stream(trace, vocab_size=32, seed=0)
    b = trace_stream(trace, vocab_size=32, seed=1, start_rid=len(a))
    assert [r.rid for r in a] == [0, 1]
    assert [r.rid for r in b] == [2, 3]
    q = ArrivalQueue(a + b)                # mixed streams: no collision
    assert len(q) == 4


def test_arrival_queue_rejects_duplicate_rids():
    reqs = [Request(0, np.array([1]), 1), Request(1, np.array([1]), 1),
            Request(0, np.array([2]), 1)]
    with pytest.raises(ValueError, match="duplicate request rids.*\\[0\\]"):
        ArrivalQueue(reqs)
