"""The benchmark-regression gate (scripts/check_bench.py) must pass on
like-for-like numbers and FAIL on an injected 2x slowdown — the negative
test the CI gate's acceptance criteria demand."""
import importlib.util
import json
from pathlib import Path

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench",
    Path(__file__).resolve().parents[1] / "scripts" / "check_bench.py")
check_bench = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_bench)


def _report(decode_paged, decode_dense):
    return {"rows": [
        {"arch": "gemma-2b-smoke", "cache": "paged",
         "decode_tok_s": decode_paged, "prefill_tok_s": 100.0},
        {"arch": "gemma-2b-smoke", "cache": "dense",
         "decode_tok_s": decode_dense, "prefill_tok_s": None},
    ]}


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return p


def test_gate_passes_on_identical_numbers(tmp_path):
    base = _write(tmp_path, "base.json", _report(100.0, 40.0))
    cur = _write(tmp_path, "cur.json", _report(100.0, 40.0))
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 0


def test_gate_passes_within_tolerance(tmp_path):
    base = _write(tmp_path, "base.json", _report(100.0, 40.0))
    cur = _write(tmp_path, "cur.json", _report(70.0, 30.0))   # -30%, -25%
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 0


def test_gate_fails_on_injected_2x_slowdown(tmp_path):
    base = _write(tmp_path, "base.json", _report(100.0, 40.0))
    cur = _write(tmp_path, "cur.json", _report(50.0, 40.0))   # 2x slower
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 1
    failures, compared = check_bench.compare(check_bench.load_metrics(base),
                                             check_bench.load_metrics(cur))
    assert len(failures) == 1 and "paged" in failures[0]
    assert compared == 2


def test_gate_ignores_rows_missing_from_either_side(tmp_path):
    base = _write(tmp_path, "base.json", _report(100.0, 40.0))
    cur = _write(tmp_path, "cur.json", {"rows": [
        {"arch": "gemma-2b-smoke", "cache": "paged",
         "decode_tok_s": 100.0}]})
    # dense row absent from current, new arch absent from baseline: noted,
    # not failed — a new benchmark must be able to land before its baseline
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 0


def test_gate_never_passes_vacuously(tmp_path):
    """Zero overlap between baseline and current (renamed metric, changed
    row keys, empty run) is an error, not a pass — the gate must have
    compared at least one row to claim success."""
    base = _write(tmp_path, "base.json", _report(100.0, 40.0))
    empty = _write(tmp_path, "empty.json", {"rows": []})
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(empty)]) == 2
    disjoint = _write(tmp_path, "disjoint.json", {"rows": [
        {"arch": "other-arch", "cache": "paged", "decode_tok_s": 1.0}]})
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(disjoint)]) == 2


def test_gate_errors_on_empty_baseline(tmp_path):
    base = _write(tmp_path, "base.json", {"rows": []})
    cur = _write(tmp_path, "cur.json", _report(1.0, 1.0))
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 2


def test_tolerance_is_configurable(tmp_path):
    base = _write(tmp_path, "base.json", _report(100.0, 40.0))
    cur = _write(tmp_path, "cur.json", _report(50.0, 40.0))
    assert check_bench.main(["--baseline", str(base), "--current", str(cur),
                             "--tolerance", "0.6"]) == 0
    with pytest.raises(SystemExit):
        check_bench.main(["--baseline", str(base), "--current", str(cur),
                          "--tolerance", "not-a-float"])


# ---------------------------------------------------------------- schedule


def _continuous_report(decode_tok_s, lat_p99):
    return {"rows": [
        {"arch": "gemma-2b-smoke", "cache": "paged",
         "schedule": "continuous", "decode_tok_s": decode_tok_s,
         "tok_latency_p99_s": lat_p99},
    ]}


def test_schedule_keys_do_not_collide(tmp_path):
    """A phased row and a continuous row for the same (arch, cache) are
    distinct gate keys — merging both modes into one report must not make
    one row shadow the other."""
    p = _write(tmp_path, "merged.json", {"rows": [
        {"arch": "a", "cache": "paged", "decode_tok_s": 1.0},
        {"arch": "a", "cache": "paged", "schedule": "continuous",
         "decode_tok_s": 2.0},
    ]})
    loaded = check_bench.load_metrics(p)
    assert loaded[("a", "paged", "phased")]["decode_tok_s"] == 1.0
    assert loaded[("a", "paged", "continuous")]["decode_tok_s"] == 2.0


def test_latency_gate_fails_on_injected_p99_blowup(tmp_path):
    """The latency ceiling is its own gate: unchanged throughput with a
    3x p99 per-token latency regression must FAIL."""
    base = _write(tmp_path, "base.json", _continuous_report(100.0, 0.010))
    cur = _write(tmp_path, "cur.json", _continuous_report(100.0, 0.030))
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 1
    failures, compared = check_bench.compare(
        check_bench.load_metrics(base), check_bench.load_metrics(cur))
    assert len(failures) == 1 and "tok_latency_p99_s" in failures[0]
    assert compared == 2         # one throughput + one latency comparison


def test_latency_gate_passes_within_its_own_tolerance(tmp_path):
    base = _write(tmp_path, "base.json", _continuous_report(100.0, 0.010))
    cur = _write(tmp_path, "cur.json", _continuous_report(100.0, 0.017))
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 0     # +70% < 80%
    # and the knob is independent of the throughput tolerance
    worse = _write(tmp_path, "worse.json", _continuous_report(100.0, 0.017))
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(worse),
                             "--lat-tolerance", "0.5"]) == 1


def test_latency_gate_env_var_override(tmp_path, monkeypatch):
    base = _write(tmp_path, "base.json", _continuous_report(100.0, 0.010))
    cur = _write(tmp_path, "cur.json", _continuous_report(100.0, 0.017))
    monkeypatch.setenv("REPRO_BENCH_LAT_TOL", "0.5")
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 1


# ----------------------------------------------------------------- sharing


def _share_report(res0, eff0, res95, eff95):
    return {"rows": [
        {"arch": "a", "cache": "paged", "schedule": "continuous-share0",
         "decode_tok_s": 100.0, "max_resident": res0,
         "prefill_tok_s_effective": eff0},
        {"arch": "a", "cache": "paged", "schedule": "continuous-share95",
         "decode_tok_s": 100.0, "max_resident": res95,
         "prefill_tok_s_effective": eff95},
    ]}


def test_sharing_gate_passes_when_share95_wins(tmp_path):
    report = _share_report(2, 500.0, 4, 1400.0)
    base = _write(tmp_path, "base.json", report)
    cur = _write(tmp_path, "cur.json", report)
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 0


def test_sharing_gate_fails_when_sharing_delivers_nothing(tmp_path):
    """share95 not strictly better than share0 on residency OR effective
    prefill throughput is a feature regression — no tolerance applies."""
    base = _write(tmp_path, "base.json", _share_report(2, 500.0, 4, 1400.0))
    cur = _write(tmp_path, "cur.json", _share_report(2, 500.0, 2, 1400.0))
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 1
    failures, compared = check_bench.compare_sharing(
        check_bench.load_metrics(cur))
    assert len(failures) == 1 and "max_resident" in failures[0]
    assert compared == 2

    worse = _write(tmp_path, "worse.json", _share_report(2, 500.0, 4, 400.0))
    failures, _ = check_bench.compare_sharing(check_bench.load_metrics(worse))
    assert len(failures) == 1 and "prefill_tok_s_effective" in failures[0]


def test_sharing_gate_skips_without_both_scenarios(tmp_path):
    """A run without the share scenarios (or only one of them) is not
    gated on sharing — the classic gates still apply."""
    only0 = {"rows": [
        {"arch": "a", "cache": "paged", "schedule": "continuous-share0",
         "decode_tok_s": 100.0, "max_resident": 2,
         "prefill_tok_s_effective": 500.0}]}
    p = _write(tmp_path, "only0.json", only0)
    failures, compared = check_bench.compare_sharing(
        check_bench.load_metrics(p))
    assert failures == [] and compared == 0


# ------------------------------------------------- sharded-serving (tp) gate

def _tp_report(match1=True, match2=True, ops2=3, kref=True):
    return {"rows": [
        {"arch": "a", "cache": "paged", "schedule": "continuous-tp1",
         "decode_tok_s": 100.0, "tp": 1, "tp_ops_in_region": 3,
         "tokens_match_oracle": match1},
        {"arch": "a", "cache": "paged", "schedule": "continuous-tp2",
         "decode_tok_s": 80.0, "tp": 2, "tp_ops_in_region": ops2,
         "tokens_match_oracle": match2, "kernels_match_reference": kref},
    ]}


def test_tp_gate_passes_on_true_verdicts(tmp_path):
    base = _write(tmp_path, "base.json", _tp_report())
    cur = _write(tmp_path, "cur.json", _tp_report())
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 0
    failures, compared = check_bench.compare_tp(check_bench.load_rows(cur))
    assert failures == [] and compared == 5   # 2x oracle + 2x ops + 1x kref


def test_tp_gate_fails_on_any_false_verdict(tmp_path):
    """Correctness verdicts have no tolerance: a diverged stream, a
    missing in-region dispatch, or a kernel/reference split each fail."""
    base = _write(tmp_path, "base.json", _tp_report())
    for bad, needle in (
            (_tp_report(match2=False), "tokens_match_oracle"),
            (_tp_report(ops2=1), "tp_ops_in_region"),
            (_tp_report(kref=False), "kernels_match_reference")):
        cur = _write(tmp_path, "cur.json", bad)
        assert check_bench.main(["--baseline", str(base),
                                 "--current", str(cur)]) == 1
        failures, _ = check_bench.compare_tp(check_bench.load_rows(cur))
        assert len(failures) == 1 and needle in failures[0], failures


def test_tp_gate_skips_without_tp_rows(tmp_path):
    p = _write(tmp_path, "plain.json", _report(100.0, 40.0))
    failures, compared = check_bench.compare_tp(check_bench.load_rows(p))
    assert failures == [] and compared == 0


# ------------------------------------------- speculative-decoding gate

def _spec_report(match_n=True, match_m=True, acc_n=0.62, acc_m=0.91,
                 tok_n=150.0, tok_m=180.0):
    return {"rows": [
        {"arch": "a", "cache": "paged", "schedule": "continuous-specngram",
         "drafter": "ngram", "decode_tok_s": tok_n,
         "baseline_decode_tok_s": 100.0, "acceptance_rate": acc_n,
         "accepted_per_step": 2.1, "tokens_match_baseline": match_n},
        {"arch": "a", "cache": "paged", "schedule": "continuous-specmodel",
         "drafter": "model", "decode_tok_s": tok_m,
         "baseline_decode_tok_s": 100.0, "acceptance_rate": acc_m,
         "accepted_per_step": 2.8, "tokens_match_baseline": match_m},
    ]}


def test_spec_gate_passes_on_healthy_rows(tmp_path):
    base = _write(tmp_path, "base.json", _spec_report())
    cur = _write(tmp_path, "cur.json", _spec_report())
    assert check_bench.main(["--baseline", str(base),
                             "--current", str(cur)]) == 0
    failures, compared = check_bench.compare_spec(check_bench.load_rows(cur))
    assert failures == [] and compared == 6   # 3 checks x 2 drafter rows


def test_spec_gate_fails_on_divergence_or_dead_drafter(tmp_path):
    """Correctness has no tolerance: a diverged stream fails, a zero (or
    missing) acceptance rate fails, a missing throughput field fails."""
    base = _write(tmp_path, "base.json", _spec_report())
    for bad, needle in (
            (_spec_report(match_m=False), "tokens_match_baseline"),
            (_spec_report(acc_n=0.0), "acceptance_rate"),
            (_spec_report(acc_m=None), "acceptance_rate"),
            (_spec_report(tok_n=None), "decode_tok_s")):
        cur = _write(tmp_path, "cur.json", bad)
        assert check_bench.main(["--baseline", str(base),
                                 "--current", str(cur)]) == 1
        failures, _ = check_bench.compare_spec(check_bench.load_rows(cur))
        assert len(failures) == 1 and needle in failures[0], failures


def test_spec_gate_skips_without_spec_rows(tmp_path):
    p = _write(tmp_path, "plain.json", _report(100.0, 40.0))
    failures, compared = check_bench.compare_spec(check_bench.load_rows(p))
    assert failures == [] and compared == 0
