"""End-to-end system behaviour: real (tiny) training through the full
production stack — sharded step, AdamW, deterministic data, checkpoints,
supervised restart — asserting the loss actually falls and that failure
injection does not change the trajectory."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as train_mod

pytestmark = pytest.mark.slow   # multi-device subprocess tests


def _run(tmp_path, extra_args=()):
    # codeqwen smoke: untied embeddings -> sane init loss scale
    argv = ["--arch", "codeqwen1.5-7b", "--smoke", "--steps", "30",
            "--batch", "4", "--seq", "32", "--lr", "3e-3",
            "--save-every", "10", "--log-every", "1000",
            "--ckpt-dir", str(tmp_path), *extra_args]
    return train_mod.main(argv)


def test_training_reduces_loss(tmp_path):
    losses = _run(tmp_path / "a")
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < 0.75 * np.mean(losses[:3]), \
        (losses[:3], losses[-5:])


def test_training_with_injected_failures_matches_clean_run(tmp_path):
    clean = _run(tmp_path / "clean")
    faulty = _run(tmp_path / "faulty", ("--inject-failures", "25"))
    # after the injected failure at 25, training restores from step 20 and
    # replays 20..24 deterministically: final losses identical
    np.testing.assert_allclose(clean[-1], faulty[-1], rtol=1e-5)


def test_training_with_compression_converges(tmp_path):
    losses = _run(tmp_path / "comp", ("--compress-grads",))
    assert np.mean(losses[-5:]) < 0.85 * np.mean(losses[:3])


def test_serving_end_to_end():
    from repro.launch import serve as serve_mod
    done = serve_mod.main(["--arch", "gemma-2b", "--smoke", "--slots", "2",
                           "--requests", "3", "--prompt-len", "4",
                           "--max-new", "4", "--max-len", "32"])
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)
