import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
for p in (str(REPO / "src"), str(REPO / "tests")):
    if p not in sys.path:
        sys.path.insert(0, p)
