"""Offline fallback for the ``hypothesis`` property-testing API.

The container this repo is developed in has no network access, so
``hypothesis`` may not be installable.  This module re-exports the real
package when it is present (identical semantics) and otherwise provides a
minimal drop-in implementing the subset the test-suite uses:

  * ``strategies.integers(lo, hi)``
  * ``strategies.floats(lo, hi)``
  * ``strategies.sampled_from(seq)``
  * ``strategies.lists(elem, min_size=, max_size=)``
  * ``@given(*strategies)`` — draws ``max_examples`` example tuples from a
    seeded PRNG (deterministic across runs) and calls the test once per
    example, re-raising the first failure with the offending example shown.
  * ``@settings(max_examples=, deadline=)`` — honoured in either decorator
    order; ``deadline`` is accepted and ignored.

Tests import from here instead of ``hypothesis`` directly::

    from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

try:                                       # real hypothesis wins when present
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    _SEED = 0xC0FFEE          # fixed: failures reproduce run-to-run
    _DEFAULT_MAX_EXAMPLES = 100

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the `hypothesis.strategies` module
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements: _Strategy, *, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        def deco(fn):
            fn._hc_max_examples = max_examples
            return fn
        return deco

    def given(*strats: _Strategy):
        def deco(fn):
            # NB: not functools.wraps — copying __wrapped__ would make pytest
            # unwrap to fn's signature and demand fixtures for drawn args.
            def wrapper(*args, **kwargs):
                max_examples = getattr(
                    wrapper, "_hc_max_examples",
                    getattr(fn, "_hc_max_examples", _DEFAULT_MAX_EXAMPLES))
                rng = random.Random(_SEED)
                for i in range(max_examples):
                    example = tuple(s.example(rng) for s in strats)
                    try:
                        fn(*args, *example, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{i + 1} "
                            f"for {fn.__name__}: {example!r}") from e
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            if hasattr(fn, "_hc_max_examples"):
                wrapper._hc_max_examples = fn._hc_max_examples
            return wrapper
        return deco

st = strategies

__all__ = ["given", "settings", "strategies", "st", "HAVE_HYPOTHESIS"]
