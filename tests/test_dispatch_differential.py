"""Differential tests for the kernel dispatch layer.

For every layer op, every assigned arch, and both compute dtypes, the
``DispatchPolicy("kernels")`` route (Pallas, interpret mode on CPU, tuned
plans) and the ``DispatchPolicy("reference")`` route (the einsum lowering
the models always had) must agree within dtype-appropriate tolerances on
fixed-seed inputs — the software-reference validation discipline for
composed kernels.  Plus regression tests proving serve (prefill + decode)
and one train step actually execute through dispatch with the tuned-plan
cache consulted, so a refactor can't silently drop back to raw einsums.

Shapes are deliberately tiny (smoke configs, S=8, width-reduced serve/
train probes) so the whole module stays inside the smoke-suite budget.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.archs import ARCHS
from repro.core.memory import DtypePolicy
from repro.kernels import dispatch
from repro.kernels.dispatch import DispatchPolicy
from repro.models import layers, moe
from repro.models.transformer import (ExecOptions, Model, _attn_spec,
                                      _moe_spec)

KEY = jax.random.key(0)
B, S = 2, 8

DTYPES = {
    "float32": DtypePolicy(compute=jnp.float32),
    "bfloat16": DtypePolicy(),
}
TOLS = {
    "float32": dict(rtol=2e-4, atol=2e-4),
    "bfloat16": dict(rtol=5e-2, atol=5e-2),
}


def _assert_close(got, want, dtype_name):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **TOLS[dtype_name])


def _positions(cfg):
    if cfg.mrope_sections:
        return jnp.broadcast_to(
            jnp.arange(S)[None, :, None],
            (B, S, len(cfg.mrope_sections))).astype(jnp.int32)
    return jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)


def test_dispatch_policy_validates():
    assert DispatchPolicy("kernels").mode == "kernels"
    with pytest.raises(ValueError):
        DispatchPolicy("einsum")
    with pytest.raises(ValueError):
        dispatch.resolve_mode("bogus")


# ----------------------------------------------------------------- matmul
@settings(max_examples=5, deadline=None)
@given(st.integers(1, 5), st.sampled_from([3, 8, 24, 40]),
       st.sampled_from([16, 48, 128]), st.sampled_from([32, 40, 96]),
       st.sampled_from(["float32", "bfloat16"]))
def test_matmul_differential(seed, m, k, n, dtype_name):
    """Property (hypothesis-shim shapes): kernels == reference for the
    generalized projection matmul, including ragged non-MXU dims."""
    cdt = DTYPES[dtype_name].compute
    ka, kb = jax.random.split(jax.random.key(seed))
    x = jax.random.normal(ka, (B, m, k), jnp.float32).astype(cdt)
    w = jax.random.normal(kb, (k, n), jnp.float32).astype(cdt)
    dispatch.reset_stats()
    got = dispatch.matmul(x, w, policy=DispatchPolicy("kernels"))
    want = dispatch.matmul(x, w, policy=DispatchPolicy("reference"))
    assert dispatch.stats()[("matmul", "kernel")] == 1   # no silent fallback
    assert got.dtype == want.dtype
    _assert_close(got, want, dtype_name)


def test_grouped_matmul_differential():
    x = jax.random.normal(KEY, (4, 8, 32), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (4, 32, 16), jnp.float32)
    dispatch.reset_stats()
    got = dispatch.grouped_matmul(x, w, policy="kernels")
    want = dispatch.grouped_matmul(x, w, policy="reference")
    assert dispatch.stats()[("grouped_matmul", "kernel")] == 1
    _assert_close(got, want, "float32")


# -------------------------------------------------------------- attention
@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_attention_differential(arch, dtype_name):
    """attention_naive / attention_blockwise agree across policies for the
    arch's own attention geometry (GQA/MQA, window, qkv bias, M-RoPE)."""
    cfg = ARCHS[arch].smoke()
    mixers = {m for m, _ in cfg.layer_kinds()}
    if not ({"attn", "swa"} & mixers):
        pytest.skip("attention-free arch")
    mixer = "swa" if "swa" in mixers else "attn"
    dt = DTYPES[dtype_name]
    spec_k = _attn_spec(dataclasses.replace(cfg, dispatch="kernels"), mixer)
    spec_r = _attn_spec(dataclasses.replace(cfg, dispatch="reference"),
                        mixer)
    p = layers.attention_init(KEY, spec_r)
    x = (0.2 * jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                                 jnp.float32)).astype(dt.compute)
    pos = _positions(cfg)
    dispatch.reset_stats()
    for fn in (layers.attention_naive, layers.attention_blockwise):
        got = fn(p, spec_k, x, pos, dt)
        want = fn(p, spec_r, x, pos, dt)
        _assert_close(got, want, dtype_name)
    stats = dispatch.stats()
    assert stats[("attention", "kernel")] == 2          # both impls routed
    assert stats[("matmul", "kernel")] == 8             # 2 x (3 qkv + proj)


@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
def test_attention_decode_differential(dtype_name):
    """Decode (rolling-cache mask -> reference attention route) still
    differs across policies in its projections; outputs must agree."""
    cfg = ARCHS["gemma3-4b"].smoke()        # exercises the swa rolling cache
    dt = DTYPES[dtype_name]
    spec_k = _attn_spec(dataclasses.replace(cfg, dispatch="kernels"), "swa")
    spec_r = _attn_spec(dataclasses.replace(cfg, dispatch="reference"),
                        "swa")
    p = layers.attention_init(KEY, spec_r)
    cap = cfg.window
    k_cache = jnp.zeros((B, cap, cfg.n_kv_heads, cfg.head_dim), dt.compute)
    v_cache = jnp.zeros_like(k_cache)
    x = (0.2 * jax.random.normal(jax.random.key(2), (B, 1, cfg.d_model),
                                 jnp.float32)).astype(dt.compute)
    got, gk, gv = layers.attention_decode(p, spec_k, x, jnp.int32(3),
                                          k_cache, v_cache, dt)
    want, wk, wv = layers.attention_decode(p, spec_r, x, jnp.int32(3),
                                           k_cache, v_cache, dt)
    _assert_close(got, want, dtype_name)
    _assert_close(gk, wk, dtype_name)
    _assert_close(gv, wv, dtype_name)


# -------------------------------------------------------------------- ffn
@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_ffn_differential(arch, dtype_name):
    cfg = ARCHS[arch].smoke()
    ffns = {f for _, f in cfg.layer_kinds()}
    if not ({"mlp", "moe"} & ffns):
        pytest.skip("no dispatched FFN (rwkv channel-mix arch)")
    dt = DTYPES[dtype_name]
    x = (0.2 * jax.random.normal(jax.random.key(3), (B, S, cfg.d_model),
                                 jnp.float32)).astype(dt.compute)
    if "mlp" in ffns:
        p = layers.mlp_init(KEY, cfg.d_model, cfg.d_ff, cfg.activation)
        got = layers.mlp_apply(p, x, cfg.activation, dt, policy="kernels")
        want = layers.mlp_apply(p, x, cfg.activation, dt,
                                policy="reference")
        _assert_close(got, want, dtype_name)
    if "moe" in ffns:
        spec_k = _moe_spec(dataclasses.replace(cfg, dispatch="kernels"))
        spec_r = _moe_spec(dataclasses.replace(cfg, dispatch="reference"))
        p = moe.moe_init(KEY, spec_r)
        got, aux_k = moe.moe_apply(p, spec_k, x, dt)
        want, aux_r = moe.moe_apply(p, spec_r, x, dt)
        _assert_close(got, want, dtype_name)
        _assert_close(aux_k, aux_r, dtype_name)


# ---------------------------------------------------- serve/train probes
def _tiny_cfg(name="gemma-2b", **overrides):
    cfg = ARCHS[name].smoke()
    return dataclasses.replace(
        cfg, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
        vocab_size=128, **overrides)


def test_serve_prefill_decode_execute_through_dispatch(tmp_path,
                                                       monkeypatch):
    """Serving runs through dispatch end-to-end: prefill + decode take the
    kernel/reference routes AND the tuned-plan cache is consulted — a
    seeded exact-shape entry is picked up by the prefill projections."""
    from repro.launch.serve import Server
    from repro.tune import cache as tune_cache

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    cache = tune_cache.PlanCache(tmp_path / "plans.json")
    # exact entry for the prefill qkv projection: (m=B*S, k=d, n=h*hd)
    cache.put("matmul", (2 * 8, 32, 32), jnp.float32,
              {"level": 3, "bm": 16, "bn": 32, "bk": 32,
               "prefetch_depth": 2}, us=1.0)
    cache.save()
    tune_cache.preload()

    cfg = _tiny_cfg(dispatch="kernels")
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))

    try:
        with dispatch.stats_scope() as stats, \
                tune_cache.lookup_scope() as looks_fn:
            logits = model.prefill(params,
                                   {"tokens": jnp.zeros((2, 8), jnp.int32)})
            assert bool(jnp.all(jnp.isfinite(logits)))
            prefill_stats = stats()
            assert prefill_stats.get(("matmul", "kernel"), 0) > 0
            assert prefill_stats.get(("attention", "kernel"), 0) > 0
            looks = looks_fn()
            assert looks["exact"] > 0            # seeded tuned plan consumed
            assert sum(looks.values()) > 0

            server = Server(model, params, slots=2, max_len=16)
            nxt = server.step(np.zeros((2,), np.int32))
            assert nxt.shape == (2,)
            decode_stats = stats()
            # decode traced through dispatch too: projections on the kernel
            # route, the rolling-cache attention on the (mask) reference
            # route
            assert decode_stats.get(("matmul", "kernel"), 0) > \
                prefill_stats.get(("matmul", "kernel"), 0)
            assert decode_stats.get(("attention", "reference"), 0) > 0
    finally:
        monkeypatch.delenv("REPRO_TUNE_CACHE")
        tune_cache.preload()             # restore the repo default cache


def test_train_step_executes_through_dispatch():
    """One real train step (fwd + bwd + AdamW in one jit) with
    dispatch="kernels": the forward routes through the Pallas kernels
    (custom-VJP backward), the loss is finite, and the counters prove the
    graph flowed through dispatch."""
    from repro.optim.adamw import AdamWConfig
    from repro.train.steps import (TrainStepConfig, init_train_state,
                                   make_train_step)

    cfg = _tiny_cfg(dispatch="kernels")
    model = Model(cfg, dt=DtypePolicy(),
                  opts=ExecOptions(mode="run", block_q=8, block_kv=8,
                                   xent_chunks=2))
    ts = TrainStepConfig(opt=AdamWConfig(lr=1e-3))
    step = make_train_step(model, ts)
    params, opt = init_train_state(model, ts, jax.random.key(0))
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    with dispatch.stats_scope() as stats_fn:
        new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
        stats = stats_fn()
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert stats.get(("matmul", "kernel"), 0) > 0
    assert stats.get(("attention", "kernel"), 0) > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


def test_auto_policy_routes_reference_on_cpu():
    """On the CPU container, "auto" must pick the reference lowering (an
    interpreted Pallas kernel is never a win) — the default policy cannot
    regress existing CPU users."""
    assert jax.default_backend() == "cpu"
    x = jax.random.normal(KEY, (2, 8, 32), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (32, 16), jnp.float32)
    with dispatch.stats_scope() as stats:
        out = dispatch.matmul(x, w)              # policy=None -> auto
        assert stats() == {("matmul", "reference"): 1}
    _assert_close(out, dispatch.matmul(x, w, policy="reference"), "float32")
    # and the env/scope override flips it
    with dispatch.policy_scope("kernels"), dispatch.stats_scope() as stats:
        out2 = dispatch.matmul(x, w)
        assert stats() == {("matmul", "kernel"): 1}
    _assert_close(out2, out, "float32")
