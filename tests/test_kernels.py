"""Per-kernel interpret-mode validation against the pure-jnp oracles:
shape/dtype sweeps + hypothesis property tests (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.plan import Level
from repro.core.scaling import TilePlan
from repro.kernels.attention import flash_attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.histogram import histogram
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.nbody import nbody_accel
from repro.kernels.nbody.ref import nbody_accel_ref
from repro.kernels.stencil import jacobi4
from repro.kernels.stencil.ref import jacobi4_iter_ref

KEY = jax.random.key(0)


# ------------------------------------------------------------------ matmul
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384),
                                   (384, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(shape, dtype):
    n, k, m = shape
    a = jax.random.normal(KEY, (n, k), dtype)
    b = jax.random.normal(jax.random.key(1), (k, m), dtype)
    want = matmul_ref(a, b)
    plan = TilePlan(128, 128, 128, 0, (n // 128, m // 128, k // 128), 0, 0)
    got = matmul(a, b, level=Level.T3_REPLICATED, plan=plan)
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16
                               else 1e-5, atol=1e-2)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([128, 256]), st.sampled_from([128, 384]),
       st.sampled_from([128, 256]), st.integers(0, 2 ** 31 - 1))
def test_matmul_property(n, k, m, seed):
    a = jax.random.normal(jax.random.key(seed), (n, k), jnp.float32)
    b = jax.random.normal(jax.random.key(seed + 1), (k, m), jnp.float32)
    plan = TilePlan(128, 128, 128, 0, (n // 128, m // 128, k // 128), 0, 0)
    got = matmul(a, b, plan=plan)
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


def test_matmul_t0_matches_ref():
    a = jax.random.normal(KEY, (32, 48))
    b = jax.random.normal(jax.random.key(3), (48, 16))
    np.testing.assert_allclose(matmul(a, b, level=Level.T0_NAIVE),
                               a @ b, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- stencil
@pytest.mark.parametrize("shape,br", [((64, 128), 16), ((128, 256), 32),
                                      ((256, 128), 256)])
@pytest.mark.parametrize("steps", [1, 3])
def test_stencil_sweep(shape, br, steps):
    x = jax.random.normal(KEY, shape, jnp.float32)
    want = jacobi4_iter_ref(x, steps)
    got = jacobi4(x, steps=steps, block_rows=br)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_stencil_boundary_is_copied():
    x = jax.random.normal(KEY, (64, 128))
    got = jacobi4(x, steps=1, block_rows=16)
    np.testing.assert_allclose(got[0], x[0])
    np.testing.assert_allclose(got[-1], x[-1])
    np.testing.assert_allclose(got[:, 0], x[:, 0])
    np.testing.assert_allclose(got[:, -1], x[:, -1])


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_stencil_property_mean_preserving_interior(seed):
    # a constant field is a fixed point of the Jacobi update
    x = jnp.full((32, 128), float(seed % 7 + 1))
    got = jacobi4(x, steps=2, block_rows=8)
    np.testing.assert_allclose(got, x, rtol=1e-6)


# ------------------------------------------------------------------- nbody
@pytest.mark.parametrize("n,bt,bs", [(128, 32, 32), (256, 64, 128)])
def test_nbody_sweep(n, bt, bs):
    pos = jax.random.normal(KEY, (3, n), jnp.float32)
    mass = jax.random.uniform(jax.random.key(5), (n,), jnp.float32) + 0.1
    want = nbody_accel_ref(pos, mass)
    got = nbody_accel(pos, mass, block_targets=bt, block_sources=bs)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_nbody_momentum_conservation():
    # sum_i m_i a_i ~= 0 (Newton's third law) — physics property
    n = 128
    pos = jax.random.normal(KEY, (3, n), jnp.float32)
    mass = jax.random.uniform(jax.random.key(5), (n,), jnp.float32) + 0.1
    acc = nbody_accel(pos, mass, block_targets=64, block_sources=64)
    total = jnp.einsum("cn,n->c", acc, mass)
    scale = jnp.abs(jnp.einsum("cn,n->c", jnp.abs(acc), mass)).max()
    assert float(jnp.abs(total).max()) < 1e-3 * float(scale)


# --------------------------------------------------------------- histogram
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([64, 256]),
       st.sampled_from([1024, 4096]))
def test_histogram_property(seed, n_bins, n):
    vals = jax.random.randint(jax.random.key(seed), (n,), 0, n_bins,
                              jnp.int32)
    got = histogram(vals, n_bins)
    want = histogram_ref(vals, n_bins)
    np.testing.assert_array_equal(got, want)
    assert int(got.sum()) == n   # conservation


def test_histogram_concentrated():
    vals = jnp.full((2048,), 7, jnp.int32)
    got = histogram(vals, 256)
    assert int(got[7]) == 2048 and int(got.sum()) == 2048


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("s,bq,bkv", [(128, 32, 32), (256, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_sweep(s, bq, bkv, dtype, window):
    b, h, hd = 2, 3, 64
    q = jax.random.normal(KEY, (b, h, s, hd), dtype)
    k = jax.random.normal(jax.random.key(1), (b, h, s, hd), dtype)
    v = jax.random.normal(jax.random.key(2), (b, h, s, hd), dtype)
    want = attention_ref(q, k, v, causal=True, window=window)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=bq, block_kv=bkv)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_flash_attention_rows_are_convex_combinations(seed):
    # each output row lies in the convex hull of V rows: |out| <= max|v|
    b, h, s, hd = 1, 2, 128, 32
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd))
    k = jax.random.normal(ks[1], (b, h, s, hd))
    v = jax.random.normal(ks[2], (b, h, s, hd))
    out = flash_attention(q, k, v, block_q=32, block_kv=32)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4
