"""Optimizer, gradient compression, data pipeline, checkpoint tests."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM, make_pipeline
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, lr_schedule)
from repro.optim.compress import (CompressorConfig, compress_gradients,
                                  init_residual)

KEY = jax.random.key(0)


# ------------------------------------------------------------------ adamw
def _quadratic_params():
    return {"w": jnp.asarray([2.0, -3.0, 1.5]), "b": jnp.asarray(4.0)}


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=1000)
    params = _quadratic_params()
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(loss(params)) < 0.2 * l0


def test_adamw_first_step_matches_closed_form():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8,
                      weight_decay=0.0, grad_clip=1e9, warmup_steps=1,
                      total_steps=10)
    params = {"w": jnp.asarray([1.0, 2.0])}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.asarray([0.1, -0.2])}
    new_params, state, _ = adamw_update(grads, state, params, cfg)
    # after bias correction, first Adam step = -lr * sign-ish g/|g|
    step = np.asarray(new_params["w"] - params["w"])
    want = -1e-2 * np.asarray(grads["w"]) / (np.abs(grads["w"]) + 1e-8)
    np.testing.assert_allclose(step, want, rtol=1e-3)


def test_int8_moments_track_f32_trajectory():
    cfg32 = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                        total_steps=100)
    cfg8 = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                       total_steps=100, int8_moments=True)
    p32 = {"w": jnp.linspace(-1, 1, 256)}
    p8 = {"w": jnp.linspace(-1, 1, 256)}
    s32, s8 = adamw_init(p32, cfg32), adamw_init(p8, cfg8)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 0.5))

    for _ in range(30):
        p32, s32, _ = adamw_update(jax.grad(loss)(p32), s32, p32, cfg32)
        p8, s8, _ = adamw_update(jax.grad(loss)(p8), s8, p8, cfg8)
    # trajectories stay close AND both converge toward 0.5
    np.testing.assert_allclose(p8["w"], p32["w"], atol=5e-2)
    assert float(jnp.abs(p8["w"] - 0.5).mean()) \
        < 0.5 * float(jnp.abs(jnp.linspace(-1, 1, 256) - 0.5).mean())


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    np.testing.assert_allclose(
        jnp.linalg.norm(clipped["a"]), 1.0, rtol=1e-5)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[1] == pytest.approx(1.0, rel=1e-3)          # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)         # floor
    assert all(a >= b - 1e-6 for a, b in zip(lrs[1:], lrs[2:]))  # decay


# ----------------------------------------------------------- compression
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_error_feedback_preserves_signal(seed):
    """Over many steps, compressed-with-feedback gradients sum to (almost)
    the true gradient sum — the residual never diverges."""
    cfg = CompressorConfig(block=64, min_size=1)
    g_true = jax.random.normal(jax.random.key(seed), (512,)) * 0.01
    grads = {"w": g_true}
    residual = init_residual(grads)
    total = jnp.zeros_like(g_true)
    for _ in range(20):
        comp, residual = compress_gradients(grads, residual, cfg)
        total = total + comp["w"]
    np.testing.assert_allclose(total + residual["w"], 20 * g_true,
                               rtol=1e-4, atol=1e-5)
    assert float(jnp.abs(residual["w"]).max()) < 0.01  # bounded residual


def test_small_leaves_bypass_compression():
    cfg = CompressorConfig(min_size=1000)
    grads = {"tiny": jnp.arange(8.0)}
    res = init_residual(grads)
    comp, _ = compress_gradients(grads, res, cfg)
    np.testing.assert_array_equal(comp["tiny"], grads["tiny"])


# ------------------------------------------------------------------- data
def test_data_deterministic_and_shifted():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4)
    src = SyntheticLM(cfg)
    b1, b2 = src.batch_at(5), src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_disjoint():
    kw = dict(vocab_size=97, seq_len=8, global_batch=8, n_hosts=2)
    a = SyntheticLM(DataConfig(host_id=0, **kw)).batch_at(0)
    b = SyntheticLM(DataConfig(host_id=1, **kw)).batch_at(0)
    assert a["tokens"].shape == (4, 8)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_prefetches_in_background():
    cfg = DataConfig(vocab_size=31, seq_len=4, global_batch=2, prefetch=3)
    stop = threading.Event()
    it = make_pipeline(cfg, stop_event=stop)
    batches = [next(it) for _ in range(5)]
    want = SyntheticLM(cfg).batch_at(2)
    np.testing.assert_array_equal(batches[2]["tokens"], want["tokens"])
    stop.set()


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"w": jnp.arange(8.0), "opt": {"m": jnp.ones((3,))}}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda a: a + step, state))
    assert mgr.steps() == [20, 30]                 # keep=2 GC'd step 10
    restored, step, _ = mgr.restore(state)
    assert step == 30
    np.testing.assert_array_equal(restored["w"], jnp.arange(8.0) + 30)


def test_checkpoint_atomicity_ignores_torn_dirs(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, {"w": jnp.ones(4)})
    torn = tmp_path / "step_00000009"
    torn.mkdir()                                   # no manifest => torn
    assert mgr.latest_step() == 5
    restored, step, _ = mgr.restore({"w": jnp.zeros(4)})
    assert step == 5


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(1, {"w": jnp.full((4,), 7.0)})
    mgr.wait()
    restored, step, _ = mgr.restore({"w": jnp.zeros(4)})
    np.testing.assert_array_equal(restored["w"], jnp.full((4,), 7.0))
