"""Load-generator and serving-metrics unit tests (no model involved).

The continuous-batching engine is only as reproducible as its inputs and
only as honest as its summaries, so these layers get direct coverage:
seeded stream determinism, trace parsing, arrival-queue ordering, and the
TTFT / per-token-latency percentile math."""
import numpy as np
import pytest

from repro.launch.loadgen import (ArrivalQueue, Request, poisson_stream,
                                  trace_stream)
from repro.launch.metrics import ServeMetrics


# ------------------------------------------------------------------ loadgen
def test_poisson_stream_is_seed_deterministic():
    a = poisson_stream(6, rate=3.0, vocab_size=100, prompt_len=4,
                       max_new=2, seed=42, prompt_jitter=2)
    b = poisson_stream(6, rate=3.0, vocab_size=100, prompt_len=4,
                       max_new=2, seed=42, prompt_jitter=2)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    c = poisson_stream(6, rate=3.0, vocab_size=100, prompt_len=4,
                       max_new=2, seed=43, prompt_jitter=2)
    assert [r.arrival for r in a] != [r.arrival for r in c]


def test_poisson_stream_shapes_and_monotone_arrivals():
    reqs = poisson_stream(8, rate=2.0, vocab_size=50, prompt_len=5,
                          max_new=3, seed=1, prompt_jitter=3, start_rid=10)
    assert [r.rid for r in reqs] == list(range(10, 18))
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr) and arr[0] > 0.0
    assert all(5 <= len(r.prompt) <= 8 for r in reqs)
    assert all(r.prompt.min() >= 0 and r.prompt.max() < 50 for r in reqs)


def test_poisson_rate_zero_is_a_burst():
    reqs = poisson_stream(4, rate=0.0, vocab_size=50, prompt_len=5,
                          max_new=3, seed=0)
    assert all(r.arrival == 0.0 for r in reqs)


def test_poisson_shared_prefix_stream_is_deterministic():
    kw = dict(rate=0.0, vocab_size=100, prompt_len=10, max_new=2, seed=3,
              shared_prefix_len=6, shared_frac=1.0)
    reqs = poisson_stream(8, **kw)
    prefix = list(reqs[0].prompt[:6])
    assert all(list(r.prompt[:6]) == prefix for r in reqs)
    assert all(len(r.prompt) == 10 for r in reqs)
    assert len({tuple(r.prompt[6:]) for r in reqs}) == 8  # unique tails
    for a, b in zip(reqs, poisson_stream(8, **kw)):
        np.testing.assert_array_equal(a.prompt, b.prompt)


def test_poisson_shared_prefix_frac_mixes_carriers():
    reqs = poisson_stream(40, rate=0.0, vocab_size=100, prompt_len=8,
                          max_new=2, seed=1, shared_prefix_len=4,
                          shared_frac=0.5)
    prefixes = [tuple(r.prompt[:4]) for r in reqs]
    common = max(set(prefixes), key=prefixes.count)
    assert 10 < prefixes.count(common) < 30     # ~half carry the prefix


def test_poisson_shared_prefix_disabled_matches_legacy_stream():
    """shared_prefix_len=0 must not perturb the rng draw sequence: the
    stream is bit-identical to a call without the sharing kwargs."""
    kw = dict(rate=2.0, vocab_size=50, prompt_len=6, max_new=2, seed=9)
    a = poisson_stream(5, **kw)
    b = poisson_stream(5, **kw, shared_prefix_len=0, shared_frac=0.9)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.prompt, rb.prompt)


def test_poisson_shared_prefix_longer_than_prompt_rejected():
    with pytest.raises(ValueError, match="shared_prefix_len"):
        poisson_stream(2, rate=0.0, vocab_size=50, prompt_len=4,
                       max_new=1, seed=0, shared_prefix_len=5,
                       shared_frac=1.0)


def test_trace_stream_parses_events():
    trace = [{"t": 1.5, "prompt_len": 3, "max_new": 2},
             {"tokens": [7, 8, 9, 10], "max_new": 5},
             {"t": 0.25, "prompt_len": 2, "max_new": 1}]
    reqs = trace_stream(trace, vocab_size=20, seed=0)
    assert [r.rid for r in reqs] == [0, 1, 2]
    assert [r.arrival for r in reqs] == [1.5, 0.0, 0.25]
    assert [r.max_new for r in reqs] == [2, 5, 1]
    np.testing.assert_array_equal(reqs[1].prompt, [7, 8, 9, 10])
    assert len(reqs[0].prompt) == 3 and reqs[0].prompt.max() < 20


def test_arrival_queue_orders_and_pops_ready_prefix():
    reqs = [Request(0, np.array([1]), 1, arrival=2.0),
            Request(1, np.array([1]), 1, arrival=0.5),
            Request(2, np.array([1]), 1, arrival=0.5),   # tie: keep order
            Request(3, np.array([1]), 1, arrival=5.0)]
    q = ArrivalQueue(reqs)
    assert len(q) == 4
    assert q.next_arrival() == 0.5
    assert [r.rid for r in q.pop_ready(0.0)] == []
    assert [r.rid for r in q.pop_ready(1.0)] == [1, 2]   # stable FCFS tie
    assert q.next_arrival() == 2.0
    assert [r.rid for r in q.pop_ready(10.0)] == [0, 3]
    assert len(q) == 0 and q.next_arrival() is None


# ------------------------------------------------------------------ metrics
def test_metrics_ttft_counts_queueing_delay():
    m = ServeMetrics()
    m.on_arrival(0, 1.0)
    m.on_admit(0, 3.0)           # waited 2 units in the queue
    m.on_token(0, 4.0)           # TTFT = 4.0 - 1.0, NOT 4.0 - 3.0
    assert m.ttfts() == [3.0]


def test_metrics_token_gaps_are_per_request():
    m = ServeMetrics()
    for rid, times in ((0, [1.0, 2.0, 4.0]), (1, [10.0, 10.5])):
        m.on_arrival(rid, 0.0)
        for t in times:
            m.on_token(rid, t)
    # gaps within a request only — never across requests
    assert sorted(m.token_gaps()) == [0.5, 1.0, 2.0]


def test_metrics_percentiles_and_summary():
    m = ServeMetrics()
    for rid in range(4):
        m.on_arrival(rid, float(rid))
        m.on_token(rid, float(rid) + 1.0)
        m.on_token(rid, float(rid) + 2.0)
        m.on_finish(rid, float(rid) + 2.0)
    m.on_arrival(99, 0.0)
    m.on_reject(99, 0.0)
    s = m.summary()
    assert s["requests_finished"] == 4
    assert s["requests_rejected"] == 1
    assert s["new_tokens"] == 8
    assert s["ttft_p50"] == pytest.approx(1.0)
    assert s["tok_latency_p50"] == pytest.approx(1.0)
    assert s["clock_span"] == pytest.approx(5.0)    # first arrival 0 .. 5


def test_metrics_empty_summary_is_none_not_nan():
    s = ServeMetrics().summary()
    assert s["requests_finished"] == 0
    assert s["ttft_p50"] is None and s["tok_latency_p99"] is None
    assert s["clock_span"] is None


def test_metrics_percentile_helper():
    assert ServeMetrics.percentile([], 99) is None
    assert ServeMetrics.percentile([2.0], 50) == 2.0
    vals = [1.0, 2.0, 3.0, 4.0]
    assert ServeMetrics.percentile(vals, 50) == pytest.approx(2.5)
    assert ServeMetrics.percentile(vals, 99) <= 4.0


def test_metrics_percentile_single_sample_every_quantile():
    # one sample answers every quantile with itself — never NaN, never an
    # index error at the q=0/q=100 extremes
    for q in (0, 1, 50, 99, 100):
        assert ServeMetrics.percentile([3.25], q) == 3.25


def test_metrics_percentile_ignores_input_order():
    shuffled = [4.0, 1.0, 3.0, 2.0]
    for q in (1, 50, 99):
        assert ServeMetrics.percentile(shuffled, q) == pytest.approx(
            ServeMetrics.percentile(sorted(shuffled), q))
    assert ServeMetrics.percentile(shuffled, 50) == pytest.approx(2.5)


def test_metrics_degenerate_distribution_p50_equals_p99():
    """All-equal samples collapse the whole distribution to one point:
    p50 == p99 is legitimate, not a sign of a broken summary."""
    m = ServeMetrics()
    for rid in range(3):
        m.on_arrival(rid, 0.0)
        m.on_token(rid, 1.0)     # every TTFT exactly 1.0
        m.on_token(rid, 2.0)     # every gap exactly 1.0
        m.on_finish(rid, 2.0)
    s = m.summary()
    assert s["ttft_p50"] == s["ttft_p99"] == pytest.approx(1.0)
    assert s["tok_latency_p50"] == s["tok_latency_p99"] == pytest.approx(1.0)


def test_metrics_single_token_request_has_no_gaps():
    # a max_new == 1 request produces a TTFT but zero inter-token gaps;
    # the summary must report None for gap percentiles, not NaN or 0.0
    m = ServeMetrics()
    m.on_arrival(0, 0.0)
    m.on_token(0, 2.0)
    m.on_finish(0, 2.0)
    s = m.summary()
    assert s["ttft_p50"] == pytest.approx(2.0)
    assert s["tok_latency_p50"] is None and s["tok_latency_p99"] is None
    assert s["new_tokens"] == 1


def test_metrics_spec_counters_default_none_and_accumulate():
    m = ServeMetrics()
    s = m.summary()
    assert s["spec_accept_rate"] is None          # no drafter: absent,
    assert s["spec_tokens_per_step"] is None      # not 0.0 or NaN
    m.on_spec_step(drafted=3, accepted=2, emitted=3)
    m.on_spec_step(drafted=3, accepted=0, emitted=1)
    m.on_spec_step(drafted=0, accepted=0, emitted=1)  # no-draft verify
    s = m.summary()
    assert s["spec_accept_rate"] == pytest.approx(2 / 6)
    assert s["spec_tokens_per_step"] == pytest.approx(5 / 3)
