"""Fault tolerance: injected failures + restore reproduce the uninterrupted
run; straggler detection; elastic resharding across device counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (FailureInjector, InjectedFailure,
                                           StragglerWatch, Supervisor)
from helpers import run_multidevice

pytestmark = pytest.mark.slow   # multi-device subprocess tests


def _step_factory():
    """A deterministic toy 'training': state = (w, step_count)."""
    def step_fn(state, step):
        w = state["w"]
        g = jnp.sin(w + step)       # pseudo-gradient derived from step
        w = w - 0.1 * g
        return {"w": w}, {"loss": float(jnp.sum(jnp.square(w)))}
    return step_fn


def _run(n_steps, tmp_path, fail_steps=(), save_every=3):
    ckpt = CheckpointManager(tmp_path, keep=5)
    injector = FailureInjector(fail_steps) if fail_steps else None
    sup = Supervisor(ckpt, save_every=save_every, injector=injector)
    state = {"w": jnp.linspace(-1, 1, 8)}
    final, _ = sup.run(state, _step_factory(), n_steps)
    return final, sup


def test_supervisor_recovers_exactly(tmp_path):
    clean, _ = _run(20, tmp_path / "clean")
    faulty, sup = _run(20, tmp_path / "faulty", fail_steps=(7, 13))
    assert sup.restarts == 2
    np.testing.assert_allclose(clean["w"], faulty["w"], rtol=1e-6)


def test_supervisor_escalates_after_max_restarts(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    injector = FailureInjector(list(range(100)))  # always fails

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            raise InjectedFailure("boom")

    sup = Supervisor(ckpt, save_every=5, max_restarts=3,
                     injector=AlwaysFail())
    with pytest.raises(InjectedFailure):
        sup.run({"w": jnp.zeros(2)}, _step_factory(), 10)
    assert sup.restarts == 4


def test_straggler_watch_flags_outliers():
    w = StragglerWatch(window=16, k=3.0)
    for i in range(12):
        assert not w.observe(i, 1.0 + 0.01 * (i % 3))
    assert w.observe(12, 5.0)          # 5x the median
    assert not w.observe(13, 1.01)
    assert len(w.flags) == 1


def test_elastic_reshard_8_to_4_devices():
    """Train on an (4,2) mesh, checkpoint, restore onto (2,2) — losses of
    the continued run match a never-resharded run."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.runtime.sharding import make_rules, tree_shardings
        from repro.runtime.elastic import restore_on_mesh
        from jax.sharding import Mesh

        devs = jax.devices()
        from repro.launch.mesh import make_mesh
        mesh8 = make_mesh((4, 2), ("data", "model"))
        mesh4 = Mesh(np.asarray(devs[:4]).reshape(2, 2), ("data", "model"))

        state = {"layer.mlp.wg": jnp.arange(64.0).reshape(8, 8),
                 "step": jnp.zeros(())}
        r8 = make_rules(mesh8)
        sh8 = tree_shardings(r8, state)
        placed = jax.device_put(state, sh8)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(3, placed)
            r4 = make_rules(mesh4)
            restored, step, _ = restore_on_mesh(mgr, state, r4)
            assert step == 3
            np.testing.assert_array_equal(
                np.asarray(restored["layer.mlp.wg"]),
                np.arange(64.0).reshape(8, 8))
            # leaf really lives on the 4-device mesh
            assert restored["layer.mlp.wg"].sharding.mesh.devices.size == 4
        print("ELASTIC-OK")
    """)
    assert "ELASTIC-OK" in out
