"""Speculative decoding: draft -> verify -> accept/rollback tests.

Three layers, mirroring the subsystem's structure:

1. unit — ``accept_longest_prefix`` semantics, the model-free
   ``NgramDrafter``, and ``make_draft_config`` truncation, no model in
   the loop;
2. differential — greedy speculative streams must be BIT-IDENTICAL to
   the non-speculative baseline for both drafters, on both the static
   ``run_speculative`` path and the continuous-batching engine path
   (the acceptance rule guarantees this: the bonus token of an empty
   acceptance IS the plain decode argmax);
3. runtime properties — the verify forward routes through the existing
   ragged ``prefill_attention`` kernel (zero new kernels), and host
   rollback keeps ``check_page_accounting`` honest under the full
   composition: oversubscribed pool + prefix-sharing CoW + int8 KV +
   sliding-window reclamation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.core.memory import DtypePolicy
from repro.kernels import dispatch
from repro.launch.engine import ContinuousEngine
from repro.launch.serve import PagedScheduler, Request
from repro.launch.speculative import (NgramDrafter, accept_longest_prefix,
                                      make_draft_config, make_drafter)
from repro.models.transformer import ExecOptions, Model


def _tiny_cfg(name, **overrides):
    cfg = ARCHS[name].smoke()
    return dataclasses.replace(
        cfg, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
        vocab_size=128, n_experts=min(cfg.n_experts, 4) or 0,
        **overrides)


def _make_scheduler(slots=2, max_len=32, page=4, total_pages=0,
                    arch="gemma-2b", dispatch_policy="reference",
                    kv_dtype="", prefix_cache=False, all_swa=False):
    cfg = _tiny_cfg(arch, dispatch=dispatch_policy, kv_dtype=kv_dtype)
    if all_swa:   # every layer windowed -> sliding-window reclamation on
        cfg = cfg.with_layers((("swa", "mlp"),) * 2)
    model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    sched = PagedScheduler(model, params, slots=slots, max_len=max_len,
                           page_size=page, total_pages=total_pages,
                           prefix_cache=prefix_cache,
                           log=lambda *a, **k: None)
    return sched, cfg


def _prompts(n, rng_seed=5, lo=3, hi=9):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(0, 128, rng.integers(lo, hi)) for _ in range(n)]


# ------------------------------------------------------------------- units
def test_accept_longest_prefix_semantics():
    # no drafts: the bonus token is exactly the plain decode argmax
    assert accept_longest_prefix([], np.array([7])) == [7]
    # full acceptance: all drafts confirmed + bonus from the last row
    assert accept_longest_prefix([1, 2], np.array([1, 2, 9])) == [1, 2, 9]
    # partial: second draft disagrees with row 1's prediction -> the
    # prediction itself is emitted in its place, nothing after
    assert accept_longest_prefix([1, 5], np.array([1, 2, 9])) == [1, 2]
    # immediate mismatch: emit only row 0's prediction (>= 1 token/step)
    assert accept_longest_prefix([4, 5], np.array([1, 2, 3])) == [1]


def test_ngram_drafter_replays_most_recent_suffix_match():
    d = NgramDrafter(max_draft=3, n=3)
    # suffix [1,2,3] occurred at the start: replay what followed it
    assert d.propose([[1, 2, 3, 9, 1, 2, 3]]) == [[9, 1, 2]]
    # order fallback to n=1: [5] matched, propose its continuation
    assert d.propose([[5, 6, 5]]) == [[6, 5]]
    # no earlier occurrence at any order -> no drafts (plain decode step)
    assert d.propose([[1, 2, 3, 4]]) == [[]]
    assert d.propose([[], [7]]) == [[], []]    # degenerate histories
    with pytest.raises(ValueError, match="max_draft"):
        NgramDrafter(max_draft=-1)


def test_make_draft_config_truncates_leading_layers():
    cfg = _tiny_cfg("gemma3-4b")               # 3-layer smoke stack
    kinds = cfg.layer_kinds()
    half = make_draft_config(cfg)
    assert half.layer_kinds() == kinds[:max(1, len(kinds) // 2)]
    assert half.name == cfg.name + "-draft"
    assert half.vocab_size == cfg.vocab_size
    two = make_draft_config(cfg, n_layers=2)
    assert two.layer_kinds() == kinds[:2]


def test_make_drafter_rejects_unknown_kind_and_vocab_mismatch():
    cfg = _tiny_cfg("gemma-2b")
    with pytest.raises(ValueError, match="unknown drafter"):
        make_drafter("medusa", cfg)
    other = Model(dataclasses.replace(_tiny_cfg("gemma-2b"), vocab_size=64),
                  dt=DtypePolicy(compute=jnp.float32),
                  opts=ExecOptions(mode="run"))
    with pytest.raises(ValueError, match="vocab"):
        make_drafter("model", cfg, model=other,
                     params=other.init(jax.random.key(0)))


# ----------------------------------------------------------- differentials
def test_static_ngram_speculative_matches_baseline_exactly():
    """run_speculative with the n-gram drafter emits bit-identical greedy
    streams to run(), including slot recycling across > slots requests."""
    prompts = _prompts(5)
    base, _ = _make_scheduler(slots=2)
    want = {r.rid: list(r.out)
            for r in base.run([Request(i, p, 6)
                               for i, p in enumerate(prompts)])}

    spec, _ = _make_scheduler(slots=2)
    got = {r.rid: list(r.out)
           for r in spec.run_speculative(
               [Request(i, p.copy(), 6) for i, p in enumerate(prompts)],
               NgramDrafter(max_draft=3))}
    assert got == want
    assert spec.verify_steps > 0
    # structural floor: every verify step emits at least one token
    assert spec.spec_emitted >= spec.verify_steps
    # prefill emits each request's first token; verify emits the rest
    assert spec.spec_emitted == sum(len(o) - 1 for o in got.values())


def test_static_model_drafter_matches_baseline_and_accepts():
    """A full-depth draft sibling initialized from the target's rng key
    IS the target (Model.init folds the key per layer index), so drafts
    agree with verify and acceptance is exercised — while the stream
    stays bit-identical to the baseline by the acceptance rule alone."""
    prompts = _prompts(4, rng_seed=8)
    base, cfg = _make_scheduler(slots=2)
    want = {r.rid: list(r.out)
            for r in base.run([Request(i, p, 5)
                               for i, p in enumerate(prompts)])}

    spec, cfg = _make_scheduler(slots=2)
    drafter = make_drafter("model", cfg, max_draft=2,
                           draft_layers=len(cfg.layer_kinds()),
                           dt=DtypePolicy(compute=jnp.float32),
                           rng_key=jax.random.key(0),
                           pad_to=spec.max_len + 2, batch_pad=spec.slots)
    got = {r.rid: list(r.out)
           for r in spec.run_speculative(
               [Request(i, p.copy(), 5) for i, p in enumerate(prompts)],
               drafter)}
    assert got == want
    assert spec.spec_drafted > 0 and spec.spec_accepted > 0


def test_engine_speculative_matches_baseline_both_drafters():
    """Continuous-batching path: an engine with a drafter produces the
    same streams as the plain engine, and the spec metrics populate."""
    prompts = _prompts(4, rng_seed=13)

    def serve(drafter):
        sched, cfg = _make_scheduler(slots=2)
        engine = ContinuousEngine(sched, clock="tick", drafter=drafter,
                                  log=None)
        done = engine.run([Request(i, p.copy(), 5)
                           for i, p in enumerate(prompts)])
        return {r.rid: list(r.out) for r in done}, engine, cfg

    want, plain, cfg = serve(None)
    assert plain.metrics.summary()["spec_accept_rate"] is None

    for drafter in (NgramDrafter(max_draft=3),
                    make_drafter("model", cfg, max_draft=2,
                                 dt=DtypePolicy(compute=jnp.float32),
                                 rng_key=jax.random.key(0),
                                 pad_to=34, batch_pad=2)):
        got, engine, _ = serve(drafter)
        assert got == want, f"{drafter.name} stream diverged"
        s = engine.metrics.summary()
        assert s["spec_tokens_per_step"] is not None
        assert s["spec_tokens_per_step"] >= 1.0   # >= 1 token per verify
        assert engine.sched.verify_steps > 0


# ------------------------------------------------------ runtime properties
def test_verify_routes_through_prefill_attention_kernel():
    """The verify forward is the ragged prefill op under kernels dispatch
    — zero new kernels; the route counters are the proof."""
    sched, _ = _make_scheduler(slots=1, dispatch_policy="kernels")
    with dispatch.stats_scope() as stats:
        done = sched.run_speculative(
            [Request(0, np.arange(6) % 128, 4)], NgramDrafter(max_draft=2))
        counts = stats()
    assert len(done) == 1 and done[0].done
    assert counts.get(("prefill_attention", "kernel"), 0) > 0
    assert counts.get(("prefill_attention", "reference"), 0) == 0


def test_rollback_accounting_oversubscribed_int8_prefix_sharing():
    """The stress composition: all-swa stack (sliding-window reclamation
    live), int8 KV (per-page scale rows), prefix-sharing CoW, and an
    oversubscribed pool — check_page_accounting asserts inside every
    scheduler mutation including post-rollback, and streams still match
    the non-speculative scheduler under the same pressure."""
    rng = np.random.default_rng(21)
    base_prompt = rng.integers(0, 128, 16)
    prompts = [base_prompt, base_prompt.copy(),          # sharers
               rng.integers(0, 128, 12), rng.integers(0, 128, 8),
               base_prompt.copy(), rng.integers(0, 128, 10)]
    kw = dict(slots=3, max_len=32, page=4, total_pages=15,
              arch="gemma3-4b", all_swa=True, kv_dtype="int8",
              prefix_cache=True)

    ref, _ = _make_scheduler(**kw)
    assert ref.window > 0, "all-swa stack should enable reclamation"
    want = {r.rid: list(r.out)
            for r in ref.run([Request(i, p, 6)
                              for i, p in enumerate(prompts)])}

    spec, _ = _make_scheduler(**kw)
    done = spec.run_speculative(
        [Request(i, p.copy(), 6) for i, p in enumerate(prompts)],
        NgramDrafter(max_draft=3))
    got = {r.rid: list(r.out) for r in done}
    assert got == want
    assert len(done) == len(prompts) and all(r.done for r in done)
    spec.check_page_accounting()               # final post-rollback state
    assert spec.pages_reclaimed > 0            # window reclaim interleaved
    assert spec.shared_tokens_total > 0        # prefix hits interleaved
    # rollback never leaves a slot claiming more tokens than its pages
    assert all(r is None for r in spec.active)


def test_speculative_cow_through_prepare_verify():
    """A fully-covered sharer's verify window appends into published
    pages: prepare_verify must copy-on-write before the batched write,
    preserving both the sharer's stream and the published pages."""
    rng = np.random.default_rng(3)
    base_prompt = rng.integers(0, 128, 16)
    kw = dict(slots=2, max_len=32, page=4, prefix_cache=True)

    ref, _ = _make_scheduler(**kw)
    want = {r.rid: list(r.out)
            for r in ref.run([Request(0, base_prompt, 4),
                              Request(1, base_prompt.copy(), 4)])}

    spec, _ = _make_scheduler(**kw)
    # serve sequentially through one admission each so the publisher's
    # prefix is in the trie before the repeat admits (same-tick twins
    # both admit pre-publication and nothing would be shared)
    out = {}
    for rid in (0, 1):
        done = spec.run_speculative(
            [Request(rid, base_prompt.copy(), 4)], NgramDrafter(max_draft=3))
        out[rid] = list(done[0].out)
    assert out == want
    assert spec.shared_tokens_total == 16      # repeat fully covered
    assert spec.cow_copies >= 1                # divergence copied, not aliased
    spec.check_page_accounting()
