"""Ragged multi-token prefill attention tests.

Mirrors the decode-kernel discipline (tests/test_paged_decode.py):

1. kernel differential — the Pallas ragged prefill kernel against the
   gather-and-mask reference, for every attention arch's own geometry
   (GQA groups, sliding windows) x {fp32, bf16} x chunk offsets covering
   the first chunk (empty history), mid-prompt, and the last chunk;
2. invariances — KV-tile geometry (pages_per_tile, incl. non-divisors of
   n_pages) is a pure performance knob; the last chunk's padded tail is
   hidden by causality;
3. route level — chunked prefill through the serve scheduler fires the
   ``(prefill_attention, kernel)`` counter under ``--dispatch kernels``
   and its logits match the dense reference forward (the acceptance
   probe for the op registered end-to-end through the registry).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.core.memory import DtypePolicy
from repro.kernels import dispatch
from repro.models.transformer import ExecOptions, Model, paged_supported
from repro.tune import cache as tune_cache

DTYPES = {
    "float32": DtypePolicy(compute=jnp.float32),
    "bfloat16": DtypePolicy(),
}
TOLS = {
    "float32": dict(rtol=2e-4, atol=2e-4),
    "bfloat16": dict(rtol=5e-2, atol=5e-2),
}


def _assert_close(got, want, dtype_name, msg=""):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               err_msg=msg, **TOLS[dtype_name])


def _prefill_inputs(n_heads, n_kv_heads, hd, dtype, *, slots=3, chunk=8,
                    page=8, n_pages=4):
    pool = 1 + slots * n_pages
    ks = jax.random.split(jax.random.key(0), 3)
    q = (0.5 * jax.random.normal(ks[0], (slots, chunk, n_heads, hd),
                                 jnp.float32)).astype(dtype)
    kp = (0.5 * jax.random.normal(ks[1], (pool, page, n_kv_heads, hd),
                                  jnp.float32)).astype(dtype)
    vp = (0.5 * jax.random.normal(ks[2], (pool, page, n_kv_heads, hd),
                                  jnp.float32)).astype(dtype)
    rng = np.random.default_rng(0)
    table = jnp.asarray(
        1 + rng.permutation(pool - 1)[:slots * n_pages].reshape(
            slots, n_pages), jnp.int32)
    # first chunk (no history), mid-prompt, last chunk of the table
    starts = jnp.asarray([0, page, (n_pages - 1) * page], jnp.int32)
    return q, kp, vp, table, starts


@pytest.fixture
def empty_plan_cache(tmp_path, monkeypatch):
    """The repo cache may hold a (CPU-tuned) level-1 prefill plan, which
    would resolve the kernel route to the reference lowering under "auto"
    — the differential must drive the actual Pallas kernel."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "empty.json"))
    tune_cache.preload()
    yield
    monkeypatch.delenv("REPRO_TUNE_CACHE")
    tune_cache.preload()


# ---------------------------------------------------- kernel differential
@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_attention_differential(arch, dtype_name, empty_plan_cache):
    """Kernel route == reference route for the arch's own attention
    geometry over chunk offsets (causal intra-chunk masking, GQA,
    windows)."""
    cfg = ARCHS[arch].smoke()
    mixers = {m for m, _ in cfg.layer_kinds()}
    if not ({"attn", "swa"} & mixers):
        pytest.skip("attention-free arch")
    window = cfg.window if "swa" in mixers else 0
    dt = DTYPES[dtype_name]
    q, kp, vp, table, starts = _prefill_inputs(
        cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt.compute)
    with dispatch.stats_scope() as stats:
        got = dispatch.prefill_attention(q, kp, vp, table, starts,
                                         window=window, policy="kernels")
        want = dispatch.prefill_attention(q, kp, vp, table, starts,
                                          window=window, policy="reference")
        s = stats()
    assert got.dtype == want.dtype
    _assert_close(got, want, dtype_name)
    assert s[("prefill_attention", "kernel")] == 1
    assert s[("prefill_attention", "reference")] == 1


# Accuracy bound for the int8 KV path (see test_paged_decode.py for the
# decode twin): prefill attends over up to a whole table of quantized
# history, so its noise bound is the same documented 5e-2 — measured
# ~2e-2 on these geometries, still orders of magnitude below any
# wrong-scale bug.
INT8_KV_MAX_ABS_ERR = 5e-2


def test_prefill_attention_int8_differential(empty_plan_cache):
    """int8 pools + per-page scales through the ragged prefill kernel:
    in-tile dequant agrees with the dequantizing reference at fp32
    tolerance; both stay within the quantization-noise bound of the
    fp32 oracle."""
    from repro.core import quant
    cfg = ARCHS["gemma-2b"].smoke()
    q, kp, vp, table, starts = _prefill_inputs(
        cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, jnp.float32)
    kq, ks = quant.quantize_pages(kp)
    vq, vs = quant.quantize_pages(vp)
    with dispatch.stats_scope() as stats:
        got = dispatch.prefill_attention(q, kq, vq, table, starts, ks, vs,
                                         policy="kernels")
        oracle = dispatch.prefill_attention(q, kq, vq, table, starts,
                                            ks, vs, policy="reference")
        s = stats()
    _assert_close(got, oracle, "float32")
    full = dispatch.prefill_attention(q, kp, vp, table, starts,
                                      policy="reference")
    err = float(jnp.max(jnp.abs(got - full)))
    assert err < INT8_KV_MAX_ABS_ERR, (
        f"int8 prefill error {err} exceeds bound {INT8_KV_MAX_ABS_ERR}")
    assert s[("prefill_attention", "kernel")] == 1


def test_prefill_pages_per_tile_invariant():
    """KV-tile geometry is a pure performance knob: every pages_per_tile
    (incl. non-divisors of n_pages -> padded tail tiles) agrees."""
    from repro.kernels.attention import prefill_attention as prefill_op
    q, kp, vp, table, starts = _prefill_inputs(4, 2, 16, jnp.float32)
    base = prefill_op(q, kp, vp, table, starts, pages_per_tile=1)
    for ppt in (2, 3, 4, 16):
        got = prefill_op(q, kp, vp, table, starts, pages_per_tile=ppt)
        _assert_close(got, base, "float32", f"ppt={ppt}")


def test_prefill_first_chunk_matches_pure_causal_attention():
    """A chunk at start=0 with its own K/V written into the pages is
    plain causal self-attention — check against the flash oracle."""
    from repro.kernels.attention import ref
    chunk, h, hd, page = 8, 4, 16, 8
    ks = jax.random.split(jax.random.key(3), 3)
    q, k, v = (0.5 * jax.random.normal(kk, (1, chunk, h, hd), jnp.float32)
               for kk in ks)
    pool = jnp.zeros((3, page, h, hd), jnp.float32)
    kp = pool.at[1].set(k[0])
    vp = pool.at[1].set(v[0])
    table = jnp.asarray([[1, 0]], jnp.int32)
    out = dispatch.prefill_attention(q, kp, vp, table,
                                     jnp.asarray([0], jnp.int32),
                                     policy="kernels")
    want = ref.attention_ref(q.transpose(0, 2, 1, 3),
                             k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True)
    _assert_close(out, want.transpose(0, 2, 1, 3), "float32")


def test_prefill_padded_tail_hidden_by_causality():
    """Garbage K/V beyond the last real token (the padded final chunk)
    must not leak into real positions' outputs — causality hides it."""
    q, kp, vp, table, _ = _prefill_inputs(4, 2, 16, jnp.float32, slots=1,
                                          n_pages=2)
    starts = jnp.asarray([8], jnp.int32)
    base = dispatch.prefill_attention(q, kp, vp, table, starts,
                                      policy="kernels")
    # trash everything at positions > the chunk's last real token: the
    # pages beyond the chunk's own page (there are none here) and nothing
    # else — instead, poison a *later* logical page mapped by the table
    kp2 = kp.at[table[0, 1], 4:].set(1e3)   # positions 12.. of the chunk
    vp2 = vp.at[table[0, 1], 4:].set(1e3)
    got = dispatch.prefill_attention(q, kp2, vp2, table, starts,
                                     policy="kernels")
    # rows 0..3 (positions 8..11) never see positions 12..15
    _assert_close(got[:, :4], base[:, :4], "float32")


def test_prefill_tuned_plan_consumed(tmp_path, monkeypatch):
    """A seeded exact-shape prefill plan is picked up by the kernel route
    (lookup counters + plan-source tags prove the cache was consulted)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    q, kp, vp, table, starts = _prefill_inputs(4, 2, 16, jnp.float32)
    shape = (q.shape[0], q.shape[1], q.shape[2], table.shape[1],
             kp.shape[1], q.shape[3])
    cache = tune_cache.PlanCache(tmp_path / "plans.json")
    cache.put("prefill_attention", shape, jnp.float32,
              {"level": 3, "page_size": kp.shape[1], "pages_per_tile": 2,
               "prefetch_depth": 2}, us=1.0)
    cache.save()
    tune_cache.preload()
    try:
        with tune_cache.lookup_scope() as looks, \
                dispatch.stats_scope() as stats:
            got = dispatch.prefill_attention(q, kp, vp, table, starts,
                                             policy="kernels")
            assert looks()["exact"] == 1
            assert stats()[("prefill_attention", "kernel")] == 1
            assert dispatch.plan_source_stats().get(
                ("prefill_attention", "kernel", "exact"), 0) == 1
        want = dispatch.prefill_attention(q, kp, vp, table, starts,
                                          policy="reference")
        _assert_close(got, want, "float32")
    finally:
        monkeypatch.delenv("REPRO_TUNE_CACHE")
        tune_cache.preload()


# ------------------------------------------------------------ route level
def _tiny_cfg(name, **overrides):
    cfg = ARCHS[name].smoke()
    return dataclasses.replace(
        cfg, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
        vocab_size=128, n_experts=min(cfg.n_experts, 4) or 0,
        **overrides)


@pytest.mark.parametrize("arch", ["gemma-2b", "gemma3-4b"])
def test_paged_serve_prefill_takes_kernel_route(arch):
    """The acceptance probe: chunked prefill through the PagedScheduler
    with dispatch="kernels" fires (prefill_attention, kernel) — across a
    global-causal arch and a sliding-window arch — and the generated
    tokens match a pure-reference scheduler run."""
    from repro.launch.serve import PagedScheduler, Request
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 128, 9), rng.integers(0, 128, 5)]

    outs = {}
    for policy in ("kernels", "reference"):
        cfg = _tiny_cfg(arch, dispatch=policy)
        assert paged_supported(cfg)
        model = Model(cfg, dt=DtypePolicy(compute=jnp.float32),
                      opts=ExecOptions(mode="run"))
        params = model.init(jax.random.key(0))
        with dispatch.stats_scope() as stats:
            sched = PagedScheduler(model, params, slots=2, max_len=32,
                                   page_size=4)
            done = sched.run([Request(i, p, 4)
                              for i, p in enumerate(prompts)])
            s = stats()
        assert len(done) == 2
        outs[policy] = {r.rid: list(r.out) for r in done}
        route = "kernel" if policy == "kernels" else "reference"
        assert s.get(("prefill_attention", route), 0) > 0, s
        assert s.get(("prefill_attention",
                      "kernel" if route == "reference" else "reference"),
                     0) == 0, s
    assert outs["kernels"] == outs["reference"]
