"""Unit tests for model components: RWKV chunked recurrence, RG-LRU scan,
MoE routing/capacity, RoPE/M-RoPE, chunked xent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.memory import BF16_POLICY, DtypePolicy, F32_POLICY
from repro.models import griffin, layers, moe, rwkv

KEY = jax.random.key(0)
F32 = F32_POLICY


# ------------------------------------------------------------------- rwkv
def wkv_sequential(r, k, v, lw, u):
    """Naive per-timestep oracle for the WKV recurrence."""
    b, s, h, hd = r.shape
    S = np.zeros((b, h, hd, hd), np.float64)
    out = np.zeros((b, s, h, hd), np.float64)
    r, k, v, lw, u = (np.asarray(t, np.float64) for t in (r, k, v, lw, u))
    for t in range(s):
        w = np.exp(lw[:, t])                       # (b, h, hd)
        kv = np.einsum("bhk,bhv->bhkv", k[:, t], v[:, t])
        bonus = np.einsum("bhk,hk,bhk->bh", r[:, t], u, k[:, t])
        out[:, t] = np.einsum("bhk,bhkv->bhv", r[:, t], S) \
            + bonus[..., None] * v[:, t]
        S = w[..., None] * S + kv
    return out


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (24, 8)])
def test_wkv_chunked_matches_sequential(s, chunk):
    b, h, hd = 2, 3, 8
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) - 2.0)
    u = jax.random.normal(ks[4], (h, hd), jnp.float32)
    got, _ = rwkv.wkv_chunked(r, k, v, lw, u, chunk=chunk)
    want = wkv_sequential(r, k, v, lw, u)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_wkv_unroll_equals_scan():
    b, s, h, hd = 1, 32, 2, 8
    ks = jax.random.split(KEY, 5)
    args = [jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3)]
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) - 2.0)
    u = jax.random.normal(ks[4], (h, hd))
    o1, s1 = rwkv.wkv_chunked(*args, lw, u, chunk=8, unroll=False)
    o2, s2 = rwkv.wkv_chunked(*args, lw, u, chunk=8, unroll=True)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("s,chunk,sub", [(64, 32, 8), (128, 64, 16)])
def test_wkv_matmul_intra_matches_direct(s, chunk, sub):
    """§Perf-1: the MXU-matmul intra-chunk form is numerically the direct
    form (all decay exponents provably <= 0)."""
    b, h, hd = 2, 2, 8
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) - 2.0)
    u = jax.random.normal(ks[4], (h, hd), jnp.float32)
    o1, s1 = rwkv.wkv_chunked(r, k, v, lw, u, chunk=chunk, intra="direct")
    o2, s2 = rwkv.wkv_chunked(r, k, v, lw, u, chunk=chunk, intra="matmul",
                              subchunk=sub)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-5)


def test_wkv_strong_decay_is_stable():
    """exp of large-negative log-decays must underflow to 0, not NaN."""
    b, s, h, hd = 1, 64, 1, 4
    r = jnp.ones((b, s, h, hd))
    k = jnp.ones((b, s, h, hd))
    v = jnp.ones((b, s, h, hd))
    lw = jnp.full((b, s, h, hd), -50.0)
    u = jnp.zeros((h, hd))
    out, state = rwkv.wkv_chunked(r, k, v, lw, u, chunk=16)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(jnp.isfinite(state)))


# ----------------------------------------------------------------- rg-lru
def test_rglru_scan_matches_sequential():
    b, s, w = 2, 24, 8
    a = jax.nn.sigmoid(jax.random.normal(KEY, (b, s, w)))
    bb = jax.random.normal(jax.random.key(1), (b, s, w))
    got = griffin.rglru_scan(a, bb)
    h = np.zeros((b, w))
    want = []
    for t in range(s):
        h = np.asarray(a[:, t]) * h + np.asarray(bb[:, t])
        want.append(h.copy())
    np.testing.assert_allclose(got, np.stack(want, 1), rtol=1e-4, atol=1e-5)


def test_rglru_block_decode_matches_apply():
    spec = griffin.GriffinSpec(d_model=16, lru_width=16, block_width=8)
    p = griffin.rglru_block_init(KEY, spec)
    b, s = 1, 6
    x = 0.1 * jax.random.normal(jax.random.key(2), (b, s, 16), jnp.float32)
    full = griffin.rglru_block_apply(p, spec, x, F32)
    cache = griffin.griffin_cache_init(b, spec, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = griffin.rglru_block_decode(p, spec, x[:, t:t + 1],
                                              cache, F32)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=2e-2, atol=2e-3)


# -------------------------------------------------------------------- moe
def test_moe_top1_equals_single_expert():
    """With top_k=1 and ample capacity, MoE output == the gated single
    expert's MLP output for every token."""
    s = moe.MoESpec(d_model=8, n_experts=4, top_k=1, d_expert=16,
                    capacity_factor=4.0, norm_topk=True)
    p = moe.moe_init(KEY, s)
    x = jax.random.normal(jax.random.key(1), (2, 6, 8), jnp.float32)
    out, aux = moe.moe_apply(p, s, x, F32)
    tokens = x.reshape(-1, 8)
    logits = tokens @ p["router"]
    eidx = jnp.argmax(logits, axis=-1)
    want = []
    for i, t in enumerate(np.asarray(tokens)):
        e = int(eidx[i])
        g = np.asarray(t) @ np.asarray(p["wg"][e])
        u = np.asarray(t) @ np.asarray(p["wu"][e])
        d = (g / (1 + np.exp(-g)) * u) @ np.asarray(p["wd"][e])
        want.append(d)   # gate normalizes to 1 for top-1
    np.testing.assert_allclose(out.reshape(-1, 8), np.stack(want),
                               rtol=1e-3, atol=1e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    s = moe.MoESpec(d_model=4, n_experts=2, top_k=1, d_expert=8,
                    capacity_factor=0.26, norm_topk=True)  # tiny capacity
    p = moe.moe_init(KEY, s)
    x = jax.random.normal(jax.random.key(1), (1, 64, 4), jnp.float32)
    out, _ = moe.moe_apply(p, s, x, F32)
    # dropped tokens produce exactly zero output rows
    norms = jnp.linalg.norm(out.reshape(-1, 4), axis=-1)
    assert int((norms == 0).sum()) > 0
    assert int((norms > 0).sum()) > 0


def test_moe_expert_padding_is_inert():
    s1 = moe.MoESpec(d_model=8, n_experts=6, top_k=2, d_expert=16,
                     capacity_factor=4.0, pad_to=1)
    s2 = moe.MoESpec(d_model=8, n_experts=6, top_k=2, d_expert=16,
                     capacity_factor=4.0, pad_to=4)   # pads to 8
    p1 = moe.moe_init(KEY, s1)
    # p2 = p1's experts + 2 zero-padded dummies
    p2 = {k: (jnp.pad(v, [(0, 2)] + [(0, 0)] * (v.ndim - 1))
              if k in ("wg", "wu", "wd") else v)
          for k, v in p1.items()}
    x = jax.random.normal(jax.random.key(1), (2, 5, 8), jnp.float32)
    o1, _ = moe.moe_apply(p1, s1, x, F32)
    o2, _ = moe.moe_apply(p2, s2, x, F32)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------- rope / m-rope
def test_rope_preserves_norm_and_relative_phase():
    b, s, h, hd = 1, 8, 2, 16
    x = jax.random.normal(KEY, (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = layers.apply_rope(x, pos, theta=1e4)
    np.testing.assert_allclose(jnp.linalg.norm(out, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-4)
    # position 0 is the identity
    np.testing.assert_allclose(out[:, 0], x[:, 0], rtol=1e-5, atol=1e-6)


def test_mrope_sections_rotate_independently():
    b, s, h, hd = 1, 4, 1, 16        # sections (2,3,3) over hd/2=8
    x = jnp.ones((b, s, h, hd))
    pos3 = jnp.zeros((b, s, 3), jnp.int32)
    pos3 = pos3.at[..., 0].set(jnp.arange(s)[None])     # only temporal moves
    out = layers.apply_rope(x, pos3, theta=1e4, mrope_sections=(2, 3, 3))
    # frequency slots owned by the h/w sections (positions all 0) unchanged
    np.testing.assert_allclose(out[0, :, 0, 2:8], x[0, :, 0, 2:8],
                               rtol=1e-6)
    np.testing.assert_allclose(out[0, :, 0, 10:16], x[0, :, 0, 10:16],
                               rtol=1e-6)
    # the temporal section rotates for t>0
    assert not np.allclose(out[0, 1:, 0, :2], x[0, 1:, 0, :2])


# ----------------------------------------------------------- chunked xent
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.sampled_from([1, 2, 4, 8]))
def test_chunked_xent_matches_reference(seed, n_chunks):
    b, s, d, v = 2, 16, 8, 32
    ks = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(ks[0], (b, s, d))
    head = jax.random.normal(ks[1], (d, v))
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    want = layers.softmax_xent((x @ head), labels)
    for unroll in (False, True):
        got = layers.chunked_xent(x, head, labels, n_chunks=n_chunks,
                                  unroll=unroll)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
