"""Quickstart: the transformation toolbox in 60 seconds.

1. Query the paper's cheat sheet (Table 1) for a bottleneck.
2. Apply the prescribed transformations to a kernel via the staged levels.
3. See the pipeline model + roofline napkin math the perf loop uses.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (Level, Objective, PipelineModel, TilePlanner,
                        recommend)
from repro.kernels.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref

# 1 ---- the cheat sheet -----------------------------------------------------
print("paper Tab. 1 — transformations for 'resolve loop-carried dependency':")
for t in recommend(Objective.LOOP_CARRIED_DEPENDENCY):
    print(f"  §{t.section} {t.name}: {t.tpu_mechanism[:70]}...")

# 2 ---- staged kernel -------------------------------------------------------
a = jax.random.normal(jax.random.key(0), (256, 256), jnp.bfloat16)
b = jax.random.normal(jax.random.key(1), (256, 256), jnp.bfloat16)
ref = matmul_ref(a, b)
for level in (Level.T0_NAIVE, Level.T1_PIPELINED, Level.T3_REPLICATED):
    out = matmul(a, b, level=level)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"matmul @ {level.name:14s} max|err| vs oracle = {err:.2e}")

# 3 ---- napkin math ---------------------------------------------------------
plan = TilePlanner().plan_matmul(8192, 8192, 8192)
print(f"\nTilePlanner for 8192^3 matmul: blocks=({plan.bm},{plan.bn},"
      f"{plan.bk}) VMEM={plan.vmem_bytes/2**20:.1f} MiB "
      f"AI={plan.arithmetic_intensity:.0f} flop/B")
pm = PipelineModel(latency=128, initiation_interval=1,
                   n=plan.grid[0] * plan.grid[1] * plan.grid[2])
print(f"grid pipeline: {pm.cycles():,.0f} cycles, fill/drain overhead "
      f"{pm.fill_drain_overhead():.2%}  (paper Eq. 1)")
