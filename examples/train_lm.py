"""End-to-end driver example: train a ~100M-param decoder for a few hundred
steps with the full production stack (sharded step, AdamW, checkpoints,
supervised restarts, deterministic data).

Default is a fast ~20M config so the example finishes in minutes on one
CPU core; pass --preset 100m for the assignment-scale run (same code, just
wider/deeper — budget ~1 h on this container, seconds on a v5e slice).

Run:  PYTHONPATH=src python examples/train_lm.py [--preset 100m] [--steps N]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_mod  # noqa: E402

PRESETS = {
    # (d_model, steps, batch, seq)
    "20m": (256, 300, 8, 128),
    "100m": (640, 200, 8, 256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="20m")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()
    d, steps, batch, seq = PRESETS[args.preset]
    if args.steps:
        steps = args.steps
    train_mod.main([
        "--arch", "codeqwen1.5-7b", "--smoke", "--d-model", str(d),
        "--steps", str(steps), "--batch", str(batch), "--seq", str(seq),
        "--lr", "1e-3", "--save-every", "100",
        "--ckpt-dir", "/tmp/repro_train_lm",
    ])


if __name__ == "__main__":
    main()
