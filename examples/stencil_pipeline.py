"""Paper §6.1 walk-through: the stencil transformation ladder, live.

Shows each stage's code-level transformation, validates the Pallas
delay-buffer kernel against the oracle in interpret mode, and prints the
derived TPU roofline progression (the Fig. 7 analogue).

Run:  PYTHONPATH=src python examples/stencil_pipeline.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import PipelineModel, TPU_V5E
from repro.core.plan import Level, PAPER_STAGES
from repro.kernels.stencil import jacobi4
from repro.kernels.stencil.ref import jacobi4_iter_ref

x = jax.random.normal(jax.random.key(0), (256, 512), jnp.float32)

print("stage ladder (paper §6.1):")
for level, desc in PAPER_STAGES.items():
    print(f"  {level.name:15s} {desc}")

# correctness: Pallas halo-BlockSpec kernel vs oracle, multiple sweeps
for steps in (1, 4):
    got = jacobi4(x, steps=steps, block_rows=64)
    want = jacobi4_iter_ref(x, steps)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"jacobi4 {steps} sweeps: max|err| = {err:.2e}")

# the derived Fig. 7 progression for an 8192x8192 domain on one v5e chip
# (memory-traffic-only model; benchmarks/run.py additionally charges T0's
# unpipelined initiation interval, which is why its T0 is ~100x slower)
hw = TPU_V5E
cells = 8192.0 * 8192.0
stages = {
    "T0 naive (no reuse)": 6 * 4 * cells / hw.hbm_bw,
    "T1 delay-buffered (§2.2)": 2 * 4 * cells / hw.hbm_bw,
    "T3 time-replicated x32 (§3.3)": max(
        2 * 4 * cells / 32 / hw.hbm_bw,
        4 * cells / (2 * 8 * 128 * hw.clock_hz)),
}
base = None
print("\nderived v5e sweep times (8192^2):")
for name, t in stages.items():
    base = base or t
    print(f"  {name:32s} {t*1e3:8.3f} ms   ({base/t:5.1f}x cumulative)")
