"""Fault-tolerance demo: inject failures mid-training, watch the supervisor
restore from the atomic checkpoint and replay to an identical trajectory.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.launch import train as train_mod  # noqa: E402

ARGS = ["--arch", "qwen2-moe-a2.7b", "--smoke", "--steps", "40",
        "--batch", "4", "--seq", "32", "--save-every", "10",
        "--log-every", "10"]

if __name__ == "__main__":
    print("=== clean run ===")
    clean = train_mod.main(ARGS + ["--ckpt-dir", "/tmp/ft_clean"])
    print("\n=== run with injected failures at steps 17 and 33 ===")
    faulty = train_mod.main(ARGS + ["--ckpt-dir", "/tmp/ft_faulty",
                                    "--inject-failures", "17,33"])
    same = np.allclose(clean[-1], faulty[-1], rtol=1e-5)
    print(f"\nfinal losses match after 2 failures + restores: {same}")
    assert same
