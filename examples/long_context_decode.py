"""Long-context decode with O(1) state: why `long_500k` runs for SSM/hybrid.

Decodes with the RWKV6 smoke model while tracking the cache footprint —
constant in context length (one (H, hd, hd) matrix + two d-vectors per
layer) — versus a same-size full-attention arch whose KV cache grows
linearly and hits the long_500k skip gate (DESIGN.md §Arch-applicability).

Run:  PYTHONPATH=src python examples/long_context_decode.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get_arch, shape_applicable  # noqa: E402
from repro.models.transformer import ExecOptions, Model  # noqa: E402


def cache_bytes(cache):
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def main():
    long = SHAPES["long_500k"]
    for arch in ("rwkv6-7b", "codeqwen1.5-7b"):
        ok, why = shape_applicable(get_arch(arch), long)
        print(f"{arch}: long_500k applicable={ok}"
              + (f"  ({why[:60]}...)" if not ok else ""))

    cfg = get_arch("rwkv6-7b").smoke()
    model = Model(cfg, opts=ExecOptions(mode="run"))
    params = model.init(jax.random.key(0))
    step = jax.jit(model.decode_step, donate_argnums=(1,))

    b = 1
    for horizon in (64, 4096):
        cache = model.init_cache(b, max_len=horizon)
        print(f"\nrwkv6 smoke cache @ context {horizon:>6}: "
              f"{cache_bytes(cache)/1024:.1f} KiB  (O(1) in context)")

    cache = model.init_cache(b, max_len=1 << 20)
    tok = jnp.zeros((b, 1), jnp.int32)
    for t in range(32):
        logits, cache = step(params, cache, {"tokens": tok}, jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    print(f"decoded 32 tokens at a 2^20-token horizon; cache still "
          f"{cache_bytes(cache)/1024:.1f} KiB; last token {int(tok[0,0])}")


if __name__ == "__main__":
    main()
