"""Batched-serving example: continuous batching with KV caches.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod  # noqa: E402

if __name__ == "__main__":
    serve_mod.main(["--arch", "gemma-2b", "--smoke", "--slots", "4",
                    "--requests", "8", "--prompt-len", "8",
                    "--max-new", "16", "--max-len", "64"])
