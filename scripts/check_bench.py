#!/usr/bin/env python
"""Benchmark-regression gate: compare a fresh BENCH_serve.json against the
committed baseline and fail on throughput OR latency regressions.

Usage (what ``scripts/ci.sh bench`` runs)::

    python benchmarks/run.py --serve --serve-dispatch kernels \
        --serve-out results/scratch/BENCH_serve_current.json
    python benchmarks/run.py --serve-continuous --serve-dispatch kernels \
        --serve-out results/scratch/BENCH_serve_current.json
    python scripts/check_bench.py \
        --baseline results/BENCH_serve.json \
        --current  results/scratch/BENCH_serve_current.json

Rows are keyed ``(arch, cache, schedule)`` — legacy rows without a
schedule field are the phased (``--serve``) rows.  Two gates per key:

* **throughput floor** — ``decode_tok_s`` must stay above
  ``baseline * (1 - tolerance)``; default tolerance 0.45 (absorbs CPU
  timer noise, still fails a 2x slowdown), override with ``--tolerance``
  or ``REPRO_BENCH_TOL``.
* **latency ceiling** — ``tok_latency_p99_s`` (the continuous engine's
  p99 per-token decode latency) must stay below
  ``baseline * (1 + lat_tolerance)``; default 0.8 (p99 of a small smoke
  sample is noisier than a mean), override with ``--lat-tolerance`` or
  ``REPRO_BENCH_LAT_TOL``.

A key gates only the metrics present on BOTH sides; keys present on one
side are reported but do not fail (a new benchmark must be able to land
before its baseline).

One more gate is self-contained in the CURRENT run: when both
``continuous-share95`` and ``continuous-share0`` rows are present for an
(arch, cache), the 95%-shared-prefix scenario must strictly beat the
0%-sharing scenario on ``max_resident`` (requests resident per page
pool) and ``prefill_tok_s_effective`` (prompt tokens served per prefill
second) — the two wins prefix sharing exists to deliver.  No tolerance:
sharing that doesn't help is a regression of the feature itself.

Likewise baseline-free: when ``continuous-int8-share0`` rides alongside
``continuous-share0``, the int8 KV pool must land STRICTLY below the
default-dtype pool on ``max_resident_kv_bytes`` (byte-denominated
residency is the entire point of quantizing the cache) while holding
``decode_tok_s`` within the throughput tolerance — capacity won by
giving back throughput beyond the noise band is not a win.

Sharded serving (``continuous-tp*`` rows, from ``--serve-sharded``) is
gated baseline-free on its CORRECTNESS verdicts rather than throughput:
``tokens_match_oracle`` must be true (tp=1 is bit-identical to the
unsharded scheduler; tp>=2 matches the single-device oracle),
``tp_ops_in_region >= 3`` proves matmul + decode_attention +
prefill_attention all dispatched through ``registry.call`` inside the
shard_map region, and ``kernels_match_reference`` (present on tp>=2
kernel rows) must be true.  Correctness has no tolerance knob.

Speculative decoding (``continuous-spec*`` rows, from
``--serve-speculative``) is likewise gated baseline-free on its own
contract: ``tokens_match_baseline`` must be truthy (greedy speculative
streams are bit-identical to the plain engine by construction — any
divergence is a bug, not noise), ``acceptance_rate`` must be strictly
positive (drafts that never survive verification make speculation a
pure slowdown), and ``decode_tok_s`` must be reported.

Updating the baseline (after an intentional perf change or a new
machine): re-run the benchmark writing straight to the baseline path and
commit the result — see benchmarks/README.md ("Benchmark-regression
gate").
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path
from typing import Dict, List, Tuple

DEFAULT_TOLERANCE = 0.45
DEFAULT_LAT_TOLERANCE = 0.8
FLOOR_METRIC = "decode_tok_s"       # higher is better
CEIL_METRIC = "tok_latency_p99_s"   # lower is better
SHARE_METRICS = ("max_resident", "prefill_tok_s_effective")  # higher wins
BYTES_METRIC = "max_resident_kv_bytes"  # lower wins (int8 vs default KV)

Key = Tuple[str, str, str]


def load_metrics(path) -> Dict[Key, Dict[str, float]]:
    """BENCH_serve.json -> {(arch, cache, schedule): {metric: value}}."""
    data = json.loads(Path(path).read_text())
    out: Dict[Key, Dict[str, float]] = {}
    for row in data.get("rows", []):
        key = (row.get("arch", "?"), row.get("cache", "?"),
               row.get("schedule", "phased"))
        metrics = {m: float(row[m])
                   for m in ((FLOOR_METRIC, CEIL_METRIC, BYTES_METRIC)
                             + SHARE_METRICS)
                   if row.get(m) is not None}
        if metrics:
            out[key] = metrics
    return out


def compare(baseline: Dict[Key, Dict[str, float]],
            current: Dict[Key, Dict[str, float]],
            tolerance: float = DEFAULT_TOLERANCE,
            lat_tolerance: float = DEFAULT_LAT_TOLERANCE
            ) -> Tuple[List[str], int]:
    """Return (failure lines, metric comparisons made).

    Zero failures only passes the gate when at least one metric
    overlapped — a current run whose keys/metrics don't line up with the
    baseline must not pass vacuously.
    """
    failures, compared = [], 0
    for key in sorted(baseline):
        if key not in current:
            print(f"note: {key} in baseline but not in current run")
            continue
        name = "/".join(key)
        base, cur = baseline[key], current[key]
        if FLOOR_METRIC in base and FLOOR_METRIC in cur:
            compared += 1
            floor = base[FLOOR_METRIC] * (1.0 - tolerance)
            if cur[FLOOR_METRIC] < floor:
                failures.append(
                    f"{name}: {FLOOR_METRIC} {cur[FLOOR_METRIC]:.2f} < "
                    f"floor {floor:.2f} (baseline {base[FLOOR_METRIC]:.2f},"
                    f" tolerance {tolerance:.0%})")
        if CEIL_METRIC in base and CEIL_METRIC in cur:
            compared += 1
            ceil = base[CEIL_METRIC] * (1.0 + lat_tolerance)
            if cur[CEIL_METRIC] > ceil:
                failures.append(
                    f"{name}: {CEIL_METRIC} {cur[CEIL_METRIC]:.6f} > "
                    f"ceiling {ceil:.6f} (baseline {base[CEIL_METRIC]:.6f},"
                    f" tolerance {lat_tolerance:.0%})")
    for key in sorted(set(current) - set(baseline)):
        print(f"note: {key} in current run but not in baseline "
              f"(commit an updated baseline to start gating it)")
    return failures, compared


def compare_sharing(current: Dict[Key, Dict[str, float]]
                    ) -> Tuple[List[str], int]:
    """Prefix-sharing win gate, baseline-free: share95 must strictly beat
    share0 (same arch/cache, same current run) on every SHARE_METRICS."""
    failures, compared = [], 0
    for arch, cache, schedule in sorted(current):
        if schedule != "continuous-share95":
            continue
        lo_key = (arch, cache, "continuous-share0")
        if lo_key not in current:
            continue
        hi, lo = current[(arch, cache, schedule)], current[lo_key]
        for metric in SHARE_METRICS:
            if metric not in hi or metric not in lo:
                continue
            compared += 1
            if hi[metric] <= lo[metric]:
                failures.append(
                    f"{arch}/{cache}: share95 {metric} {hi[metric]:.2f} "
                    f"<= share0 {lo[metric]:.2f} — prefix sharing "
                    f"delivered no {metric} gain")
    return failures, compared


def compare_kv_dtype(current: Dict[Key, Dict[str, float]],
                     tolerance: float = DEFAULT_TOLERANCE
                     ) -> Tuple[List[str], int]:
    """Quantized-KV win gate, baseline-free: the int8 pool must be
    strictly cheaper in bytes than the default-dtype pool on the SAME
    0%-sharing workload (no tolerance — the byte ratio is a layout
    constant, not a timing), without giving back decode throughput
    beyond the ordinary noise tolerance."""
    failures, compared = [], 0
    for arch, cache, schedule in sorted(current):
        if schedule != "continuous-int8-share0":
            continue
        base_key = (arch, cache, "continuous-share0")
        if base_key not in current:
            continue
        q, base = current[(arch, cache, schedule)], current[base_key]
        if BYTES_METRIC in q and BYTES_METRIC in base:
            compared += 1
            if q[BYTES_METRIC] >= base[BYTES_METRIC]:
                failures.append(
                    f"{arch}/{cache}: int8-share0 {BYTES_METRIC} "
                    f"{q[BYTES_METRIC]:.0f} >= share0 "
                    f"{base[BYTES_METRIC]:.0f} — quantizing the pool "
                    f"saved no bytes")
        if FLOOR_METRIC in q and FLOOR_METRIC in base:
            compared += 1
            floor = base[FLOOR_METRIC] * (1.0 - tolerance)
            if q[FLOOR_METRIC] < floor:
                failures.append(
                    f"{arch}/{cache}: int8-share0 {FLOOR_METRIC} "
                    f"{q[FLOOR_METRIC]:.2f} < floor {floor:.2f} "
                    f"(share0 {base[FLOOR_METRIC]:.2f}, tolerance "
                    f"{tolerance:.0%}) — int8 capacity won by giving "
                    f"back decode throughput")
    return failures, compared


def load_rows(path) -> List[dict]:
    """Raw rows (verdict fields included — booleans never survive
    ``load_metrics``' float coercion)."""
    return json.loads(Path(path).read_text()).get("rows", [])


def compare_tp(rows: List[dict]) -> Tuple[List[str], int]:
    """Sharded-serving correctness gate, baseline-free: every
    ``continuous-tp*`` row in the CURRENT run must carry a truthy
    ``tokens_match_oracle`` (the sharded engine reproduced the
    single-device oracle's greedy streams — bit-identical at tp=1),
    ``tp_ops_in_region`` >= 3 (matmul + decode_attention +
    prefill_attention all routed through registry.call INSIDE the
    shard_map region), and, when present, a truthy
    ``kernels_match_reference`` (sharded kernels vs sharded reference
    agree token-for-token).  Correctness has no tolerance knob."""
    failures, compared = [], 0
    for row in rows:
        sched = row.get("schedule", "")
        if not sched.startswith("continuous-tp"):
            continue
        name = f"{row.get('arch', '?')}/{row.get('cache', '?')}/{sched}"
        compared += 1
        if not row.get("tokens_match_oracle"):
            failures.append(
                f"{name}: tokens_match_oracle="
                f"{row.get('tokens_match_oracle')!r} — sharded streams "
                f"diverged from the single-device oracle")
        compared += 1
        if int(row.get("tp_ops_in_region", 0)) < 3:
            failures.append(
                f"{name}: tp_ops_in_region="
                f"{row.get('tp_ops_in_region')!r} < 3 — serving ops did "
                f"not all route through registry.call inside shard_map")
        if "kernels_match_reference" in row:
            compared += 1
            if not row["kernels_match_reference"]:
                failures.append(
                    f"{name}: kernels_match_reference="
                    f"{row['kernels_match_reference']!r} — sharded kernel "
                    f"and reference routes disagree")
    return failures, compared


def compare_spec(rows: List[dict]) -> Tuple[List[str], int]:
    """Speculative-decoding gate, baseline-free: every
    ``continuous-spec*`` row in the CURRENT run must carry a truthy
    ``tokens_match_baseline`` (greedy speculative streams bit-identical
    to the plain continuous engine on the same seeded stream — the
    subsystem's correctness contract), an ``acceptance_rate`` strictly
    above zero (a drafter whose drafts never survive verification is a
    pure slowdown, not a feature), and a reported ``decode_tok_s``
    (the row must carry the throughput it claims to improve).
    Correctness has no tolerance knob."""
    failures, compared = [], 0
    for row in rows:
        sched = row.get("schedule", "")
        if not sched.startswith("continuous-spec"):
            continue
        name = f"{row.get('arch', '?')}/{row.get('cache', '?')}/{sched}"
        compared += 1
        if not row.get("tokens_match_baseline"):
            failures.append(
                f"{name}: tokens_match_baseline="
                f"{row.get('tokens_match_baseline')!r} — speculative "
                f"streams diverged from the non-speculative baseline")
        compared += 1
        if not float(row.get("acceptance_rate") or 0.0) > 0.0:
            failures.append(
                f"{name}: acceptance_rate="
                f"{row.get('acceptance_rate')!r} — no draft token "
                f"survived verification (drafting is pure overhead)")
        compared += 1
        if row.get("decode_tok_s") is None:
            failures.append(
                f"{name}: decode_tok_s missing — the row carries no "
                f"decode throughput to compare against the baseline")
    return failures, compared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="results/BENCH_serve.json")
    ap.add_argument("--current",
                    default="results/scratch/BENCH_serve_current.json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("REPRO_BENCH_TOL",
                                                 DEFAULT_TOLERANCE)),
                    help="allowed fractional throughput slowdown before "
                         f"failing (default {DEFAULT_TOLERANCE}, env "
                         "REPRO_BENCH_TOL)")
    ap.add_argument("--lat-tolerance", type=float,
                    default=float(os.environ.get("REPRO_BENCH_LAT_TOL",
                                                 DEFAULT_LAT_TOLERANCE)),
                    help="allowed fractional p99 per-token latency "
                         f"increase before failing (default "
                         f"{DEFAULT_LAT_TOLERANCE}, env "
                         "REPRO_BENCH_LAT_TOL)")
    args = ap.parse_args(argv)
    baseline = load_metrics(args.baseline)
    current = load_metrics(args.current)
    if not baseline:
        print(f"error: no gated-metric rows in baseline {args.baseline}")
        return 2
    failures, compared = compare(baseline, current, args.tolerance,
                                 args.lat_tolerance)
    share_failures, share_compared = compare_sharing(current)
    failures += share_failures
    compared += share_compared
    q_failures, q_compared = compare_kv_dtype(current, args.tolerance)
    failures += q_failures
    compared += q_compared
    current_rows = load_rows(args.current)
    tp_failures, tp_compared = compare_tp(current_rows)
    failures += tp_failures
    compared += tp_compared
    spec_failures, spec_compared = compare_spec(current_rows)
    failures += spec_failures
    compared += spec_compared
    for line in failures:
        print(f"REGRESSION: {line}")
    if failures:
        print(f"bench gate FAILED ({len(failures)} regression(s)); if "
              "intentional, update the baseline per benchmarks/README.md")
        return 1
    if compared == 0:
        print(f"error: no gated metrics in {args.current} overlap the "
              "baseline — the gate compared nothing (metric or row keys "
              "changed?)")
        return 2
    print(f"bench gate passed: {compared} metric comparison(s) within "
          f"tolerance (throughput {args.tolerance:.0%}, latency "
          f"{args.lat_tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
