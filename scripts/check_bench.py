#!/usr/bin/env python
"""Benchmark-regression gate: compare a fresh BENCH_serve.json against the
committed baseline and fail if decode throughput regressed.

Usage (what ``scripts/ci.sh bench`` runs)::

    python benchmarks/run.py --serve --serve-dispatch kernels \
        --serve-out results/BENCH_serve_current.json
    python scripts/check_bench.py \
        --baseline results/BENCH_serve.json \
        --current  results/BENCH_serve_current.json

A row regresses when ``current < baseline * (1 - tolerance)`` for its
``(arch, cache)`` key; rows present on only one side are reported but do
not fail the gate (a new benchmark must be able to land before its
baseline).  The default tolerance (0.45) absorbs CPU timer noise while
still failing a 2x slowdown; override per-run with ``--tolerance`` or the
``REPRO_BENCH_TOL`` env var.

Updating the baseline (after an intentional perf change or a new
machine): re-run the benchmark writing straight to the baseline path and
commit the result — see benchmarks/README.md ("Benchmark-regression
gate").
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path
from typing import Dict, List, Tuple

DEFAULT_TOLERANCE = 0.45
METRIC = "decode_tok_s"


def load_metrics(path) -> Dict[Tuple[str, str], float]:
    """BENCH_serve.json -> {(arch, cache): decode_tok_s}."""
    data = json.loads(Path(path).read_text())
    out: Dict[Tuple[str, str], float] = {}
    for row in data.get("rows", []):
        val = row.get(METRIC)
        if val is not None:
            out[(row.get("arch", "?"), row.get("cache", "?"))] = float(val)
    return out


def compare(baseline: Dict[Tuple[str, str], float],
            current: Dict[Tuple[str, str], float],
            tolerance: float = DEFAULT_TOLERANCE) -> Tuple[List[str], int]:
    """Return (failure lines, rows actually compared).

    Zero failures only passes the gate when at least one row overlapped —
    a current run whose keys/metric don't line up with the baseline must
    not pass vacuously.
    """
    failures, compared = [], 0
    for key in sorted(baseline):
        if key not in current:
            print(f"note: {key} in baseline but not in current run")
            continue
        compared += 1
        base, cur = baseline[key], current[key]
        floor = base * (1.0 - tolerance)
        if cur < floor:
            failures.append(
                f"{key[0]}/{key[1]}: {METRIC} {cur:.2f} < floor {floor:.2f} "
                f"(baseline {base:.2f}, tolerance {tolerance:.0%})")
    for key in sorted(set(current) - set(baseline)):
        print(f"note: {key} in current run but not in baseline "
              f"(commit an updated baseline to start gating it)")
    return failures, compared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="results/BENCH_serve.json")
    ap.add_argument("--current", default="results/BENCH_serve_current.json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("REPRO_BENCH_TOL",
                                                 DEFAULT_TOLERANCE)),
                    help="allowed fractional slowdown before failing "
                         f"(default {DEFAULT_TOLERANCE}, env "
                         "REPRO_BENCH_TOL)")
    args = ap.parse_args(argv)
    baseline = load_metrics(args.baseline)
    current = load_metrics(args.current)
    if not baseline:
        print(f"error: no {METRIC} rows in baseline {args.baseline}")
        return 2
    failures, compared = compare(baseline, current, args.tolerance)
    for line in failures:
        print(f"REGRESSION: {line}")
    if failures:
        print(f"bench gate FAILED ({len(failures)} regression(s)); if "
              "intentional, update the baseline per benchmarks/README.md")
        return 1
    if compared == 0:
        print(f"error: no {METRIC} rows in {args.current} overlap the "
              "baseline — the gate compared nothing (metric or row keys "
              "changed?)")
        return 2
    print(f"bench gate passed: {compared} row(s) within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
