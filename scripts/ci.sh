#!/usr/bin/env bash
# CI entry points.
#   scripts/ci.sh smoke   — fast suite (-m "not slow"), incl. the kernel
#                           dispatch differential tests
#                           (tests/test_dispatch_differential.py +
#                           tests/test_paged_decode.py, capped shapes)
#   scripts/ci.sh full    — everything, incl. multi-device subprocess tests
#   scripts/ci.sh tune    — design-space sweep; writes results/tuned_plans.json
#   scripts/ci.sh serve   — paged-serving smoke: interpret-mode ragged
#                           decode through dispatch.decode_attention for a
#                           few steps, plus BENCH_serve.json throughput rows
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-smoke}" in
  smoke) python -m pytest -q -m "not slow" ;;
  full)  python -m pytest -q ;;
  tune)  python benchmarks/run.py --tune ;;
  serve)
    python -m repro.launch.serve --arch gemma-2b --smoke --cache paged \
      --dispatch kernels --slots 2 --requests 3 --prompt-len 6 \
      --max-new 4 --max-len 32 --page-size 8
    python benchmarks/run.py --serve --serve-dispatch kernels
    ;;
  *) echo "usage: $0 {smoke|full|tune|serve}" >&2; exit 2 ;;
esac
