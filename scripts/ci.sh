#!/usr/bin/env bash
# CI entry points.
#   scripts/ci.sh smoke   — fast suite (-m "not slow"), incl. the kernel
#                           dispatch differential tests
#                           (tests/test_dispatch_differential.py +
#                           tests/test_paged_decode.py +
#                           tests/test_flash_backward.py, capped shapes)
#                           Timing audit (2026-07-30, container single-CPU,
#                           --durations=15): slowest test 27s < the 30s
#                           slow-marker threshold, no moves needed; target
#                           smoke wall-time <= ~8 min.
#   scripts/ci.sh full    — everything, incl. multi-device subprocess tests
#   scripts/ci.sh lint    — compileall + compat-policy grep gates (no direct
#                           hypothesis imports outside the shim, no direct
#                           jax.make_mesh(..., axis_types=...) outside
#                           launch/mesh.py, no direct kernel-family imports
#                           from models/ or launch/ — everything routes
#                           through kernels.dispatch / kernels.registry —
#                           and shard_map / mesh construction only via
#                           runtime/compat.py + launch/mesh.py)
#   scripts/ci.sh tune    — design-space sweep; writes results/tuned_plans.json
#   scripts/ci.sh serve   — paged-serving smoke: interpret-mode ragged
#                           prefill + decode through dispatch for a few
#                           steps (static AND continuous schedules), plus
#                           BENCH_serve.json throughput/latency rows and
#                           BENCH_prefill.json kernel-vs-reference rows,
#                           plus a forced-2-device sharded smoke (--mesh 2
#                           CLI + --serve-sharded bench) gated by
#                           check_bench's baseline-free compare_tp, plus a
#                           speculative smoke (--speculate ngram CLI +
#                           --serve-speculative bench) gated by compare_spec
#   scripts/ci.sh bench   — benchmark-regression gate: re-run both serve
#                           benchmark modes and fail if decode throughput
#                           dropped or p99 per-token latency rose more than
#                           the tolerances vs the committed
#                           results/BENCH_serve.json (scripts/check_bench.py;
#                           REPRO_BENCH_TOL / REPRO_BENCH_LAT_TOL override)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

lint() {
  python -m compileall -q src tests benchmarks scripts examples
  # ROADMAP compat policy, enforced as grep gates:
  # 1. tests import the seeded shim, never hypothesis directly
  bad=$(grep -rnE '^[[:space:]]*(import hypothesis|from hypothesis)' \
        src tests --include='*.py' | grep -v '_hypothesis_compat.py' || true)
  if [ -n "$bad" ]; then
    echo "lint: direct hypothesis import (use tests/_hypothesis_compat):"
    echo "$bad"; exit 1
  fi
  # 2. mesh construction goes through repro.launch.mesh.make_mesh
  bad=$(grep -rn 'axis_types' src --include='*.py' \
        | grep -v 'launch/mesh.py' || true)
  if [ -n "$bad" ]; then
    echo "lint: jax.make_mesh axis_types outside launch/mesh.py" \
         "(use repro.launch.mesh.make_mesh):"
    echo "$bad"; exit 1
  fi
  # 3. models/ and launch/ never import a kernel family directly — every
  #    hot contraction routes through kernels.dispatch (thin facades) /
  #    kernels.registry (the one generic path), so tuned plans, route
  #    counters, and policy knobs can't be silently bypassed
  bad=$(grep -rnE \
        'kernels(\.| +import +)(matmul|attention|stencil|histogram|nbody|wkv)' \
        src/repro/models src/repro/launch --include='*.py' || true)
  if [ -n "$bad" ]; then
    echo "lint: direct kernel-family import from models/ or launch/" \
         "(route through repro.kernels.dispatch):"
    echo "$bad"; exit 1
  fi
  # 4. int8 KV pools are born in ONE place (transformer.layer_cache_init_
  #    paged, following cfg.kv_dtype) so scale leaves can never be missing
  #    or mis-sized — model/launch code must not construct int8 buffers
  #    directly (repro.core.quant owns the quantize/dequantize math)
  bad=$(grep -rnE 'jnp\.(zeros|empty|full)\([^)]*jnp\.int8' \
        src/repro/models src/repro/launch --include='*.py' \
        | grep -v 'models/transformer.py' || true)
  if [ -n "$bad" ]; then
    echo "lint: int8 KV buffer constructed outside" \
         "models/transformer.layer_cache_init_paged (route kv storage" \
         "through cfg.kv_dtype + repro.core.quant):"
    echo "$bad"; exit 1
  fi
  # 5. shard_map enters the codebase through ONE shim
  #    (runtime/compat.shard_map handles the jax.shard_map vs
  #    jax.experimental.shard_map + check_vma/check_rep rename) and mesh
  #    construction through launch/mesh.py — sharded serving must not
  #    fork new version-feature-detection sites
  bad=$(grep -rnE 'jax\.shard_map|experimental(\.| +import +)shard_map' \
        src --include='*.py' | grep -v 'runtime/compat.py' || true)
  if [ -n "$bad" ]; then
    echo "lint: shard_map used outside runtime/compat.py" \
         "(call repro.runtime.compat.shard_map):"
    echo "$bad"; exit 1
  fi
  bad=$(grep -rnE 'jax\.make_mesh|sharding\.Mesh\(' src --include='*.py' \
        | grep -v 'launch/mesh.py' || true)
  if [ -n "$bad" ]; then
    echo "lint: mesh constructed outside launch/mesh.py" \
         "(use repro.launch.mesh.make_mesh / make_serving_mesh):"
    echo "$bad"; exit 1
  fi
  echo "lint: OK"
}

case "${1:-smoke}" in
  smoke) python -m pytest -q -m "not slow" ;;
  full)  python -m pytest -q ;;
  lint)  lint ;;
  tune)  python benchmarks/run.py --tune ;;
  serve)
    python -m repro.launch.serve --arch gemma-2b --smoke --cache paged \
      --dispatch kernels --slots 2 --requests 3 --prompt-len 6 \
      --max-new 4 --max-len 32 --page-size 8
    python -m repro.launch.serve --arch gemma-2b --smoke --cache paged \
      --schedule continuous --dispatch kernels --slots 2 --requests 3 \
      --prompt-len 6 --max-new 4 --max-len 32 --page-size 4 --clock tick
    # speculative smoke: ngram draft -> fixed-width verify -> rollback on
    # the same paged path; the CLI prints the verify/accept counters and
    # the bench rows carry tokens_match_baseline + acceptance_rate for
    # check_bench's baseline-free compare_spec gate
    python -m repro.launch.serve --arch gemma-2b --smoke --cache paged \
      --dispatch kernels --speculate ngram --slots 2 --requests 3 \
      --prompt-len 6 --max-new 4 --max-len 32 --page-size 8
    python benchmarks/run.py --serve --serve-dispatch kernels
    python benchmarks/run.py --serve-continuous --serve-dispatch kernels
    python benchmarks/run.py --serve-speculative --serve-dispatch kernels
    python benchmarks/run.py --prefill
    # sharded smoke: force a 2-device host mesh and run the tensor-parallel
    # paged path end-to-end — the CLI on gemma (MQA, replicated pools) and
    # the bench on codeqwen (GQA, sharded pools).  The bench rows carry the
    # correctness verdicts (tokens_match_oracle, kernels_match_reference,
    # tp_ops_in_region) that check_bench's compare_tp gates baseline-free.
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
      python -m repro.launch.serve --arch gemma-2b --smoke --cache paged \
      --dispatch kernels --mesh 2 --slots 2 --requests 3 --prompt-len 6 \
      --max-new 4 --max-len 32 --page-size 8
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
      python benchmarks/run.py --serve-sharded --serve-dispatch kernels
    python scripts/check_bench.py \
      --baseline results/BENCH_serve.json \
      --current results/BENCH_serve.json
    ;;
  bench)
    # scratch outputs live under gitignored results/scratch/ so a bench
    # run can never leave stray artifacts in the committed results/
    mkdir -p results/scratch
    rm -f results/scratch/BENCH_serve_current.json
    python benchmarks/run.py --serve --serve-dispatch kernels \
      --serve-out results/scratch/BENCH_serve_current.json
    python benchmarks/run.py --serve-continuous --serve-dispatch kernels \
      --serve-out results/scratch/BENCH_serve_current.json
    python benchmarks/run.py --serve-speculative --serve-dispatch kernels \
      --serve-out results/scratch/BENCH_serve_current.json
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
      python benchmarks/run.py --serve-sharded --serve-dispatch kernels \
      --serve-out results/scratch/BENCH_serve_current.json
    python scripts/check_bench.py \
      --baseline results/BENCH_serve.json \
      --current results/scratch/BENCH_serve_current.json
    ;;
  *) echo "usage: $0 {smoke|full|lint|tune|serve|bench}" >&2; exit 2 ;;
esac
