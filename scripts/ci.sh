#!/usr/bin/env bash
# CI entry points.
#   scripts/ci.sh smoke   — fast suite (-m "not slow"), incl. the kernel
#                           dispatch differential tests
#                           (tests/test_dispatch_differential.py, capped
#                           shapes: ~30s of the budget); stays ≲3 min
#   scripts/ci.sh full    — everything, incl. multi-device subprocess tests
#   scripts/ci.sh tune    — design-space sweep; writes results/tuned_plans.json
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-smoke}" in
  smoke) python -m pytest -q -m "not slow" ;;
  full)  python -m pytest -q ;;
  tune)  python benchmarks/run.py --tune ;;
  *) echo "usage: $0 {smoke|full|tune}" >&2; exit 2 ;;
esac
