.PHONY: smoke test tune bench

smoke:        ## fast suite, skips multi-device subprocess tests
	./scripts/ci.sh smoke

test:         ## full tier-1 suite
	./scripts/ci.sh full

tune:         ## sweep the kernel design space, persist tuned plans
	./scripts/ci.sh tune

bench:        ## Fig. 7 staged-progression benchmark
	PYTHONPATH=src python benchmarks/run.py
