.PHONY: smoke test tune serve bench

smoke:        ## fast suite, skips multi-device subprocess tests
	./scripts/ci.sh smoke

test:         ## full tier-1 suite
	./scripts/ci.sh full

tune:         ## sweep the kernel design space, persist tuned plans
	./scripts/ci.sh tune

serve:        ## paged-serving smoke + BENCH_serve.json throughput rows
	./scripts/ci.sh serve

bench:        ## Fig. 7 staged-progression benchmark
	PYTHONPATH=src python benchmarks/run.py
