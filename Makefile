.PHONY: smoke test lint tune serve bench bench-gate train-grad prefill

smoke:        ## fast suite, skips multi-device subprocess tests
	./scripts/ci.sh smoke

test:         ## full tier-1 suite
	./scripts/ci.sh full

lint:         ## compileall + compat-policy grep gates
	./scripts/ci.sh lint

tune:         ## sweep the kernel design space, persist tuned plans
	./scripts/ci.sh tune

serve:        ## paged-serving smoke + BENCH_serve.json throughput rows
	./scripts/ci.sh serve

bench-gate:   ## re-run serve bench, fail on decode-throughput regression
	./scripts/ci.sh bench

train-grad:   ## fused vs reference attention-backward timing rows
	PYTHONPATH=src python benchmarks/run.py --train-grad

prefill:      ## ragged prefill-attention kernel vs reference timing rows
	PYTHONPATH=src python benchmarks/run.py --prefill

bench:        ## Fig. 7 staged-progression benchmark
	PYTHONPATH=src python benchmarks/run.py
